//! K-means on Gaussian blobs through the full stack: ds-array blocks →
//! task runtime → fused Pallas `kmeans_assign` artifact via PJRT.
//!
//!     make artifacts && cargo run --release --example kmeans_clustering

use anyhow::Result;
use rustdslib::bench::workloads::blobs;
use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::Estimator;
use rustdslib::tasking::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::builder().workers(2).build()?;
    let (n, f, k) = (4096, 64, 6);
    let (data, truth) = blobs(n, f, k, 0.8, 3);
    let x = creation::from_matrix(&rt, &data, (64, 64))?;
    println!("data: {n} samples x {f} features, {k} blobs, blocks 64x64 ({} blocks)", x.n_blocks());
    println!(
        "pjrt: {}",
        if rustdslib::runtime::global().is_some() {
            "fused kmeans artifact active"
        } else {
            "artifacts missing -> native fallback (run `make artifacts`)"
        }
    );

    let mut km = KMeans::new(KMeansConfig {
        k,
        max_iter: 25,
        tol: 1e-5,
        seed: 11,
    });
    let t0 = std::time::Instant::now();
    km.fit(&x, None)?;
    println!(
        "\nfit: {} iterations, inertia {:.1}, {:.2}s",
        km.n_iter,
        km.inertia,
        t0.elapsed().as_secs_f64()
    );

    // Cluster-label agreement with ground truth (best-match purity).
    let pred = km.predict(&x)?.collect()?;
    let mut table = vec![vec![0usize; k]; k];
    for (i, &t) in truth.iter().enumerate() {
        table[t][pred.get(i, 0) as usize] += 1;
    }
    let purity: usize = table.iter().map(|row| row.iter().max().unwrap()).sum();
    println!("cluster purity: {:.1}% (majority-match)", 100.0 * purity as f64 / n as f64);

    let m = rt.metrics();
    println!(
        "tasks: {} total — {} kmeans.partial, {} kmeans.reduce, {} kmeans.reduce_update (plan-composed)",
        m.total_tasks(),
        m.tasks_for("kmeans.partial"),
        m.tasks_for("kmeans.reduce"),
        m.tasks_for("kmeans.reduce_update"),
    );
    Ok(())
}
