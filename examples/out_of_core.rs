//! Out-of-core quickstart: ingest a CSV with the parallel partitioned
//! loader, fit KMeans under a memory budget **half** the array's footprint,
//! and verify the centroids match an unconstrained in-memory run.
//!
//! ```text
//! cargo run --release --example out_of_core
//! cargo run --release --example out_of_core -- --data fixtures/csv_parts --svm fixtures/part.svm
//! ```
//!
//! `--data <dir>` loads a partition directory (one CSV file per block-row)
//! instead of generating data; `--svm <file>` additionally smoke-tests the
//! parallel SVMLight loader; `--budget-kb <n>` overrides the budget.

use anyhow::Result;
use rustdslib::dsarray::io as dsio;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::Estimator;
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let args = rustdslib::util::cli::Args::from_env();
    let workers = args.get_usize("workers", 2);

    // Where the data comes from: a partition directory, or a generated file.
    let mut generated = None;
    let (path, block_shape) = match args.get("data") {
        // Partition directory: rows-per-block comes from the files.
        Some(dir) => (std::path::PathBuf::from(dir), (usize::MAX, 16)),
        None => {
            let mut rng = Xoshiro256::seed_from_u64(9);
            let m = DenseMatrix::from_fn(512, 16, |_, _| rng.next_normal());
            let p = std::env::temp_dir()
                .join(format!("rustdslib_ooc_example_{}.csv", std::process::id()));
            rustdslib::storage::io::write_csv(&p, &m, ',')?;
            generated = Some(p.clone());
            (p, (64, 16))
        }
    };

    // Unconstrained baseline.
    let rt_mem = Runtime::builder().workers(workers).build()?;
    let x_mem = dsio::load_csv(&rt_mem, &path, block_shape, ',')?;
    let footprint = (x_mem.rows() * x_mem.cols() * 4) as u64;
    let mut km_mem = KMeans::new(KMeansConfig::default());
    km_mem.fit(&x_mem, None)?;

    // Same pipeline at HALF the footprint: blocks spill to disk and fault
    // back in as the fit touches them.
    let budget = args.get_u64("budget-kb", 0) * 1024;
    let budget = if budget > 0 { budget } else { (footprint / 2).max(1) };
    let rt = Runtime::builder()
        .workers(workers)
        .memory_budget_bytes(budget)
        .build()?;
    let x = dsio::load_csv(&rt, &path, block_shape, ',')?;
    println!(
        "loaded {}x{} ({} blocks) from {} — footprint {} B, budget {} B",
        x.rows(),
        x.cols(),
        x.n_blocks(),
        path.display(),
        footprint,
        budget
    );
    let mut km = KMeans::new(KMeansConfig::default());
    km.fit(&x, None)?;

    let same = km.centers.as_ref() == km_mem.centers.as_ref();
    let met = rt.metrics();
    println!(
        "kmeans at {}x RAM: centroids identical to in-memory run: {same}",
        (footprint as f64 / budget as f64 * 10.0).round() / 10.0
    );
    println!(
        "spilled {} blocks ({} B written), faulted {} back, peak resident {} B",
        met.blocks_spilled, met.spill_bytes, met.blocks_faulted, met.peak_resident_bytes
    );
    assert!(same, "out-of-core run must be bit-identical");
    assert!(met.blocks_spilled > 0 && met.blocks_faulted > 0);

    // Optional: smoke the parallel SVMLight loader against a fixture.
    if let Some(svm) = args.get("svm") {
        let nf = args.get_usize("svm-features", 10);
        let (xs, ys) = dsio::load_svmlight(&rt, std::path::Path::new(svm), nf, (64, nf))?;
        println!(
            "svmlight: {}x{} sparse samples + {} labels loaded in {} tasks",
            xs.rows(),
            xs.cols(),
            ys.rows(),
            rt.metrics().tasks_for("dsarray.io.load_svmlight")
        );
        xs.runtime().barrier()?;
    }

    if let Some(p) = generated {
        std::fs::remove_file(p).ok();
    }
    println!("ok");
    Ok(())
}
