//! ALS recommender on a scaled-down Netflix-like sparse ratings matrix —
//! the paper's §5.3 workload at laptop scale, real execution.
//!
//!     make artifacts && cargo run --release --example als_recommender
//!
//! Demonstrates the ds-array advantage end-to-end: the V update reads the
//! ratings matrix's block-COLUMNS directly; the Dataset baseline must build
//! a transposed copy first. Both are run and timed.

use anyhow::Result;
use rustdslib::bench::workloads::netflix_like_csr;
use rustdslib::dataset::Dataset;
use rustdslib::dsarray::creation;
use rustdslib::estimators::als::{Als, AlsConfig};
use rustdslib::tasking::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::builder().workers(2).build()?;
    // Netflix shape / 100: same density profile (power-law users).
    let (rows, cols, nnz) = (512, 4096, 25_000);
    let ratings = netflix_like_csr(rows, cols, nnz, 9)?;
    println!(
        "ratings: {rows} items x {cols} users, {} observed ({:.2}% dense, Netflix-like)",
        ratings.nnz(),
        100.0 * ratings.density()
    );

    let cfg = AlsConfig {
        d: 16,
        lambda: 0.1,
        max_iter: 8,
        seed: 3,
    };

    // ---- ds-array path: 8x8 block grid, direct column access ----
    let x = creation::from_csr(&rt, &ratings, (64, 512))?;
    let t0 = std::time::Instant::now();
    let mut als = Als::new(cfg.clone());
    als.fit_dsarray(&x)?;
    let t_dsarray = t0.elapsed().as_secs_f64();
    let m = rt.metrics();
    println!(
        "\nds-array fit: {t_dsarray:.2}s, transpose tasks: {}",
        m.tasks_with_prefix("dataset.transpose") + m.tasks_with_prefix("dsarray.transpose")
    );

    // ---- Dataset baseline: transposed copy inside fit ----
    let ds = Dataset::from_matrix(&rt, &ratings.to_dense(), None, 8)?;
    let t0 = std::time::Instant::now();
    let mut als_base = Als::new(cfg);
    als_base.fit_dataset(&ds)?;
    let t_dataset = t0.elapsed().as_secs_f64();
    let m2 = rt.metrics().since(&m);
    println!(
        "dataset fit : {t_dataset:.2}s, transpose tasks: {} (N²+N for N=8)",
        m2.tasks_with_prefix("dataset.transpose")
    );

    // ---- Quality: both models rank observed cells above random cells ----
    for (name, model) in [("ds-array", &als), ("dataset ", &als_base)] {
        let rec = model.reconstruct()?;
        let dense = ratings.to_dense();
        let (mut hit, mut miss, mut nh, mut nm) = (0.0f64, 0.0f64, 0usize, 0usize);
        for i in 0..rows {
            for j in 0..cols {
                if dense.get(i, j) > 0.0 {
                    hit += rec.get(i, j) as f64;
                    nh += 1;
                } else if (i + j) % 97 == 0 {
                    miss += rec.get(i, j) as f64;
                    nm += 1;
                }
            }
        }
        println!(
            "{name}: mean prediction on observed {:.3} vs unobserved {:.3}",
            hit / nh as f64,
            miss / nm as f64
        );
    }

    // ---- A few recommendations for user 0 ----
    println!("\ntop items for user 0 (ds-array model):");
    let mut scored: Vec<(usize, f32)> = (0..rows).map(|i| (i, als.predict_one(i, 0).unwrap())).collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (item, score) in scored.iter().take(5) {
        println!("  item {item:>4}: {score:.3}");
    }
    Ok(())
}
