//! Extended features (paper §6: "matrix multiplication and decomposition,
//! in a more natural way"): distributed TSQR, k-NN and Gaussian NB
//! classifiers on ds-arrays, and array concatenation.
//!
//!     make artifacts && cargo run --release --example extended_features

use anyhow::Result;
use rustdslib::bench::workloads::blobs;
use rustdslib::dsarray::{combine, creation};
use rustdslib::estimators::{Estimator, GaussianNb, KnnClassifier};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let rt = Runtime::builder().workers(2).build()?;

    // ---- TSQR: distributed thin QR of a tall-skinny ds-array ----
    let mut rng = Xoshiro256::seed_from_u64(1);
    let a = DenseMatrix::from_fn(4000, 16, |_, _| rng.next_normal());
    let d = creation::from_matrix(&rt, &a, (250, 16))?; // 16 block rows
    let t0 = std::time::Instant::now();
    let (q, r) = d.tsqr()?;
    let qm = q.collect()?;
    let rm = rt.wait(r)?.to_dense()?;
    let recon_err = qm.matmul(&rm)?.max_abs_diff(&a);
    let ortho_err = qm
        .transpose()
        .matmul(&qm)?
        .max_abs_diff(&DenseMatrix::identity(16));
    println!(
        "TSQR 4000x16 (16 block rows): ||QR-A||∞ = {recon_err:.2e}, ||QᵀQ-I||∞ = {ortho_err:.2e} ({:.2}s)",
        t0.elapsed().as_secs_f64()
    );
    let m = rt.metrics();
    println!(
        "  tasks: {} local QR + {} merges + {} applies",
        m.tasks_for("dsarray.tsqr.local"),
        m.tasks_for("dsarray.tsqr.merge"),
        m.tasks_for("dsarray.tsqr.apply"),
    );

    // ---- Classifiers on blobs: kNN vs Gaussian NB ----
    let (train, ytrain) = blobs(600, 12, 4, 0.9, 5);
    let (test, ytest) = blobs(200, 12, 4, 0.9, 99);
    let xt = creation::from_matrix(&rt, &train, (50, 12))?;
    let yt = creation::from_matrix(
        &rt,
        &DenseMatrix::from_fn(600, 1, |i, _| ytrain[i] as f32),
        (50, 1),
    )?;
    let xq = creation::from_matrix(&rt, &test, (50, 12))?;
    let yq = creation::from_matrix(
        &rt,
        &DenseMatrix::from_fn(200, 1, |i, _| ytest[i] as f32),
        (50, 1),
    )?;

    let mut knn = KnnClassifier::new(5);
    knn.fit(&xt, Some(&yt))?;
    println!("\nkNN (k=5)      test accuracy: {:.1}%", 100.0 * knn.score(&xq, &yq)?);

    let mut gnb = GaussianNb::default();
    gnb.fit(&xt, Some(&yt))?;
    println!("Gaussian NB    test accuracy: {:.1}%", 100.0 * gnb.score(&xq, &yq)?);

    // ---- Concatenation ----
    let top = creation::random(&rt, (100, 12), (50, 12), 1)?;
    let both = combine::vstack(&[&top, &xt])?;
    println!(
        "\nvstack: (100x12) + (600x12) -> {:?} in {} blocks (zero-task fast path)",
        both.shape(),
        both.n_blocks()
    );
    Ok(())
}
