//! Quickstart: the ds-array NumPy-like API in two minutes.
//!
//!     cargo run --release --example quickstart
//!
//! Creates distributed arrays, chains operations exactly like the paper's
//! §4.2.3 example (`sqrt(||wᵀ||²)`), slices, reduces, multiplies, and
//! collects — all automatically parallelized by the task runtime.

use anyhow::Result;
use rustdslib::dsarray::creation;
use rustdslib::tasking::Runtime;

fn main() -> Result<()> {
    // A local runtime with one worker thread per core.
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let rt = Runtime::builder().workers(workers).build()?;
    println!("runtime: {workers} worker threads\n");

    // -- Creation: one task per block, data born distributed ------------
    let w = creation::random(&rt, (600, 400), (100, 100), 42)?;
    println!("w        : {:?} in {:?} blocks of {:?}", w.shape(), w.grid(), w.block_shape());

    // -- The paper's chained expression: sqrt(||wᵀ||₂²) ------------------
    let expr = w.transpose()?.norm_axis(1)?.pow(2.0)?.sqrt()?;
    println!("expr     : {:?} = sqrt(||w^T||²) per column", expr.shape());
    let vals = expr.collect()?;
    println!("first 4  : {:?}", &vals.data()[..4]);

    // -- Indexing: zero-copy views ---------------------------------------
    // Block-aligned slices are pure metadata: zero tasks, blocks shared
    // with `w` (benches/hotpath.rs measures this against forced copies —
    // sub-microsecond view construction vs a full per-block copy pass).
    let before = rt.metrics().total_tasks();
    let rows = w.slice_rows(100, 500)?; // A[100:500] — aligned to 100-row blocks
    let cols = w.slice_cols(300, 400)?; // A[:, 300:400] — cheap on ds-arrays!
    println!(
        "A[100:500]: {:?}   A[:,300:400]: {:?}   tasks submitted: {}",
        rows.shape(),
        cols.shape(),
        rt.metrics().total_tasks() - before
    );
    // Unaligned slices become lazy views; downstream ops (or .force())
    // materialize them per block only when needed.
    let lazy = w.slice(5, 595, 3, 397)?;
    println!("A[5:595,3:397]: is_view={} until an op forces it", lazy.is_view());
    println!("A[5,7]   : {:.4}", w.get(5, 7)?);
    // Fancy indexing: arbitrary row lists, boolean masks, train/test split.
    let picked = w.take_rows(&[599, 0, 7, 7])?;
    let (train, test) = w.train_test_split(0.25, 42)?;
    println!(
        "take_rows : {:?}   split: train {:?} / test {:?} (all lazy views)",
        picked.shape(),
        train.shape(),
        test.shape()
    );

    // -- Math ------------------------------------------------------------
    let b = creation::random(&rt, (400, 300), (100, 100), 7)?;
    let c = w.matmul(&b)?;
    println!("w @ b    : {:?} (blocked matmul, one task per output block)", c.shape());
    let mean = c.mean_axis(0)?.collect()?;
    println!("col means: {:.3} {:.3} {:.3} ...", mean.get(0, 0), mean.get(0, 1), mean.get(0, 2));

    // -- Shuffle + reductions --------------------------------------------
    let s = w.shuffle_rows(1)?;
    println!("shuffle  : preserves sums? {} vs {}", s.sum()? as i64, w.sum()? as i64);

    // -- What did the runtime do? ----------------------------------------
    let m = rt.metrics();
    println!("\ntasks executed: {} across {} ops", m.total_tasks(), m.tasks_by_op.len());
    for (op, n) in m.tasks_by_op.iter().take(6) {
        println!("  {op:<32} {n}");
    }
    Ok(())
}
