//! END-TO-END DRIVER (DESIGN.md §7): the full stack on a real small
//! workload, proving all layers compose —
//!
//!   L3 rust coordinator (task graphs, scheduling, metrics)
//!     → L2 jax block graphs → L1 Pallas kernels, AOT via PJRT
//!
//! Pipeline: generate a labeled dataset → load as ds-array → StandardScaler
//! (col_stats + standardize artifacts) → K-means (fused kmeans artifact) →
//! predict + purity; then reproduce the paper's headline data-ops
//! comparison (transpose / shuffle, ds-array vs Dataset) on the same data,
//! measured for real on the local executor, and a mini ALS for the column
//! access story. Results are recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example pipeline_e2e

use std::time::Instant;

use anyhow::Result;
use rustdslib::bench::workloads::blobs;
use rustdslib::dataset::Dataset;
use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::{Estimator, StandardScaler};
use rustdslib::tasking::Runtime;

fn main() -> Result<()> {
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let rt = Runtime::builder().workers(workers).build()?;
    println!("=== pipeline_e2e: full-stack driver ({workers} workers) ===");
    let pjrt = rustdslib::runtime::global().is_some();
    println!(
        "PJRT artifacts: {}",
        if pjrt { "ACTIVE (L1/L2 on the hot path)" } else { "missing — run `make artifacts`" }
    );

    // ---- 1. Real small workload: 4096 x 512, 16 Gaussian blobs ----
    let (n, f, k) = (4096, 512, 16);
    let (data, truth) = blobs(n, f, k, 1.0, 42);
    let t0 = Instant::now();
    let x = creation::from_matrix(&rt, &data, (64, 64))?;
    println!(
        "\n[load]   {n}x{f} as {:?} grid of 64x64 blocks   ({:.2}s)",
        x.grid(),
        t0.elapsed().as_secs_f64()
    );

    // ---- 2. StandardScaler through the fused elementwise engine ----
    // fit_transform returns a deferred `(x − μ) · σ⁻¹` chain; force() makes
    // it materialize here (one fused task per block) so the timing below
    // measures the transform, not the K-means entry point.
    let t0 = Instant::now();
    let mut scaler = StandardScaler::default();
    let xs = scaler.fit_transform(&x)?.force()?;
    xs.runtime().barrier()?;
    println!("[scale]  fit+transform                         ({:.2}s)", t0.elapsed().as_secs_f64());

    // ---- 3. K-means through the fused Pallas kernel ----
    let t0 = Instant::now();
    let mut km = KMeans::new(KMeansConfig {
        k,
        max_iter: 30,
        tol: 1e-5,
        seed: 7,
    });
    km.fit(&xs, None)?;
    let fit_s = t0.elapsed().as_secs_f64();
    let pred = km.predict(&xs)?.collect()?;
    let mut table = vec![vec![0usize; k]; k];
    for (i, &t) in truth.iter().enumerate() {
        table[t][pred.get(i, 0) as usize] += 1;
    }
    let purity: usize = table.iter().map(|r| r.iter().max().unwrap()).sum();
    println!(
        "[kmeans] {} iters, inertia {:.0}, purity {:.1}%   ({fit_s:.2}s)",
        km.n_iter,
        km.inertia,
        100.0 * purity as f64 / n as f64
    );

    // ---- 4. Headline data-ops comparison on the SAME data ----
    println!("\n--- paper headline: data ops, ds-array vs Dataset (real, local) ---");
    let n_parts = 64;
    let ds = Dataset::from_matrix(&rt, &data, None, n_parts)?;
    let xa = creation::from_matrix(&rt, &data, (n / n_parts, f))?; // 64x1 grid

    let snap = rt.metrics();
    let t0 = Instant::now();
    let td = ds.transpose()?;
    td.collect_samples()?; // force completion
    let t_ds = t0.elapsed().as_secs_f64();
    let tasks_ds = rt.metrics().since(&snap).total_tasks();

    let snap = rt.metrics();
    let t0 = Instant::now();
    let ta = xa.transpose()?;
    ta.runtime().barrier()?;
    let t_da = t0.elapsed().as_secs_f64();
    let tasks_da = rt.metrics().since(&snap).total_tasks();
    println!(
        "transpose: Dataset {t_ds:.3}s / {tasks_ds} tasks   ds-array {t_da:.3}s / {tasks_da} tasks   ({:.1}x, {:.0}x fewer tasks)",
        t_ds / t_da,
        tasks_ds as f64 / tasks_da as f64
    );

    let snap = rt.metrics();
    let t0 = Instant::now();
    ds.shuffle(5)?.collect_samples()?;
    let s_ds = t0.elapsed().as_secs_f64();
    let stasks_ds = rt.metrics().since(&snap).total_tasks();

    let snap = rt.metrics();
    let t0 = Instant::now();
    let sh = xa.shuffle_rows(5)?;
    sh.runtime().barrier()?;
    let s_da = t0.elapsed().as_secs_f64();
    let stasks_da = rt.metrics().since(&snap).total_tasks();
    println!(
        "shuffle  : Dataset {s_ds:.3}s / {stasks_ds} tasks   ds-array {s_da:.3}s / {stasks_da} tasks   ({:.1}x, {:.0}x fewer tasks)",
        s_ds / s_da,
        stasks_ds as f64 / stasks_da as f64
    );

    // ---- 5. Column access story (mini ALS gram) ----
    let t0 = Instant::now();
    let g = xs.slice_cols(0, 128)?.gram()?;
    g.runtime().barrier()?;
    println!(
        "gram     : XᵀX on 128 columns with ZERO transpose tasks ({:.3}s)",
        t0.elapsed().as_secs_f64()
    );

    let m = rt.metrics();
    println!(
        "\ntotal: {} tasks across {} distinct ops; {:.1} MB declared I/O",
        m.total_tasks(),
        m.tasks_by_op.len(),
        (m.read_bytes + m.write_bytes) / 1e6
    );
    println!("=== pipeline_e2e OK ===");
    Ok(())
}
