//! Paper workload generators (§5) — both the real (materialized) variants
//! used by examples/local benches and the phantom variants the cluster
//! simulator schedules at MareNostrum scale.

use anyhow::Result;

use crate::dataset::Dataset;
use crate::dsarray::{creation, DsArray};
use crate::storage::{CsrMatrix, DenseMatrix};
use crate::tasking::Runtime;
use crate::util::rng::Xoshiro256;

/// Netflix Prize dimensions (paper §5.3).
pub const NETFLIX_ROWS: usize = 17_770;
pub const NETFLIX_COLS: usize = 480_189;
pub const NETFLIX_NNZ: usize = 100_480_507;

/// Netflix density ≈ 1.18 %.
pub fn netflix_density() -> f64 {
    NETFLIX_NNZ as f64 / (NETFLIX_ROWS as f64 * NETFLIX_COLS as f64)
}

/// Phantom Netflix-shape ratings as a ds-array with an n×n block grid
/// (the paper uses 192×192 blocks).
pub fn netflix_phantom_dsarray(rt: &Runtime, grid: usize) -> Result<DsArray> {
    let bs = (
        NETFLIX_ROWS.div_ceil(grid),
        NETFLIX_COLS.div_ceil(grid),
    );
    creation::phantom(rt, (NETFLIX_ROWS, NETFLIX_COLS), bs, Some(netflix_density()))
}

/// Phantom Netflix-shape ratings as a Dataset with `n_subsets` row panels.
pub fn netflix_phantom_dataset(rt: &Runtime, n_subsets: usize) -> Result<Dataset> {
    Dataset::phantom(
        rt,
        NETFLIX_ROWS,
        NETFLIX_COLS,
        n_subsets,
        Some(netflix_density()),
    )
}

/// Materialized scaled-down Netflix-like ratings with a power-law column
/// (user) popularity profile: rank r gets weight ∝ 1/(r+1)^0.8.
pub fn netflix_like_csr(rows: usize, cols: usize, nnz: usize, seed: u64) -> Result<CsrMatrix> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    // Zipf-ish column sampler via inverse CDF over precomputed weights.
    let weights: Vec<f64> = (0..cols).map(|r| 1.0 / ((r + 1) as f64).powf(0.8)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(cols);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    let mut trips = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let u = rng.next_f64();
        let col = cdf.partition_point(|&c| c < u).min(cols - 1);
        let row = rng.next_below(rows as u64) as usize;
        let rating = 1.0 + rng.next_below(5) as f32; // 1..=5 stars
        trips.push((row, col, rating));
    }
    CsrMatrix::from_triplets(rows, cols, &trips)
}

/// Gaussian blobs with ground-truth labels: `k` well-separated clusters.
pub fn blobs(
    n: usize,
    f: usize,
    k: usize,
    spread: f32,
    seed: u64,
) -> (DenseMatrix, Vec<usize>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    // Cluster centers on a scaled hypercube lattice.
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|c| {
            (0..f)
                .map(|j| if (c >> (j % 16)) & 1 == 1 { 6.0 } else { -6.0 } + (c as f32) * 0.5)
                .collect()
        })
        .collect();
    let mut data = DenseMatrix::zeros(n, f);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        labels.push(c);
        for j in 0..f {
            data.set(i, j, centers[c][j] + rng.next_normal() * spread);
        }
    }
    (data, labels)
}

/// Fig 6 strong-scaling transpose workload parameters (paper §5.2).
pub struct TransposeStrong;
impl TransposeStrong {
    pub const ROWS: usize = 46_080;
    pub const COLS: usize = 46_080;
    pub const PARTITIONS: usize = 1_536;

    pub fn dsarray(rt: &Runtime) -> Result<DsArray> {
        // 1536×1 blocks: full-width row panels of 30 rows.
        let bs = (Self::ROWS / Self::PARTITIONS, Self::COLS);
        creation::phantom(rt, (Self::ROWS, Self::COLS), bs, None)
    }

    pub fn dataset(rt: &Runtime) -> Result<Dataset> {
        Dataset::phantom(rt, Self::ROWS, Self::COLS, Self::PARTITIONS, None)
    }
}

/// Fig 6 weak-scaling transpose workload: 500 rows/core × 100 000 features.
pub struct TransposeWeak;
impl TransposeWeak {
    pub const ROWS_PER_CORE: usize = 500;
    pub const COLS: usize = 100_000;

    pub fn dsarray(rt: &Runtime, cores: usize) -> Result<DsArray> {
        let rows = Self::ROWS_PER_CORE * cores;
        creation::phantom(rt, (rows, Self::COLS), (Self::ROWS_PER_CORE, Self::COLS), None)
    }

    pub fn dataset(rt: &Runtime, cores: usize) -> Result<Dataset> {
        Dataset::phantom(rt, Self::ROWS_PER_CORE * cores, Self::COLS, cores, None)
    }
}

/// Fig 8 weak-scaling shuffle workload: 300 rows × 2 features per core.
pub struct ShuffleWeak;
impl ShuffleWeak {
    pub const ROWS_PER_CORE: usize = 300;
    pub const COLS: usize = 2;

    pub fn dsarray(rt: &Runtime, cores: usize) -> Result<DsArray> {
        let rows = Self::ROWS_PER_CORE * cores;
        creation::phantom(rt, (rows, Self::COLS), (Self::ROWS_PER_CORE, Self::COLS), None)
    }

    pub fn dataset(rt: &Runtime, cores: usize) -> Result<Dataset> {
        Dataset::phantom(rt, Self::ROWS_PER_CORE * cores, Self::COLS, cores, None)
    }
}

/// Fig 9 K-means workload: ~50M samples × 1000 features, 1536 partitions.
pub struct KMeansStrong;
impl KMeansStrong {
    pub const ROWS: usize = 50_000_000;
    pub const COLS: usize = 1_000;
    pub const PARTITIONS: usize = 1_536;
    /// The paper does not state k; 50 is dislib's benchmark default.
    pub const K: usize = 50;
    pub const ITERS: usize = 5;

    pub fn dsarray(rt: &Runtime) -> Result<DsArray> {
        let bs = (Self::ROWS.div_ceil(Self::PARTITIONS), Self::COLS);
        creation::phantom(rt, (Self::ROWS, Self::COLS), bs, None)
    }

    pub fn dataset(rt: &Runtime) -> Result<Dataset> {
        Dataset::phantom(rt, Self::ROWS, Self::COLS, Self::PARTITIONS, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasking::SimConfig;

    #[test]
    fn netflix_density_matches_paper() {
        let d = netflix_density();
        assert!((0.0117..0.0119).contains(&d), "density {d}");
    }

    #[test]
    fn netflix_like_has_power_law_columns() {
        let m = netflix_like_csr(200, 1000, 20_000, 1).unwrap();
        assert_eq!(m.nnz() <= 20_000, true); // duplicates merged
        let dense = m.to_dense();
        // First 10 columns should hold far more mass than columns 500..510.
        let head: f32 = (0..10)
            .map(|j| (0..200).map(|i| dense.get(i, j).min(1.0)).sum::<f32>())
            .sum();
        let tail: f32 = (500..510)
            .map(|j| (0..200).map(|i| dense.get(i, j).min(1.0)).sum::<f32>())
            .sum();
        assert!(head > 4.0 * tail, "head {head} tail {tail}");
    }

    #[test]
    fn blobs_are_separable() {
        let (data, labels) = blobs(60, 8, 3, 0.3, 2);
        // Same-label rows are close; cross-label rows are far.
        let dist = |a: usize, b: usize| -> f32 {
            (0..8)
                .map(|j| (data.get(a, j) - data.get(b, j)).powi(2))
                .sum::<f32>()
                .sqrt()
        };
        assert_eq!(labels[0], labels[3]);
        assert_ne!(labels[0], labels[1]);
        assert!(dist(0, 3) < dist(0, 1), "intra < inter");
    }

    #[test]
    fn phantom_workloads_have_paper_geometry() {
        let sim = Runtime::sim(SimConfig::with_workers(4));
        let a = TransposeStrong::dsarray(&sim).unwrap();
        assert_eq!(a.grid(), (1536, 1));
        let d = TransposeStrong::dataset(&sim).unwrap();
        assert_eq!(d.n_subsets(), 1536);
        let n = netflix_phantom_dsarray(&sim, 192).unwrap();
        assert_eq!(n.grid(), (192, 192));
        assert!(n.is_sparse());
        let k = KMeansStrong::dsarray(&sim).unwrap();
        assert_eq!(k.grid(), (1536, 1));
        // No tasks were submitted for any of this.
        assert_eq!(sim.metrics().total_tasks(), 0);
    }
}
