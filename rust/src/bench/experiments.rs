//! Per-figure experiment drivers (DESIGN.md §6): each reproduces one table
//! or figure of the paper's §5 by building the *real* task graphs at
//! MareNostrum scale against the sim-mode runtime and replaying them under
//! the calibrated cluster model.

use anyhow::Result;

use crate::config::Config;
use crate::dsarray::creation;
use crate::estimators::als::{Als, AlsConfig};
use crate::estimators::kmeans::{KMeans, KMeansConfig};
use crate::tasking::Runtime;

use super::report::{Point, Series};
use super::workloads::{
    netflix_phantom_dataset, netflix_phantom_dsarray, KMeansStrong, ShuffleWeak, TransposeStrong,
    TransposeWeak,
};

/// Run one simulated operation: build the workload + op graph with `build`,
/// replay, return (makespan, task count).
fn simulate(cfg: &Config, cores: usize, build: impl FnOnce(&Runtime) -> Result<()>) -> Result<(f64, u64)> {
    let rt = Runtime::sim(cfg.sim_at(cores));
    build(&rt)?;
    let tasks = rt.metrics().total_tasks();
    let report = rt.run_sim()?;
    Ok((report.makespan_s, tasks))
}

/// Fig 6 (left): strong-scaling transpose, 46 080² with 1 536 partitions.
/// `dataset_core_cap`: beyond this core count the Dataset run is reported
/// as n.a. (the paper's missing points are real OOMs at the master; the
/// simulated graph is identical at every core count, so we mirror the
/// paper's reporting rather than pretend the run succeeded).
pub fn fig6_strong(cfg: &Config, dataset_core_cap: usize) -> Result<Series> {
    let mut series = Series::new(
        "Fig 6 (strong): transpose 46080x46080, 1536 partitions — Datasets vs ds-arrays",
    );
    for &cores in &cfg.sim_cores {
        let dataset_s = if cores <= dataset_core_cap {
            let (t, _) = simulate(cfg, cores, |rt| {
                let ds = TransposeStrong::dataset(rt)?;
                ds.transpose()?;
                Ok(())
            })?;
            Some(t)
        } else {
            None
        };
        let (a_t, a_tasks) = simulate(cfg, cores, |rt| {
            let a = TransposeStrong::dsarray(rt)?;
            a.transpose()?;
            Ok(())
        })?;
        let d_tasks = if dataset_s.is_some() {
            (TransposeStrong::PARTITIONS * TransposeStrong::PARTITIONS
                + TransposeStrong::PARTITIONS) as u64
        } else {
            0
        };
        series.push(Point {
            cores,
            dataset_s,
            dsarray_s: a_t,
            tasks: (d_tasks, a_tasks),
        });
    }
    Ok(series)
}

/// Fig 6 (right): weak-scaling transpose, 500 rows/core × 100 000 features.
pub fn fig6_weak(cfg: &Config) -> Result<Series> {
    let mut series =
        Series::new("Fig 6 (weak): transpose 500 rows/core x 100k cols — Datasets vs ds-arrays");
    for &cores in &cfg.sim_cores {
        let (d_t, d_tasks) = simulate(cfg, cores, |rt| {
            let ds = TransposeWeak::dataset(rt, cores)?;
            ds.transpose()?;
            Ok(())
        })?;
        let (a_t, a_tasks) = simulate(cfg, cores, |rt| {
            let a = TransposeWeak::dsarray(rt, cores)?;
            a.transpose()?;
            Ok(())
        })?;
        series.push(Point {
            cores,
            dataset_s: Some(d_t),
            dsarray_s: a_t,
            tasks: (d_tasks, a_tasks),
        });
    }
    Ok(series)
}

/// Fig 7: ALS on Netflix-shape data; Dataset (192 Subsets, transposed copy
/// inside fit) vs ds-array (192×192 blocks, direct column access).
pub fn fig7_als(cfg: &Config, grid: usize, iters: usize) -> Result<Series> {
    let mut series = Series::new(format!(
        "Fig 7: ALS, Netflix 17770x480189 (~100.5M nnz), {grid} partitions, {iters} iters"
    ));
    for &cores in &cfg.sim_cores {
        let (d_t, d_tasks) = simulate(cfg, cores, |rt| {
            let ds = netflix_phantom_dataset(rt, grid)?;
            let mut als = Als::new(AlsConfig {
                d: 32,
                lambda: 0.1,
                max_iter: iters,
                seed: 1,
            });
            als.fit_dataset(&ds)
        })?;
        let (a_t, a_tasks) = simulate(cfg, cores, |rt| {
            let a = netflix_phantom_dsarray(rt, grid)?;
            let mut als = Als::new(AlsConfig {
                d: 32,
                lambda: 0.1,
                max_iter: iters,
                seed: 1,
            });
            als.fit_dsarray(&a)
        })?;
        series.push(Point {
            cores,
            dataset_s: Some(d_t),
            dsarray_s: a_t,
            tasks: (d_tasks, a_tasks),
        });
    }
    Ok(series)
}

/// Fig 8: weak-scaling pseudo-shuffle, 300 rows × 2 features per core.
pub fn fig8_shuffle(cfg: &Config) -> Result<Series> {
    let mut series =
        Series::new("Fig 8 (weak): shuffle 300 rows x 2 cols per core — Datasets vs ds-arrays");
    for &cores in &cfg.sim_cores {
        let (d_t, d_tasks) = simulate(cfg, cores, |rt| {
            let ds = ShuffleWeak::dataset(rt, cores)?;
            ds.shuffle(7)?;
            Ok(())
        })?;
        let (a_t, a_tasks) = simulate(cfg, cores, |rt| {
            let a = ShuffleWeak::dsarray(rt, cores)?;
            a.shuffle_rows(7)?;
            Ok(())
        })?;
        series.push(Point {
            cores,
            dataset_s: Some(d_t),
            dsarray_s: a_t,
            tasks: (d_tasks, a_tasks),
        });
    }
    Ok(series)
}

/// Fig 9: strong-scaling K-means, ~50M × 1000, 1536 partitions — the
/// control experiment (curves should overlap).
pub fn fig9_kmeans(cfg: &Config, iters: usize) -> Result<Series> {
    let mut series = Series::new(format!(
        "Fig 9 (strong): K-means 50M x 1000, k={}, 1536 partitions, {iters} iters",
        KMeansStrong::K
    ));
    for &cores in &cfg.sim_cores {
        let kcfg = KMeansConfig {
            k: KMeansStrong::K,
            max_iter: iters,
            tol: 0.0,
            seed: 5,
        };
        let (d_t, d_tasks) = simulate(cfg, cores, |rt| {
            let ds = KMeansStrong::dataset(rt)?;
            KMeans::new(kcfg.clone()).fit_dataset(&ds)
        })?;
        let (a_t, a_tasks) = simulate(cfg, cores, |rt| {
            let a = KMeansStrong::dsarray(rt)?;
            KMeans::new(kcfg.clone()).fit_dsarray(&a)
        })?;
        series.push(Point {
            cores,
            dataset_s: Some(d_t),
            dsarray_s: a_t,
            tasks: (d_tasks, a_tasks),
        });
    }
    Ok(series)
}

/// EXP-TASKS: task-count formulas vs partition count N (paper §4.3/§5).
/// Returns rows of (N, dataset transpose, dsarray transpose, dataset
/// shuffle, dsarray shuffle, dsarray shuffle w/o collections).
pub fn task_count_table(cfg: &Config, ns: &[usize]) -> Result<Vec<(usize, u64, u64, u64, u64, u64)>> {
    let mut rows = Vec::new();
    for &n in ns {
        let rt = Runtime::sim(cfg.sim_at(48));
        // Transpose needs features >= N for the Dataset split.
        let ds = crate::dataset::Dataset::phantom(&rt, n * 4, n * 2, n, None)?;
        let before = rt.metrics();
        ds.transpose()?;
        let d_tr = rt.metrics().since(&before).total_tasks();

        let a = creation::phantom(&rt, (n * 4, n * 2), (4, n * 2), None)?;
        let before = rt.metrics();
        a.transpose()?;
        let a_tr = rt.metrics().since(&before).total_tasks();

        // Shuffle: S = 4 rows per subset (S < N once n > 4).
        let before = rt.metrics();
        ds.shuffle(1)?;
        let d_sh = rt.metrics().since(&before).total_tasks();

        let before = rt.metrics();
        a.shuffle_rows(1)?;
        let a_sh = rt.metrics().since(&before).total_tasks();

        let before = rt.metrics();
        a.shuffle_rows_no_collections(1)?;
        let a_shn = rt.metrics().since(&before).total_tasks();

        rows.push((n, d_tr, a_tr, d_sh, a_sh, a_shn));
    }
    Ok(rows)
}

/// ABL-BLK: ALS block-grid ablation at fixed core counts — the §5.3
/// partition-count overhead discussion.
pub fn ablation_blocks(cfg: &Config, grids: &[usize], iters: usize) -> Result<Vec<(usize, f64, u64)>> {
    let cores = *cfg.sim_cores.last().unwrap_or(&768);
    let mut rows = Vec::new();
    for &g in grids {
        let (t, tasks) = simulate(cfg, cores, |rt| {
            let a = netflix_phantom_dsarray(rt, g)?;
            let mut als = Als::new(AlsConfig {
                d: 32,
                lambda: 0.1,
                max_iter: iters,
                seed: 1,
            });
            als.fit_dsarray(&a)
        })?;
        rows.push((g, t, tasks));
    }
    Ok(rows)
}

/// ABL-COLL: shuffle with vs without collection parameters across cores.
pub fn ablation_collections(cfg: &Config) -> Result<Vec<(usize, f64, f64, u64, u64)>> {
    let mut rows = Vec::new();
    for &cores in &cfg.sim_cores {
        let (with_t, with_tasks) = simulate(cfg, cores, |rt| {
            let a = ShuffleWeak::dsarray(rt, cores)?;
            a.shuffle_rows(3)?;
            Ok(())
        })?;
        let (wo_t, wo_tasks) = simulate(cfg, cores, |rt| {
            let a = ShuffleWeak::dsarray(rt, cores)?;
            a.shuffle_rows_no_collections(3)?;
            Ok(())
        })?;
        rows.push((cores, with_t, wo_t, with_tasks, wo_tasks));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Config {
        Config {
            sim_cores: vec![48, 96],
            ..Config::default()
        }
    }

    #[test]
    fn fig6_weak_dsarray_wins_big() {
        let cfg = small_cfg();
        let s = fig6_weak(&cfg).unwrap();
        assert_eq!(s.points.len(), 2);
        for p in &s.points {
            let d = p.dataset_s.unwrap();
            assert!(
                d > 20.0 * p.dsarray_s,
                "expected >95% reduction at {} cores: {d} vs {}",
                p.cores,
                p.dsarray_s
            );
            // Task counts: N²+N vs N.
            assert_eq!(p.tasks.0, (p.cores * p.cores + p.cores) as u64);
            assert_eq!(p.tasks.1, p.cores as u64);
        }
    }

    #[test]
    fn fig8_dsarray_wins_and_gap_grows() {
        let cfg = Config {
            sim_cores: vec![48, 192],
            ..Config::default()
        };
        let s = fig8_shuffle(&cfg).unwrap();
        let r0 = s.points[0].dataset_s.unwrap() / s.points[0].dsarray_s;
        let r1 = s.points[1].dataset_s.unwrap() / s.points[1].dsarray_s;
        assert!(r0 > 1.0, "ds-array should win at 48 cores ({r0})");
        assert!(r1 >= r0 * 0.8, "gap should not collapse ({r0} -> {r1})");
    }

    #[test]
    fn task_count_formulas_hold() {
        let cfg = small_cfg();
        let rows = task_count_table(&cfg, &[6, 10]).unwrap();
        for (n, d_tr, a_tr, d_sh, a_sh, a_shn) in rows {
            assert_eq!(d_tr, (n * n + n) as u64, "dataset transpose N²+N");
            assert_eq!(a_tr, n as u64, "dsarray transpose N");
            let s = 4; // rows per subset
            assert_eq!(d_sh, (n * n.min(s) + n) as u64, "dataset shuffle");
            assert_eq!(a_sh, 2 * n as u64, "dsarray shuffle 2N");
            assert_eq!(a_shn, (n * n + n) as u64, "no-collections N²+N");
        }
    }
}
