//! Table/series reporting in the paper's format: execution time and
//! speedup per core count, Datasets vs ds-arrays — plus machine-readable
//! JSON emitters for runtime metrics and series.

use std::fmt::Write as _;

use crate::tasking::Metrics;

/// One core-count measurement for one structure.
#[derive(Clone, Debug)]
pub struct Point {
    pub cores: usize,
    pub dataset_s: Option<f64>,
    pub dsarray_s: f64,
    /// Tasks executed (dataset, dsarray).
    pub tasks: (u64, u64),
}

/// A figure reproduction: a series of points plus metadata.
#[derive(Clone, Debug)]
pub struct Series {
    pub title: String,
    pub points: Vec<Point>,
    /// Baseline (first Dataset time) for speedup, per the paper's
    /// "Dataset execution with 48 cores as baseline".
    pub baseline_s: Option<f64>,
}

impl Series {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            points: Vec::new(),
            baseline_s: None,
        }
    }

    pub fn push(&mut self, p: Point) {
        if self.baseline_s.is_none() {
            self.baseline_s = p.dataset_s;
        }
        self.points.push(p);
    }

    /// Largest time reduction across points (the paper's "up to X %").
    pub fn max_reduction_pct(&self) -> Option<f64> {
        self.points
            .iter()
            .filter_map(|p| p.dataset_s.map(|d| 100.0 * (1.0 - self.fin(p.dsarray_s) / d)))
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    fn fin(&self, v: f64) -> f64 {
        if v.is_finite() {
            v
        } else {
            f64::MAX
        }
    }

    /// Render the paper-style table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let _ = writeln!(
            out,
            "{:>6} | {:>14} | {:>14} | {:>9} | {:>10} | {:>10}",
            "cores", "Dataset (s)", "ds-array (s)", "reduction", "D tasks", "A tasks"
        );
        let _ = writeln!(out, "{}", "-".repeat(78));
        for p in &self.points {
            let ds = p
                .dataset_s
                .map(|v| format!("{v:14.2}"))
                .unwrap_or_else(|| format!("{:>14}", "OOM/n.a."));
            let red = p
                .dataset_s
                .map(|d| format!("{:8.1}%", 100.0 * (1.0 - p.dsarray_s / d)))
                .unwrap_or_else(|| format!("{:>9}", "-"));
            let _ = writeln!(
                out,
                "{:>6} | {} | {:14.2} | {} | {:>10} | {:>10}",
                p.cores, ds, p.dsarray_s, red, p.tasks.0, p.tasks.1
            );
        }
        if let (Some(base), true) = (self.baseline_s, !self.points.is_empty()) {
            let _ = writeln!(out, "speedup vs Dataset@{} cores baseline:", self.points[0].cores);
            let _ = write!(out, "  Dataset : ");
            for p in &self.points {
                match p.dataset_s {
                    Some(d) => {
                        let _ = write!(out, "{:>8.2}", base / d);
                    }
                    None => {
                        let _ = write!(out, "{:>8}", "-");
                    }
                }
            }
            let _ = writeln!(out);
            let _ = write!(out, "  ds-array: ");
            for p in &self.points {
                let _ = write!(out, "{:>8.2}", base / p.dsarray_s);
            }
            let _ = writeln!(out);
        }
        out
    }
}

/// Render runtime [`Metrics`] as a single-line JSON object, including the
/// residency counters added with refcount reclamation
/// (`peak_resident_bytes`, `blocks_evicted`), the fusion counters
/// (`tasks_fused`, `inplace_hits`, `bytes_allocated`), the out-of-core
/// counters (`blocks_spilled`, `blocks_faulted`, `spill_bytes`), the
/// cluster-backend counters (`bytes_on_wire`, `remote_transfers`,
/// `locality_hits`), the kernel-layer counters (`simd_kernel_hits`,
/// `subtasks_spawned`), the plan-layer counters (`tasks_submitted` — an
/// alias of `total_tasks` the parity tests compare across optimizer
/// levels — plus `tasks_deduped` and `blocks_prereleased`), the
/// fault-recovery counters (`workers_lost`,
/// `blocks_recovered`, `tasks_replayed`, `recovery_ms`), the
/// elasticity counters (`workers_joined`, `workers_drained`,
/// `tasks_speculated`, plus the per-slot `tasks_by_worker` array), and the
/// serving counters (`requests_served`, `batches_coalesced`,
/// `requests_shed`, plus the log₂ `predict_latency_us_hist` array).
pub fn metrics_json(m: &Metrics) -> String {
    let mut out = String::from("{");
    let _ = write!(out, "\"total_tasks\":{}", m.total_tasks());
    let _ = write!(out, ",\"read_edges\":{}", m.read_edges);
    let _ = write!(out, ",\"write_edges\":{}", m.write_edges);
    let _ = write!(out, ",\"read_bytes\":{:.0}", m.read_bytes);
    let _ = write!(out, ",\"write_bytes\":{:.0}", m.write_bytes);
    let _ = write!(out, ",\"resident_bytes\":{}", m.resident_bytes);
    let _ = write!(out, ",\"peak_resident_bytes\":{}", m.peak_resident_bytes);
    let _ = write!(out, ",\"blocks_evicted\":{}", m.blocks_evicted);
    let _ = write!(out, ",\"tasks_fused\":{}", m.tasks_fused);
    let _ = write!(out, ",\"inplace_hits\":{}", m.inplace_hits);
    let _ = write!(out, ",\"bytes_allocated\":{}", m.bytes_allocated);
    let _ = write!(out, ",\"blocks_spilled\":{}", m.blocks_spilled);
    let _ = write!(out, ",\"blocks_faulted\":{}", m.blocks_faulted);
    let _ = write!(out, ",\"spill_bytes\":{}", m.spill_bytes);
    let _ = write!(out, ",\"bytes_on_wire\":{}", m.bytes_on_wire);
    let _ = write!(out, ",\"remote_transfers\":{}", m.remote_transfers);
    let _ = write!(out, ",\"locality_hits\":{}", m.locality_hits);
    let _ = write!(out, ",\"simd_kernel_hits\":{}", m.simd_kernel_hits);
    let _ = write!(out, ",\"tasks_submitted\":{}", m.total_tasks());
    let _ = write!(out, ",\"tasks_deduped\":{}", m.tasks_deduped);
    let _ = write!(out, ",\"blocks_prereleased\":{}", m.blocks_prereleased);
    let _ = write!(out, ",\"subtasks_spawned\":{}", m.subtasks_spawned);
    let _ = write!(out, ",\"workers_lost\":{}", m.workers_lost);
    let _ = write!(out, ",\"blocks_recovered\":{}", m.blocks_recovered);
    let _ = write!(out, ",\"tasks_replayed\":{}", m.tasks_replayed);
    let _ = write!(out, ",\"recovery_ms\":{}", m.recovery_ms);
    let _ = write!(out, ",\"workers_joined\":{}", m.workers_joined);
    let _ = write!(out, ",\"workers_drained\":{}", m.workers_drained);
    let _ = write!(out, ",\"tasks_speculated\":{}", m.tasks_speculated);
    let _ = write!(out, ",\"requests_served\":{}", m.requests_served);
    let _ = write!(out, ",\"batches_coalesced\":{}", m.batches_coalesced);
    let _ = write!(out, ",\"requests_shed\":{}", m.requests_shed);
    out.push_str(",\"tasks_by_worker\":[");
    for (i, v) in m.tasks_by_worker.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
    out.push_str(",\"predict_latency_us_hist\":[");
    for (i, v) in m.predict_latency_us_hist.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
    out.push_str(",\"tasks_by_op\":{");
    for (i, (k, v)) in m.tasks_by_op.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{k}\":{v}");
    }
    out.push_str("}}");
    out
}

/// Minimal JSON string escaping (UTF-8 passes through unescaped).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

impl Series {
    /// Machine-readable form of the series (one JSON object).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        let _ = write!(out, "\"title\":\"{}\"", json_escape(&self.title));
        match self.baseline_s {
            Some(b) => {
                let _ = write!(out, ",\"baseline_s\":{b}");
            }
            None => out.push_str(",\"baseline_s\":null"),
        }
        out.push_str(",\"points\":[");
        for (i, p) in self.points.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{{\"cores\":{}", p.cores);
            match p.dataset_s {
                Some(d) => {
                    let _ = write!(out, ",\"dataset_s\":{d}");
                }
                None => out.push_str(",\"dataset_s\":null"),
            }
            let _ = write!(
                out,
                ",\"dsarray_s\":{},\"dataset_tasks\":{},\"dsarray_tasks\":{}}}",
                p.dsarray_s, p.tasks.0, p.tasks.1
            );
        }
        out.push_str("]}");
        out
    }
}

/// Machine-readable form of hot-path bench rows (`(name, secs, note)`),
/// paired with a metrics snapshot — the `BENCH_hotpath.json` artifact CI
/// tracks across PRs.
pub fn bench_rows_json(rows: &[(String, f64, String)], metrics: &Metrics) -> String {
    let mut out = String::from("{\"rows\":[");
    for (i, (name, secs, note)) in rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let s = if secs.is_finite() {
            format!("{secs}")
        } else {
            "null".to_string()
        };
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"secs\":{},\"note\":\"{}\"}}",
            json_escape(name),
            s,
            json_escape(note)
        );
    }
    out.push_str("],\"metrics\":");
    out.push_str(&metrics_json(metrics));
    out.push('}');
    out
}

/// Simple named-value table for ablations / single-run reports.
pub fn kv_table(title: &str, rows: &[(String, String)]) -> String {
    let w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(8).max(8);
    let mut out = String::new();
    let _ = writeln!(out, "\n== {title} ==");
    for (k, v) in rows {
        let _ = writeln!(out, "{k:>w$} : {v}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_renders_and_computes_reduction() {
        let mut s = Series::new("fig X");
        s.push(Point {
            cores: 48,
            dataset_s: Some(1000.0),
            dsarray_s: 10.0,
            tasks: (100, 10),
        });
        s.push(Point {
            cores: 96,
            dataset_s: None,
            dsarray_s: 5.0,
            tasks: (0, 10),
        });
        let r = s.render();
        assert!(r.contains("fig X"));
        assert!(r.contains("OOM/n.a."));
        assert!(r.contains("99.0%"));
        assert_eq!(s.baseline_s, Some(1000.0));
        assert!((s.max_reduction_pct().unwrap() - 99.0).abs() < 1e-9);
    }

    #[test]
    fn kv_table_aligns() {
        let t = kv_table("t", &[("a".into(), "1".into()), ("long_key".into(), "2".into())]);
        assert!(t.contains("long_key : 2"));
    }

    #[test]
    fn metrics_json_parses_and_surfaces_residency() {
        let mut m = Metrics::default();
        m.record_submit("op.a", 2, 1, 64.0, 32.0);
        m.record_resident(4096);
        m.record_evicted(1024);
        m.record_fused(4);
        m.record_inplace_grant(256);
        m.record_allocated(512, 256);
        m.record_spilled(512, 512);
        m.record_faulted(512);
        m.record_wire(2048);
        m.record_locality(5, 2);
        m.simd_kernel_hits = 7;
        m.record_subtasks(4);
        m.record_recovery(5, 3, 2);
        m.record_join();
        m.record_drain();
        m.record_speculated();
        m.record_task_on_worker(0);
        m.record_task_on_worker(1);
        m.record_task_on_worker(1);
        m.requests_served = 9;
        m.batches_coalesced = 2;
        m.requests_shed = 1;
        m.predict_latency_us_hist = vec![0, 3, 6];
        let s = metrics_json(&m);
        let v = crate::util::json::parse(&s).unwrap();
        assert_eq!(v.get("total_tasks").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("peak_resident_bytes").unwrap().as_usize(), Some(4096));
        assert_eq!(v.get("resident_bytes").unwrap().as_usize(), Some(2816));
        assert_eq!(v.get("blocks_evicted").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("tasks_fused").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("inplace_hits").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("bytes_allocated").unwrap().as_usize(), Some(256));
        assert_eq!(v.get("blocks_spilled").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("blocks_faulted").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("spill_bytes").unwrap().as_usize(), Some(512));
        assert_eq!(v.get("bytes_on_wire").unwrap().as_usize(), Some(2048));
        assert_eq!(v.get("remote_transfers").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("locality_hits").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("simd_kernel_hits").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("subtasks_spawned").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("workers_lost").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("blocks_recovered").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("tasks_replayed").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("recovery_ms").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("workers_joined").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("workers_drained").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("tasks_speculated").unwrap().as_usize(), Some(1));
        let by_worker = v.get("tasks_by_worker").unwrap().as_arr().unwrap();
        assert_eq!(by_worker.len(), 2);
        assert_eq!(by_worker[0].as_usize(), Some(1));
        assert_eq!(by_worker[1].as_usize(), Some(2));
        assert_eq!(v.get("requests_served").unwrap().as_usize(), Some(9));
        assert_eq!(v.get("batches_coalesced").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("requests_shed").unwrap().as_usize(), Some(1));
        let hist = v.get("predict_latency_us_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 3);
        assert_eq!(hist[2].as_usize(), Some(6));
        assert_eq!(
            v.get("tasks_by_op").unwrap().get("op.a").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn bench_rows_json_parses() {
        let rows = vec![
            ("fused chain".to_string(), 0.0125, "3 ops".to_string()),
            ("pjrt".to_string(), f64::NAN, "artifacts not built".to_string()),
        ];
        let s = bench_rows_json(&rows, &Metrics::default());
        let v = crate::util::json::parse(&s).unwrap();
        let r = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].get("name").unwrap().as_str(), Some("fused chain"));
        assert_eq!(r[1].get("secs"), Some(&crate::util::json::Json::Null));
        assert_eq!(
            v.get("metrics").unwrap().get("total_tasks").unwrap().as_usize(),
            Some(0)
        );
    }

    #[test]
    fn series_json_parses() {
        let mut s = Series::new("fig J");
        s.push(Point {
            cores: 48,
            dataset_s: Some(10.0),
            dsarray_s: 1.0,
            tasks: (100, 10),
        });
        s.push(Point {
            cores: 96,
            dataset_s: None,
            dsarray_s: 0.5,
            tasks: (0, 10),
        });
        let v = crate::util::json::parse(&s.to_json()).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("fig J"));
        let pts = v.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("dataset_s"), Some(&crate::util::json::Json::Null));
        assert_eq!(pts[0].get("dsarray_tasks").unwrap().as_usize(), Some(10));
    }
}
