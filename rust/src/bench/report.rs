//! Table/series reporting in the paper's format: execution time and
//! speedup per core count, Datasets vs ds-arrays.

use std::fmt::Write as _;

/// One core-count measurement for one structure.
#[derive(Clone, Debug)]
pub struct Point {
    pub cores: usize,
    pub dataset_s: Option<f64>,
    pub dsarray_s: f64,
    /// Tasks executed (dataset, dsarray).
    pub tasks: (u64, u64),
}

/// A figure reproduction: a series of points plus metadata.
#[derive(Clone, Debug)]
pub struct Series {
    pub title: String,
    pub points: Vec<Point>,
    /// Baseline (first Dataset time) for speedup, per the paper's
    /// "Dataset execution with 48 cores as baseline".
    pub baseline_s: Option<f64>,
}

impl Series {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            points: Vec::new(),
            baseline_s: None,
        }
    }

    pub fn push(&mut self, p: Point) {
        if self.baseline_s.is_none() {
            self.baseline_s = p.dataset_s;
        }
        self.points.push(p);
    }

    /// Largest time reduction across points (the paper's "up to X %").
    pub fn max_reduction_pct(&self) -> Option<f64> {
        self.points
            .iter()
            .filter_map(|p| p.dataset_s.map(|d| 100.0 * (1.0 - self.fin(p.dsarray_s) / d)))
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    fn fin(&self, v: f64) -> f64 {
        if v.is_finite() {
            v
        } else {
            f64::MAX
        }
    }

    /// Render the paper-style table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let _ = writeln!(
            out,
            "{:>6} | {:>14} | {:>14} | {:>9} | {:>10} | {:>10}",
            "cores", "Dataset (s)", "ds-array (s)", "reduction", "D tasks", "A tasks"
        );
        let _ = writeln!(out, "{}", "-".repeat(78));
        for p in &self.points {
            let ds = p
                .dataset_s
                .map(|v| format!("{v:14.2}"))
                .unwrap_or_else(|| format!("{:>14}", "OOM/n.a."));
            let red = p
                .dataset_s
                .map(|d| format!("{:8.1}%", 100.0 * (1.0 - p.dsarray_s / d)))
                .unwrap_or_else(|| format!("{:>9}", "-"));
            let _ = writeln!(
                out,
                "{:>6} | {} | {:14.2} | {} | {:>10} | {:>10}",
                p.cores, ds, p.dsarray_s, red, p.tasks.0, p.tasks.1
            );
        }
        if let (Some(base), true) = (self.baseline_s, !self.points.is_empty()) {
            let _ = writeln!(out, "speedup vs Dataset@{} cores baseline:", self.points[0].cores);
            let _ = write!(out, "  Dataset : ");
            for p in &self.points {
                match p.dataset_s {
                    Some(d) => {
                        let _ = write!(out, "{:>8.2}", base / d);
                    }
                    None => {
                        let _ = write!(out, "{:>8}", "-");
                    }
                }
            }
            let _ = writeln!(out);
            let _ = write!(out, "  ds-array: ");
            for p in &self.points {
                let _ = write!(out, "{:>8.2}", base / p.dsarray_s);
            }
            let _ = writeln!(out);
        }
        out
    }
}

/// Simple named-value table for ablations / single-run reports.
pub fn kv_table(title: &str, rows: &[(String, String)]) -> String {
    let w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(8).max(8);
    let mut out = String::new();
    let _ = writeln!(out, "\n== {title} ==");
    for (k, v) in rows {
        let _ = writeln!(out, "{k:>w$} : {v}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_renders_and_computes_reduction() {
        let mut s = Series::new("fig X");
        s.push(Point {
            cores: 48,
            dataset_s: Some(1000.0),
            dsarray_s: 10.0,
            tasks: (100, 10),
        });
        s.push(Point {
            cores: 96,
            dataset_s: None,
            dsarray_s: 5.0,
            tasks: (0, 10),
        });
        let r = s.render();
        assert!(r.contains("fig X"));
        assert!(r.contains("OOM/n.a."));
        assert!(r.contains("99.0%"));
        assert_eq!(s.baseline_s, Some(1000.0));
        assert!((s.max_reduction_pct().unwrap() - 99.0).abs() < 1e-9);
    }

    #[test]
    fn kv_table_aligns() {
        let t = kv_table("t", &[("a".into(), "1".into()), ("long_key".into(), "2".into())]);
        assert!(t.contains("long_key : 2"));
    }
}
