//! Benchmark harness: workload generators, paper-figure experiment drivers,
//! and table/series reporting. Every table and figure of the paper's §5 has
//! a driver here and a bench binary under `rust/benches/` (DESIGN.md §6).

pub mod experiments;
pub mod report;
pub mod workloads;
