//! Deferred blocked gemm with graftable elementwise epilogues.
//!
//! At [`Level::Full`], `matmul`/`tn_matmul` do not submit tasks: they
//! return a ds-array carrying a [`GemmSpec`] — the pending multiply's
//! operand grids plus an (initially empty) epilogue chain. Unary
//! elementwise ops applied to that pending result extend the chain instead
//! of going through the expression engine, so when the gemm is forced each
//! output tile runs gemm-accumulate and then the whole epilogue through the
//! kernel vtable's `epilogue` entry while the tile is still cache-hot — one
//! task where the eager path paid one gemm task plus one fused-elementwise
//! task per block (plus a full extra traversal of the output).
//!
//! The spec doubles as the CSE identity for the multiply: [`GemmSpec::key`]
//! hashes kind, operand grids, input [`DataId`]s, and the epilogue chain,
//! so a repeated Gram matrix or `XᵀY` inside an estimator iteration — same
//! single-assignment inputs, same epilogue — collapses to a memo hit.
//!
//! Force-time semantics (memoization, early operand release, the credit a
//! later `Drop` consumes) mirror `dsarray/expr.rs`'s [`ExprState`] exactly;
//! see `DsArray::force_gemm` in `dsarray/linalg.rs` for the lowering.
//!
//! [`Level::Full`]: super::Level
//! [`ExprState`]: crate::dsarray::DsArray
//! [`DataId`]: crate::tasking::DataId

use std::sync::{Arc, Mutex};

use crate::kernels::UnaryKind;
use crate::tasking::Future;

use super::PlanKey;
use crate::dsarray::DsArray;

/// Which blocked multiply a [`GemmSpec`] lowers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    /// `A @ B` — `dsarray.matmul.block` shapes: output block (i, j) reads
    /// block-row i of A and block-col j of B.
    Nn,
    /// `Aᵀ @ B` without materializing the transpose —
    /// `dsarray.tn_matmul.block` shapes: output block (i, j) reads
    /// block-col i of A and block-col j of B.
    Tn,
}

/// Mutable shared state of one pending gemm (shared by clones of the
/// deferred array) — the deferred-gemm twin of `ExprState`.
#[derive(Default)]
pub struct GemmState {
    /// Memoized materialization: filled by the first force, reused by later
    /// consumers so the multiply executes once.
    pub forced: Option<DsArray>,
    /// Set when force released this spec's operand handle references early
    /// (dead-block pre-release); exactly one subsequent `Drop` consumes the
    /// credit instead of releasing again.
    pub release_credit: bool,
}

/// A pending blocked multiply plus its grafted elementwise epilogue,
/// carried by a deferred [`DsArray`].
#[derive(Clone)]
pub struct GemmSpec {
    pub kind: GemmKind,
    /// Row-major grid of the left operand's block futures.
    pub a: Vec<Future>,
    pub a_grid: (usize, usize),
    /// Row-major grid of the right operand's block futures.
    pub b: Vec<Future>,
    pub b_grid: (usize, usize),
    /// Logical contraction length (for cost hints — `A.cols` for Nn,
    /// `A.rows` for Tn).
    pub k_total: usize,
    /// Logical shape of the result.
    pub out_shape: (usize, usize),
    /// Regular block shape of the result.
    pub out_block_shape: (usize, usize),
    /// Unary elementwise ops grafted onto every output tile, applied in
    /// order while the tile is cache-hot.
    pub epilogue: Vec<UnaryKind>,
    pub state: Arc<Mutex<GemmState>>,
}

impl GemmSpec {
    /// Output grid dimensions (block rows, block cols).
    pub fn out_grid(&self) -> (usize, usize) {
        match self.kind {
            GemmKind::Nn => (self.a_grid.0, self.b_grid.1),
            GemmKind::Tn => (self.a_grid.1, self.b_grid.1),
        }
    }

    /// Tasks this plan submits when forced — one per output block (the same
    /// count the eager path paid for the multiply alone).
    pub fn n_tasks(&self) -> usize {
        let (gr, gc) = self.out_grid();
        gr * gc
    }

    /// Every operand future, A grid then B grid — the references this spec
    /// owns (retained at construction, released early at force or by the
    /// owning array's `Drop`). A Gram matrix lists its single operand
    /// twice; the double retain/release is balanced.
    pub fn operands(&self) -> Vec<Future> {
        let mut v = Vec::with_capacity(self.a.len() + self.b.len());
        v.extend_from_slice(&self.a);
        v.extend_from_slice(&self.b);
        v
    }

    /// Canonical CSE key: kind, operand grids + ids, epilogue chain. Input
    /// ids are single-assignment, so equal keys mean the forced plans would
    /// compute identical values.
    pub fn key(&self) -> u128 {
        let name = match self.kind {
            GemmKind::Nn => "plan.gemm.nn",
            GemmKind::Tn => "plan.gemm.tn",
        };
        let mut k = PlanKey::op(name)
            .u64(self.a_grid.0 as u64)
            .u64(self.a_grid.1 as u64)
            .ids(&self.a)
            .u64(self.b_grid.0 as u64)
            .u64(self.b_grid.1 as u64)
            .ids(&self.b);
        for &op in &self.epilogue {
            k = k.unary(op);
        }
        k.finish()
    }

    /// Task name the lowering uses: the legacy block-gemm names when no
    /// epilogue is grafted (so `Level::Cse` and memo-miss `Full` runs keep
    /// the pre-planner task streams observable), `.fused` variants once an
    /// epilogue rides along.
    pub fn task_name(&self) -> &'static str {
        match (self.kind, self.epilogue.is_empty()) {
            (GemmKind::Nn, true) => "dsarray.matmul.block",
            (GemmKind::Nn, false) => "dsarray.matmul.fused",
            (GemmKind::Tn, true) => "dsarray.tn_matmul.block",
            (GemmKind::Tn, false) => "dsarray.tn_matmul.fused",
        }
    }

    /// One-line human rendering for [`DsArray::explain`].
    pub fn describe(&self) -> String {
        let (gr, gc) = self.out_grid();
        let op = match self.kind {
            GemmKind::Nn => "A@B",
            GemmKind::Tn => "Aᵀ@B",
        };
        let mut s = format!(
            "gemm {op}: {}x{} · {}x{} grids → {gr}x{gc} ({} tasks, k={})",
            self.a_grid.0,
            self.a_grid.1,
            self.b_grid.0,
            self.b_grid.1,
            self.n_tasks(),
            self.k_total,
        );
        if !self.epilogue.is_empty() {
            s.push_str(" epilogue:");
            for op in &self.epilogue {
                s.push_str(&format!(" {op:?}"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::BlockMeta;

    fn fut(id: u32) -> Future {
        Future {
            id,
            meta: BlockMeta::dense(2, 2),
        }
    }

    fn spec(kind: GemmKind, a_ids: &[u32], b_ids: &[u32], epilogue: Vec<UnaryKind>) -> GemmSpec {
        GemmSpec {
            kind,
            a: a_ids.iter().map(|&i| fut(i)).collect(),
            a_grid: (2, 2),
            b: b_ids.iter().map(|&i| fut(i)).collect(),
            b_grid: (2, 2),
            k_total: 4,
            out_shape: (4, 4),
            out_block_shape: (2, 2),
            epilogue,
            state: Arc::default(),
        }
    }

    #[test]
    fn geometry_and_task_names() {
        let nn = spec(GemmKind::Nn, &[1, 2, 3, 4], &[5, 6, 7, 8], vec![]);
        assert_eq!(nn.out_grid(), (2, 2));
        assert_eq!(nn.n_tasks(), 4);
        assert_eq!(nn.task_name(), "dsarray.matmul.block");
        assert_eq!(nn.operands().len(), 8);

        let tn = spec(
            GemmKind::Tn,
            &[1, 2, 3, 4],
            &[5, 6, 7, 8],
            vec![UnaryKind::Relu],
        );
        assert_eq!(tn.task_name(), "dsarray.tn_matmul.fused");
        assert!(tn.describe().contains("Relu"));
        assert!(nn.describe().contains("4 tasks"));
    }

    #[test]
    fn keys_separate_kind_ids_and_epilogue() {
        let base = spec(GemmKind::Nn, &[1, 2, 3, 4], &[5, 6, 7, 8], vec![]);
        let same = spec(GemmKind::Nn, &[1, 2, 3, 4], &[5, 6, 7, 8], vec![]);
        assert_eq!(base.key(), same.key(), "structurally identical plans alias");

        let tn = spec(GemmKind::Tn, &[1, 2, 3, 4], &[5, 6, 7, 8], vec![]);
        assert_ne!(base.key(), tn.key());

        let other_ids = spec(GemmKind::Nn, &[1, 2, 3, 9], &[5, 6, 7, 8], vec![]);
        assert_ne!(base.key(), other_ids.key());

        let scaled = spec(
            GemmKind::Nn,
            &[1, 2, 3, 4],
            &[5, 6, 7, 8],
            vec![UnaryKind::MulScalar(0.5)],
        );
        assert_ne!(base.key(), scaled.key());
        let scaled2 = spec(
            GemmKind::Nn,
            &[1, 2, 3, 4],
            &[5, 6, 7, 8],
            vec![UnaryKind::MulScalar(0.25)],
        );
        assert_ne!(scaled.key(), scaled2.key(), "epilogue params key distinctly");

        // A swapped grid split over the same flat id list keys distinctly.
        let mut tall = spec(GemmKind::Nn, &[1, 2, 3, 4], &[5, 6, 7, 8], vec![]);
        tall.a_grid = (4, 1);
        assert_ne!(base.key(), tall.key());
    }
}
