//! Plan layer: the graph-level query optimizer between the dsarray op
//! layer and task submission (ROADMAP item 5).
//!
//! PR 3's fused elementwise engine optimizes per-block chains; this module
//! optimizes across whole pending subgraphs, in three moves:
//!
//! * **Common-subexpression elimination.** Structurally identical pending
//!   subgraphs — same op, same input [`DataId`]s, same parameters — within
//!   a `force()`/`collect` epoch collapse to one task set. Data ids are
//!   single-assignment (PyCOMPSs renaming made explicit), so "same ids"
//!   means *the same values, forever*: a memo hit can never observe a
//!   mutated input, and memo entries never go stale. Epochs are therefore a
//!   garbage-collection generation, not a correctness boundary: every
//!   `collect`/`barrier` bumps the epoch, and entries untouched for
//!   [`CSE_MAX_AGE`] generations (or past the [`CSE_CAPACITY`] FIFO) are
//!   evicted and their memo references released. The memo holds one
//!   application handle reference per memoized block, which also keeps the
//!   in-place execution engine from ever mutating a memoized output (an
//!   extra handle ref forbids exclusive grants).
//!
//! * **Epilogue grafting.** At [`Level::Full`], `matmul`/`tn_matmul` return
//!   a *pending* gemm ([`GemmSpec`]) instead of submitting tasks; unary
//!   elementwise ops applied to the pending result extend its epilogue
//!   chain. At force time each output tile runs gemm-accumulate and then
//!   the whole chain through the `epilogue` kernel-vtable entry — while the
//!   tile is cache-hot — in one task. Bit-identicality is preserved because
//!   elementwise unary ops commute with traversal order (a per-element fold
//!   equals sequential full passes) and the vectorized epilogue is
//!   property-tested against the scalar fold.
//!
//! * **Dead-block pre-release.** A deferred gemm retains its operand blocks
//!   like any container; at force time it hands them to
//!   `submit_batch_releasing`, dropping its references in the same
//!   scheduler critical section that registers the reads. Operands whose
//!   last consumer is the plan itself are reclaimed as soon as the gemm
//!   tasks finish — the spill tier sees pressure later.
//!
//! The [`RuntimeBuilder`] (`Runtime::builder()`) is the single public
//! construction path that carries the optimizer knob; legacy constructors
//! default to [`Level::Off`], which preserves the pre-planner task streams
//! exactly.
//!
//! [`DataId`]: crate::tasking::DataId

pub mod builder;
pub mod gemm;

pub use builder::RuntimeBuilder;
pub use gemm::{GemmKind, GemmSpec, GemmState};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::kernels::UnaryKind;
use crate::tasking::{DataId, Future};

/// Optimization level of the plan layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Level {
    /// No planning: every op submits the exact task stream it submitted
    /// before the plan layer existed. The default for the legacy
    /// constructors (`Runtime::local` and friends), so exact-task-count
    /// tests and recorded baselines stay valid.
    #[default]
    Off,
    /// Common-subexpression elimination only — repeated subgraphs dedupe,
    /// but every op still lowers to the legacy task shapes.
    Cse,
    /// CSE + gemm deferral with epilogue grafting + reduce-tail composition
    /// in the estimator loops + dead-block pre-release. The default for
    /// [`RuntimeBuilder`].
    Full,
}

impl Level {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Level::Off),
            "cse" => Ok(Level::Cse),
            "full" => Ok(Level::Full),
            other => bail!("unknown optimizer level `{other}` (expected off|cse|full)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Cse => "cse",
            Level::Full => "full",
        }
    }
}

/// Memoized subgraphs the CSE table holds before FIFO eviction kicks in.
pub const CSE_CAPACITY: usize = 256;

/// Epoch generations an entry survives untouched before the lazy sweep
/// releases it (a PCA `fit` followed by `score` spans two collect epochs;
/// eight gives cross-call reuse plenty of slack without pinning working
/// sets forever).
pub const CSE_MAX_AGE: u64 = 8;

struct MemoEntry {
    outputs: Vec<Future>,
    /// Epoch of last insert or hit — the GC generation stamp.
    epoch: u64,
}

#[derive(Default)]
struct CseMemo {
    entries: HashMap<u128, MemoEntry>,
    /// Insertion-order FIFO for capacity eviction.
    order: VecDeque<u128>,
}

/// Per-runtime planner: optimization level, the CSE memo table, and the
/// plan-layer counters folded into [`crate::tasking::Metrics`] snapshots.
/// Shared by `Runtime` clones behind an `Arc`.
pub struct Planner {
    level: Level,
    epoch: AtomicU64,
    memo: Mutex<CseMemo>,
    tasks_deduped: AtomicU64,
    blocks_prereleased: AtomicU64,
}

impl Planner {
    pub fn new(level: Level) -> Self {
        Self {
            level,
            epoch: AtomicU64::new(0),
            memo: Mutex::new(CseMemo::default()),
            tasks_deduped: AtomicU64::new(0),
            blocks_prereleased: AtomicU64::new(0),
        }
    }

    pub fn level(&self) -> Level {
        self.level
    }

    /// Whether subgraph memoization is on (`Cse` and `Full`).
    pub fn cse_enabled(&self) -> bool {
        self.level != Level::Off
    }

    /// Whether structural rewrites are on (gemm deferral, epilogue
    /// grafting, reduce-tail composition) — `Full` only.
    pub fn fuse_enabled(&self) -> bool {
        self.level == Level::Full
    }

    /// Current collect/barrier epoch (the memo's GC generation).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Memoized outputs for `key`, if present. A hit refreshes the entry's
    /// generation stamp and credits `tasks_avoided` to the dedup counter.
    /// Always `None` at [`Level::Off`].
    pub fn lookup(&self, key: u128, tasks_avoided: u64) -> Option<Vec<Future>> {
        if !self.cse_enabled() {
            return None;
        }
        let now = self.epoch();
        let mut memo = self.memo.lock().unwrap_or_else(|e| e.into_inner());
        let entry = memo.entries.get_mut(&key)?;
        entry.epoch = now;
        let outs = entry.outputs.clone();
        drop(memo);
        self.tasks_deduped.fetch_add(tasks_avoided, Ordering::Relaxed);
        Some(outs)
    }

    /// Insert `outputs` under `key`. The caller must already hold one
    /// application handle reference per output *for the memo* (retained
    /// before calling); the returned futures are entries this insert
    /// displaced — capacity FIFO or age sweep — whose memo references the
    /// caller must release. No-op (returning `outputs` back for release)
    /// at [`Level::Off`].
    #[must_use = "displaced memo entries carry handle references that must be released"]
    pub fn record(&self, key: u128, outputs: Vec<Future>) -> Vec<Future> {
        if !self.cse_enabled() {
            return outputs;
        }
        let now = self.epoch();
        let mut memo = self.memo.lock().unwrap_or_else(|e| e.into_inner());
        let mut displaced = Vec::new();
        if let Some(old) = memo.entries.insert(
            key,
            MemoEntry {
                outputs,
                epoch: now,
            },
        ) {
            // Two threads raced on the same subgraph: keep the newer tasks,
            // hand the older entry's references back for release.
            displaced.extend(old.outputs);
        } else {
            memo.order.push_back(key);
        }
        while memo.entries.len() > CSE_CAPACITY {
            let Some(oldest) = memo.order.pop_front() else {
                break;
            };
            if let Some(e) = memo.entries.remove(&oldest) {
                displaced.extend(e.outputs);
            }
        }
        displaced
    }

    /// Advance the collect/barrier epoch and sweep entries untouched for
    /// [`CSE_MAX_AGE`] generations. Returns the swept entries' futures so
    /// the caller can release the memo's handle references.
    #[must_use = "swept memo entries carry handle references that must be released"]
    pub fn bump_epoch(&self) -> Vec<Future> {
        let now = self.epoch.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.cse_enabled() {
            return Vec::new();
        }
        let mut memo = self.memo.lock().unwrap_or_else(|e| e.into_inner());
        let mut swept = Vec::new();
        memo.entries.retain(|_, e| {
            if e.epoch + CSE_MAX_AGE < now {
                swept.append(&mut e.outputs);
                false
            } else {
                true
            }
        });
        if !swept.is_empty() {
            let entries = &memo.entries;
            memo.order.retain(|k| entries.contains_key(k));
        }
        swept
    }

    /// Credit `n` operand blocks released inside a plan's own scheduler
    /// critical section (dead-block pre-release).
    pub fn note_prereleased(&self, n: u64) {
        self.blocks_prereleased.fetch_add(n, Ordering::Relaxed);
    }

    /// Tasks avoided by CSE memo hits so far.
    pub fn tasks_deduped(&self) -> u64 {
        self.tasks_deduped.load(Ordering::Relaxed)
    }

    /// Blocks pre-released by plan-layer early handle drops so far.
    pub fn blocks_prereleased(&self) -> u64 {
        self.blocks_prereleased.load(Ordering::Relaxed)
    }

    /// Live memoized subgraphs (test/debug visibility).
    pub fn memo_len(&self) -> usize {
        self.memo.lock().unwrap_or_else(|e| e.into_inner()).entries.len()
    }
}

// ---------------------------------------------------------------------------
// Canonical subgraph keys.
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;
/// Second-lane seed so the two halves of the 128-bit key diverge — a
/// collision must defeat both lanes at once.
const LANE2_SEED: u64 = 0x9e3779b97f4a7c15;

/// Canonical hash of a pending subgraph: op name, input [`DataId`]s, and
/// every parameter that shapes the result. Two independent FNV-1a lanes
/// form a 128-bit key, so the memo never has to compare full key material.
/// Ids are single-assignment, which is what makes `op + ids + params` a
/// sound identity for the *values* a subgraph would compute.
#[derive(Clone, Copy, Debug)]
pub struct PlanKey {
    h1: u64,
    h2: u64,
}

impl PlanKey {
    /// Start a key for the named op.
    pub fn op(name: &str) -> Self {
        Self {
            h1: FNV_OFFSET,
            h2: FNV_OFFSET ^ LANE2_SEED,
        }
        .bytes(name.as_bytes())
    }

    pub fn bytes(mut self, bs: &[u8]) -> Self {
        for &b in bs {
            self.h1 = (self.h1 ^ b as u64).wrapping_mul(FNV_PRIME);
            self.h2 = (self.h2 ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn u64(self, v: u64) -> Self {
        self.bytes(&v.to_le_bytes())
    }

    pub fn f32(self, v: f32) -> Self {
        // Bit pattern, not value: -0.0 and NaN payloads key distinctly,
        // matching the bit-identical output contract.
        self.bytes(&v.to_bits().to_le_bytes())
    }

    pub fn id(self, id: DataId) -> Self {
        self.u64(id as u64)
    }

    /// Hash an input operand list — length first, so differently-split
    /// concatenations can never alias.
    pub fn ids(mut self, futs: &[Future]) -> Self {
        self = self.u64(futs.len() as u64);
        for f in futs {
            self = self.id(f.id);
        }
        self
    }

    /// Hash one epilogue op (discriminant + parameter bits).
    pub fn unary(self, op: UnaryKind) -> Self {
        let (tag, param) = match op {
            UnaryKind::AddScalar(s) => (0u64, s),
            UnaryKind::MulScalar(s) => (1, s),
            UnaryKind::Pow(e) => (2, e),
            UnaryKind::Sqrt => (3, 0.0),
            UnaryKind::Abs => (4, 0.0),
            UnaryKind::Exp => (5, 0.0),
            UnaryKind::Neg => (6, 0.0),
            UnaryKind::Relu => (7, 0.0),
        };
        self.u64(tag).f32(param)
    }

    pub fn finish(self) -> u128 {
        ((self.h1 as u128) << 64) | self.h2 as u128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::BlockMeta;

    fn fut(id: DataId) -> Future {
        Future {
            id,
            meta: BlockMeta::dense(2, 2),
        }
    }

    #[test]
    fn level_parse_round_trips() {
        for l in [Level::Off, Level::Cse, Level::Full] {
            assert_eq!(Level::parse(l.as_str()).unwrap(), l);
        }
        assert!(Level::parse("max").is_err());
        assert_eq!(Level::default(), Level::Off);
    }

    #[test]
    fn plan_keys_separate_ops_ids_params_and_splits() {
        let base = PlanKey::op("gram").ids(&[fut(1), fut(2)]).finish();
        assert_eq!(
            base,
            PlanKey::op("gram").ids(&[fut(1), fut(2)]).finish(),
            "deterministic"
        );
        assert_ne!(base, PlanKey::op("matmul").ids(&[fut(1), fut(2)]).finish());
        assert_ne!(base, PlanKey::op("gram").ids(&[fut(1), fut(3)]).finish());
        assert_ne!(base, PlanKey::op("gram").ids(&[fut(2), fut(1)]).finish());
        // Length prefixes: [1,2]+[3] never aliases [1]+[2,3].
        let a = PlanKey::op("x").ids(&[fut(1), fut(2)]).ids(&[fut(3)]).finish();
        let b = PlanKey::op("x").ids(&[fut(1)]).ids(&[fut(2), fut(3)]).finish();
        assert_ne!(a, b);
        // Parameters and epilogue ops key distinctly.
        assert_ne!(
            PlanKey::op("e").unary(UnaryKind::AddScalar(1.0)).finish(),
            PlanKey::op("e").unary(UnaryKind::AddScalar(2.0)).finish()
        );
        assert_ne!(
            PlanKey::op("e").unary(UnaryKind::Sqrt).finish(),
            PlanKey::op("e").unary(UnaryKind::Abs).finish()
        );
    }

    #[test]
    fn memo_hits_dedupe_and_misses_after_eviction() {
        let p = Planner::new(Level::Cse);
        let key = PlanKey::op("gram").ids(&[fut(7)]).finish();
        assert!(p.lookup(key, 9).is_none());
        assert_eq!(p.tasks_deduped(), 0);
        let displaced = p.record(key, vec![fut(100)]);
        assert!(displaced.is_empty());
        let hit = p.lookup(key, 9).expect("memoized");
        assert_eq!(hit[0].id, 100);
        assert_eq!(p.tasks_deduped(), 9);
        assert_eq!(p.memo_len(), 1);

        // Capacity FIFO: over-filling displaces the oldest entries.
        for i in 0..(CSE_CAPACITY as u32 + 10) {
            let k = PlanKey::op("fill").u64(i as u64).finish();
            let _ = p.record(k, vec![fut(1000 + i)]);
        }
        assert_eq!(p.memo_len(), CSE_CAPACITY);
        assert!(p.lookup(key, 9).is_none(), "original entry displaced");
    }

    #[test]
    fn epoch_sweep_releases_stale_entries_but_keeps_recent_hits() {
        let p = Planner::new(Level::Full);
        let stale = PlanKey::op("stale").finish();
        let fresh = PlanKey::op("fresh").finish();
        let _ = p.record(stale, vec![fut(1)]);
        let _ = p.record(fresh, vec![fut(2)]);
        // Age both entries right up to the horizon, refreshing only `fresh`.
        for _ in 0..CSE_MAX_AGE {
            let swept = p.bump_epoch();
            assert!(swept.is_empty());
            assert!(p.lookup(fresh, 1).is_some());
        }
        let swept = p.bump_epoch();
        assert_eq!(swept.len(), 1, "stale entry swept");
        assert_eq!(swept[0].id, 1);
        assert!(p.lookup(stale, 1).is_none());
        assert!(p.lookup(fresh, 1).is_some(), "refreshed entry survives");
    }

    #[test]
    fn off_level_never_memoizes() {
        let p = Planner::new(Level::Off);
        assert!(!p.cse_enabled());
        assert!(!p.fuse_enabled());
        let key = PlanKey::op("gram").finish();
        let returned = p.record(key, vec![fut(5)]);
        assert_eq!(returned.len(), 1, "refs handed straight back");
        assert!(p.lookup(key, 3).is_none());
        assert_eq!(p.tasks_deduped(), 0);
        assert_eq!(p.memo_len(), 0);
    }

    #[test]
    fn fuse_enabled_only_at_full() {
        assert!(!Planner::new(Level::Off).fuse_enabled());
        assert!(!Planner::new(Level::Cse).fuse_enabled());
        assert!(Planner::new(Level::Cse).cse_enabled());
        assert!(Planner::new(Level::Full).fuse_enabled());
        assert!(Planner::new(Level::Full).cse_enabled());
    }
}
