//! [`RuntimeBuilder`] — the single public construction path for runtimes.
//!
//! Nine PRs of features left runtime construction sprawled across
//! `LocalOptions`, `ClusterOptions`, and `Config::runtime()`; the builder
//! replaces all of them with one fluent front door that also carries the
//! plan-layer [`Level`] knob:
//!
//! ```
//! use rustdslib::config::Backend;
//! use rustdslib::plan::Level;
//! use rustdslib::tasking::Runtime;
//!
//! let rt = Runtime::builder()
//!     .backend(Backend::Local)
//!     .workers(2)
//!     .memory_budget_mb(512)
//!     .optimizer(Level::Full)
//!     .build()
//!     .unwrap();
//! assert_eq!(rt.planner().level(), Level::Full);
//! ```
//!
//! The legacy constructors (`Runtime::local` and friends, the deprecated
//! `LocalOptions::new` / `ClusterOptions::spawn` / `Config::runtime`
//! shims) stay compilable and default to [`Level::Off`] — exactly the
//! pre-planner task streams. The builder defaults to [`Level::Full`].

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{Backend, Config};
use crate::tasking::{ClusterOptions, LocalOptions, Runtime, SimConfig, TransferMode};

use super::Level;

/// Fluent builder for every [`Runtime`] backend — see the module docs.
/// Obtain one via [`Runtime::builder`].
#[derive(Clone, Debug)]
pub struct RuntimeBuilder {
    backend: Backend,
    /// Executor threads: local worker threads, or the cluster
    /// coordinator's thread count. `None` picks the backend default.
    workers: Option<usize>,
    cluster_workers: usize,
    cluster_addrs: Vec<String>,
    memory_budget_bytes: Option<u64>,
    spill_dir: Option<PathBuf>,
    recovery: bool,
    replication: usize,
    heartbeat_ms: u64,
    straggler_factor: f64,
    transfer: Option<TransferMode>,
    program: Option<PathBuf>,
    sim: Option<SimConfig>,
    optimizer: Level,
}

impl Default for RuntimeBuilder {
    fn default() -> Self {
        Self {
            backend: Backend::Local,
            workers: None,
            cluster_workers: 2,
            cluster_addrs: Vec::new(),
            memory_budget_bytes: None,
            spill_dir: None,
            recovery: true,
            replication: 1,
            heartbeat_ms: 0,
            straggler_factor: 0.0,
            transfer: None,
            program: None,
            sim: None,
            optimizer: Level::Full,
        }
    }
}

impl RuntimeBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Execution backend (default [`Backend::Local`]).
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    /// Executor threads: local worker threads, or the cluster
    /// coordinator's executor-thread count.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Worker processes the cluster backend spawns on loopback when no
    /// explicit addresses are given (default 2).
    pub fn cluster_workers(mut self, n: usize) -> Self {
        self.cluster_workers = n;
        self
    }

    /// Connect to already-running `dsarray worker` processes instead of
    /// spawning (cluster backend).
    pub fn cluster_addrs(mut self, addrs: Vec<String>) -> Self {
        self.cluster_addrs = addrs;
        self
    }

    /// Out-of-core resident-set budget in bytes (local: the spill store's
    /// budget; cluster: per-worker budget).
    pub fn memory_budget_bytes(mut self, bytes: u64) -> Self {
        self.memory_budget_bytes = (bytes > 0).then_some(bytes);
        self
    }

    /// Out-of-core resident-set budget in MiB — the common spelling.
    pub fn memory_budget_mb(self, mb: u64) -> Self {
        self.memory_budget_bytes(mb * 1024 * 1024)
    }

    /// Parent directory for spill files (only used with a budget; the
    /// runtime creates and removes its own subdirectory under it).
    pub fn spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self
    }

    /// Lineage-based recovery of dead cluster workers (default on).
    pub fn recovery(mut self, on: bool) -> Self {
        self.recovery = on;
        self
    }

    /// Copies of each block kept on distinct cluster workers (default 1 =
    /// no replication).
    pub fn replication(mut self, k: usize) -> Self {
        self.replication = k.max(1);
        self
    }

    /// Heartbeat interval for proactive cluster liveness probes in
    /// milliseconds (default 0 = reactive detection only).
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.heartbeat_ms = ms;
        self
    }

    /// Straggler speculation threshold (default 0 = off; see
    /// `ClusterOptions::with_straggler_factor`).
    pub fn straggler_factor(mut self, f: f64) -> Self {
        self.straggler_factor = f.max(0.0);
        self
    }

    /// Cluster block-transfer mode (default [`TransferMode::Pull`]).
    pub fn transfer(mut self, t: TransferMode) -> Self {
        self.transfer = Some(t);
        self
    }

    /// Worker binary to spawn for loopback cluster workers (default: the
    /// current executable).
    pub fn program(mut self, p: impl Into<PathBuf>) -> Self {
        self.program = Some(p.into());
        self
    }

    /// Cost model for the simulator backend (default: MareNostrum
    /// calibration at the configured worker count).
    pub fn sim_config(mut self, s: SimConfig) -> Self {
        self.sim = Some(s);
        self
    }

    /// Plan-layer optimization level (default [`Level::Full`]; the legacy
    /// constructors default to [`Level::Off`]).
    pub fn optimizer(mut self, level: Level) -> Self {
        self.optimizer = level;
        self
    }

    /// Absorb a resolved [`Config`] (TOML file + CLI flags) into the
    /// builder; later fluent calls still override individual knobs.
    pub fn from_config(mut self, cfg: &Config) -> Self {
        self.backend = cfg.backend;
        self.workers = Some(cfg.local_workers);
        self.cluster_workers = cfg.cluster_workers;
        self.cluster_addrs = cfg.cluster_addrs.clone();
        self.memory_budget_bytes = cfg.memory_budget_bytes;
        self.spill_dir = cfg.spill_dir.as_ref().map(PathBuf::from);
        self.recovery = cfg.recovery;
        self.replication = cfg.replicate_blocks.max(1);
        self.heartbeat_ms = cfg.heartbeat_ms;
        self.straggler_factor = cfg.straggler_factor;
        self.sim = Some(cfg.sim.clone());
        self.optimizer = cfg.optimizer;
        self
    }

    /// Construct the runtime. Local and cluster construction can fail
    /// (spill-store setup, worker spawn/connect); the simulator cannot.
    pub fn build(self) -> Result<Runtime> {
        let rt = match self.backend {
            Backend::Local => {
                let workers = self.workers.unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
                });
                Runtime::local_with_options(LocalOptions {
                    workers,
                    memory_budget_bytes: self.memory_budget_bytes,
                    // The spill directory only matters under a budget —
                    // mirroring the old Config::local_runtime contract.
                    spill_dir: self.memory_budget_bytes.and(self.spill_dir),
                })?
            }
            Backend::Sim => {
                let sim = self
                    .sim
                    .unwrap_or_else(|| SimConfig::with_workers(self.workers.unwrap_or(48)));
                Runtime::sim(sim)
            }
            Backend::Cluster => {
                let (addrs, spawn) = if self.cluster_addrs.is_empty() {
                    (Vec::new(), self.cluster_workers)
                } else {
                    (self.cluster_addrs, 0)
                };
                Runtime::cluster(ClusterOptions {
                    addrs,
                    spawn,
                    program: self.program,
                    threads: self.workers.unwrap_or(2).max(1),
                    transfer: self.transfer.unwrap_or_default(),
                    worker_budget_bytes: self.memory_budget_bytes,
                    recovery: self.recovery,
                    replicate: self.replication,
                    heartbeat_ms: self.heartbeat_ms,
                    straggler_factor: self.straggler_factor,
                })?
            }
        };
        Ok(rt.with_optimizer(self.optimizer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_local_full() {
        let rt = Runtime::builder().workers(2).build().unwrap();
        assert!(!rt.is_sim());
        assert_eq!(rt.planner().level(), Level::Full);
    }

    #[test]
    fn builder_optimizer_and_backend_knobs() {
        let rt = Runtime::builder()
            .workers(1)
            .optimizer(Level::Off)
            .build()
            .unwrap();
        assert_eq!(rt.planner().level(), Level::Off);

        let rt = Runtime::builder()
            .backend(Backend::Sim)
            .workers(16)
            .optimizer(Level::Cse)
            .build()
            .unwrap();
        assert!(rt.is_sim());
        assert_eq!(rt.planner().level(), Level::Cse);
    }

    #[test]
    fn builder_absorbs_config_and_budget() {
        let mut cfg = Config::default();
        cfg.local_workers = 2;
        cfg.memory_budget_bytes = Some(4 << 20);
        cfg.optimizer = Level::Cse;
        let rt = Runtime::builder().from_config(&cfg).build().unwrap();
        assert_eq!(rt.planner().level(), Level::Cse);
        // Fluent override after from_config still wins.
        let rt = Runtime::builder()
            .from_config(&cfg)
            .optimizer(Level::Off)
            .build()
            .unwrap();
        assert_eq!(rt.planner().level(), Level::Off);
    }

    #[test]
    fn budget_helpers_convert_and_clamp() {
        let b = RuntimeBuilder::new().memory_budget_mb(2);
        assert_eq!(b.memory_budget_bytes, Some(2 << 20));
        let b = RuntimeBuilder::new().memory_budget_bytes(0);
        assert_eq!(b.memory_budget_bytes, None);
        let b = RuntimeBuilder::new().replication(0).straggler_factor(-2.0);
        assert_eq!(b.replication, 1);
        assert_eq!(b.straggler_factor, 0.0);
    }
}
