//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the L3 hot path. Python never runs at request time: `make
//! artifacts` lowers the L2 graphs once to `artifacts/*.hlo.txt`, and this
//! module compiles them on the PJRT CPU client at startup.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), so all
//! PJRT state lives on one dedicated **service thread**; task closures on
//! worker threads call [`PjrtService::call`] through a channel. One compiled
//! executable per (entry point, canonical shape) pair, per the manifest.

pub mod artifact;
pub mod client;
pub mod exec;

pub use artifact::{ArtifactSig, Manifest};
pub use client::{global, PjrtService};
