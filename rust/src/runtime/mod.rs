//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the L3 hot path. Python never runs at request time: `make
//! artifacts` lowers the L2 graphs once to `artifacts/*.hlo.txt`, and this
//! module compiles them on the PJRT CPU client at startup.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), so all
//! PJRT state lives on one dedicated **service thread**; task closures on
//! worker threads call [`PjrtService::call`] through a channel. One compiled
//! executable per (entry point, canonical shape) pair, per the manifest.

// The `pjrt` feature requires the external `xla` crate, which the offline
// build intentionally does not declare. Fail with one actionable message
// instead of a cascade of unresolved-crate errors. To actually enable PJRT:
// add `xla` to [dependencies] in rust/Cargo.toml and delete this guard.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the undeclared `xla` crate: add it to rust/Cargo.toml [dependencies], then remove this guard in src/runtime/mod.rs"
);

pub mod artifact;
pub mod client;
pub mod exec;

pub use artifact::{ArtifactSig, Manifest};
pub use client::{global, PjrtService};
