//! Block ⇄ `xla::Literal` conversion and padded-call helpers.
//!
//! Artifacts have fixed canonical shapes (AOT); these helpers pad inputs up
//! to the canonical block edge and slice results back to logical sizes, so
//! estimator task closures can call PJRT on any block size.

use anyhow::Result;

#[cfg(feature = "pjrt")]
use anyhow::anyhow;

use crate::storage::DenseMatrix;

use super::PjrtService;

/// Dense matrices → row-major f32 literals. Uses the raw untyped-data
/// constructor: one shaped copy instead of vec1 + XLA reshape (§Perf it.2).
#[cfg(feature = "pjrt")]
pub fn matrices_to_literals(ms: &[DenseMatrix]) -> Result<Vec<xla::Literal>> {
    ms.iter()
        .map(|m| {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(m.data().as_ptr() as *const u8, m.data().len() * 4)
            };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &[m.rows(), m.cols()],
                bytes,
            )
            .map_err(|e| anyhow!("creating shaped literal: {e}"))
        })
        .collect()
}

/// Literal (rank ≤ 2 f32) → dense matrix with the manifest's shape.
#[cfg(feature = "pjrt")]
pub fn literal_to_dense(lit: &xla::Literal, rows: usize, cols: usize) -> Result<DenseMatrix> {
    let v = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("reading f32 literal: {e}"))?;
    DenseMatrix::from_vec(rows, cols, v)
}

/// Canonical artifact edges available, best (largest that fits) first.
pub const CANONICAL_EDGES: [usize; 2] = [128, 64];

/// Pick the smallest canonical edge that covers `n`, or the largest one for
/// tiling bigger inputs.
pub fn pick_edge(n: usize) -> usize {
    for &e in CANONICAL_EDGES.iter().rev() {
        if n <= e {
            return e;
        }
    }
    CANONICAL_EDGES[0]
}



/// Static artifact names for the canonical edges (§Perf it.3: no per-call
/// string formatting on the dispatch path).
fn artifact_name(kind: &str, edge: usize) -> &'static str {
    match (kind, edge) {
        ("gemm", 64) => "gemm_64",
        ("gemm", _) => "gemm_128",
        ("gemm_tn", 64) => "gemm_tn_64",
        ("gemm_tn", _) => "gemm_tn_128",
        ("kmeans", 64) => "kmeans_64_k8",
        ("kmeans", _) => "kmeans_128_k8",
        ("standardize", 64) => "standardize_64",
        ("standardize", _) => "standardize_128",
        ("col_stats", 64) => "col_stats_64",
        ("col_stats", _) => "col_stats_128",
        ("pairwise", 64) => "pairwise_64",
        (_, _) => "pairwise_128",
    }
}

/// Slice an owned output back to its logical size; a no-op move when the
/// logical size IS the canonical size (§Perf: avoids a full-block copy).
fn shrink(mut outs: Vec<DenseMatrix>, idx: usize, rows: usize, cols: usize) -> Result<DenseMatrix> {
    let m = std::mem::replace(&mut outs[idx], DenseMatrix::zeros(0, 0));
    if (m.rows(), m.cols()) == (rows, cols) {
        Ok(m)
    } else {
        m.slice(0, 0, rows, cols)
    }
}

/// `C + A@B` through the gemm artifact: pads (m,k,n) up to one canonical
/// edge when everything fits, otherwise falls back to native matmul (the
/// caller keeps block sizes ≤ 128 on the hot path).
pub fn gemm_acc(
    svc: &PjrtService,
    a: &DenseMatrix,
    b: &DenseMatrix,
    c: &DenseMatrix,
) -> Result<DenseMatrix> {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let edge = pick_edge(m.max(k).max(n));
    if m.max(k).max(n) > edge {
        // Larger than the largest artifact: native tiled-accumulate fallback.
        let mut out = c.clone();
        out.gemm_acc(a, b)?;
        return Ok(out);
    }
    let name = artifact_name("gemm", edge);
    let pa = a.pad_to(edge, edge)?;
    let pb = b.pad_to(edge, edge)?;
    let pc = c.pad_to(edge, edge)?;
    let out = svc.call(name, vec![pa, pb, pc])?;
    shrink(out, 0, m, n)
}

/// `C + Aᵀ@B` through the gemm_tn artifact (A is (k, m)).
pub fn gemm_tn_acc(
    svc: &PjrtService,
    a: &DenseMatrix,
    b: &DenseMatrix,
    c: &DenseMatrix,
) -> Result<DenseMatrix> {
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let edge = pick_edge(m.max(k).max(n));
    if m.max(k).max(n) > edge {
        let mut out = c.clone();
        let at = a.transpose();
        out.gemm_acc(&at, b)?;
        return Ok(out);
    }
    let name = artifact_name("gemm_tn", edge);
    let pa = a.pad_to(edge, edge)?;
    let pb = b.pad_to(edge, edge)?;
    let pc = c.pad_to(edge, edge)?;
    let out = svc.call(name, vec![pa, pb, pc])?;
    shrink(out, 0, m, n)
}

/// Fused K-means assignment step through the kmeans artifact.
///
/// Pads samples to (edge, edge) with a validity mask, pads unused center
/// rows with a huge sentinel (never selected — verified in
/// python/tests/test_kernel.py), and slices partials back to (k, f).
/// Returns (psum (k, f), pcount (1, k), pssd scalar).
pub fn kmeans_assign(
    svc: &PjrtService,
    x: &DenseMatrix,
    centers: &DenseMatrix,
) -> Result<(DenseMatrix, DenseMatrix, f32)> {
    let (m, f) = (x.rows(), x.cols());
    let (k, fc) = (centers.rows(), centers.cols());
    if f != fc {
        anyhow::bail!("kmeans feature mismatch: x has {f}, centers have {fc}");
    }
    const K_MAX: usize = 8; // model.KMEANS_K baked into the artifacts
    if k > K_MAX {
        anyhow::bail!("artifact supports k <= {K_MAX}, got {k}");
    }
    let edge = pick_edge(m.max(f));
    if m.max(f) > edge {
        anyhow::bail!("block {m}x{f} exceeds largest kmeans artifact ({edge})");
    }
    let name = artifact_name("kmeans", edge);
    let px = x.pad_to(edge, edge)?;
    // Pad unused center rows with a sentinel far from any data.
    let mut pc = DenseMatrix::full(K_MAX, edge, 1e30);
    pc.paste(0, 0, centers)?;
    // Zero-pad the center feature tail (sentinel would corrupt distances of
    // real centers if left in their columns; those columns of x are zero).
    for kk in 0..k {
        for ff in f..edge {
            pc.set(kk, ff, 0.0);
        }
    }
    let mut mask = DenseMatrix::zeros(edge, 1);
    for i in 0..m {
        mask.set(i, 0, 1.0);
    }
    let out = svc.call(name, vec![px, pc, mask])?;
    let psum = out[0].slice(0, 0, k, f)?;
    let pcount = out[1].slice(0, 0, 1, k)?;
    let pssd = out[2].get(0, 0);
    Ok((psum, pcount, pssd))
}

/// Scaler transform `(x - mean) * inv_std` through the standardize artifact.
pub fn standardize(
    svc: &PjrtService,
    x: &DenseMatrix,
    mean: &DenseMatrix,
    inv_std: &DenseMatrix,
) -> Result<DenseMatrix> {
    let (m, f) = (x.rows(), x.cols());
    let edge = pick_edge(m.max(f));
    if m.max(f) > edge {
        anyhow::bail!("block {m}x{f} exceeds largest standardize artifact");
    }
    let name = artifact_name("standardize", edge);
    let px = x.pad_to(edge, edge)?;
    let pm = mean.pad_to(1, edge)?;
    // inv_std pad with 1.0 (0 would zero the padding harmlessly, but 1 keeps
    // the identity semantics if anything reads the tail).
    let mut pi = DenseMatrix::full(1, edge, 1.0);
    pi.paste(0, 0, inv_std)?;
    let out = svc.call(name, vec![px, pm, pi])?;
    shrink(out, 0, m, f)
}

/// Pairwise squared distances between query rows and a reference set
/// through the pairwise artifact. Reference rows beyond `y.rows()` are
/// padded with a distant sentinel and sliced away.
pub fn pairwise_dist2(
    svc: &PjrtService,
    x: &DenseMatrix,
    y: &DenseMatrix,
) -> Result<DenseMatrix> {
    let (m, f) = (x.rows(), x.cols());
    let k = y.rows();
    if y.cols() != f {
        anyhow::bail!("pairwise feature mismatch: {f} vs {}", y.cols());
    }
    let edge = pick_edge(m.max(f).max(k));
    if m.max(f).max(k) > edge {
        anyhow::bail!("block {m}x{f} vs {k} refs exceeds largest pairwise artifact");
    }
    let name = artifact_name("pairwise", edge);
    let px = x.pad_to(edge, edge)?;
    // Padding reference rows with a large sentinel keeps them from ever
    // being nearest; zero-padding x's feature tail keeps real distances
    // exact as long as y's tail is zero for the real rows.
    let mut py = DenseMatrix::full(edge, edge, 1e15);
    py.paste(0, 0, y)?;
    for r in 0..k {
        for c in f..edge {
            py.set(r, c, 0.0);
        }
    }
    let out = svc.call(name, vec![px, py])?;
    out[0].slice(0, 0, m, k)
}

/// Masked column stats (sums, sumsq) through the col_stats artifact.
pub fn col_stats(svc: &PjrtService, x: &DenseMatrix) -> Result<(DenseMatrix, DenseMatrix)> {
    let (m, f) = (x.rows(), x.cols());
    let edge = pick_edge(m.max(f));
    if m.max(f) > edge {
        anyhow::bail!("block {m}x{f} exceeds largest col_stats artifact");
    }
    let name = artifact_name("col_stats", edge);
    let px = x.pad_to(edge, edge)?;
    let mut mask = DenseMatrix::zeros(edge, 1);
    for i in 0..m {
        mask.set(i, 0, 1.0);
    }
    let out = svc.call(name, vec![px, mask])?;
    Ok((out[0].slice(0, 0, 1, f)?, out[1].slice(0, 0, 1, f)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_edge_prefers_smallest_cover() {
        assert_eq!(pick_edge(1), 64);
        assert_eq!(pick_edge(64), 64);
        assert_eq!(pick_edge(65), 128);
        assert_eq!(pick_edge(128), 128);
        assert_eq!(pick_edge(129), 128); // tiling fallback edge
    }
}
