//! The PJRT service thread: owns the (non-`Send`) PJRT CPU client and every
//! compiled executable; serves execution requests over a channel.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, Result};

use crate::storage::DenseMatrix;

use super::artifact::Manifest;

// The `xla` crate (and everything touching it) only exists behind the
// `pjrt` cargo feature: the offline default build has no PJRT dependency
// and every caller falls back to native block math via `global() == None`.
#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;

#[cfg(feature = "pjrt")]
use anyhow::Context;

#[cfg(feature = "pjrt")]
use super::exec::{literal_to_dense, matrices_to_literals};

struct Request {
    name: String,
    inputs: Vec<DenseMatrix>,
    reply: mpsc::Sender<Result<Vec<DenseMatrix>>>,
}

/// Handle to the PJRT service thread. Cloneable and thread-safe; the PJRT
/// objects themselves never leave the service thread.
pub struct PjrtService {
    tx: Mutex<mpsc::Sender<Request>>,
    manifest: Manifest,
}

impl PjrtService {
    /// Start the service for an artifact directory. Compiles executables
    /// lazily (first call per entry point) on the service thread.
    #[cfg(feature = "pjrt")]
    pub fn start(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.validate_files()?;
        let (tx, rx) = mpsc::channel::<Request>();
        let thread_manifest = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_loop(thread_manifest, rx))
            .context("spawning pjrt service thread")?;
        Ok(Self {
            tx: Mutex::new(tx),
            manifest,
        })
    }

    /// Built without the `pjrt` feature: validates the artifact directory
    /// but always errors — `global()` then reports `None` and every hot
    /// path uses its native fallback.
    #[cfg(not(feature = "pjrt"))]
    pub fn start(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.validate_files()?;
        anyhow::bail!(
            "rustdslib was built without the `pjrt` feature: artifacts in {} cannot be executed",
            dir.display()
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute `name` with the given inputs (shapes must match the
    /// manifest); returns the output matrices.
    pub fn call(&self, name: &str, inputs: Vec<DenseMatrix>) -> Result<Vec<DenseMatrix>> {
        let sig = self.manifest.sig(name)?;
        if inputs.len() != sig.inputs.len() {
            anyhow::bail!(
                "artifact {name} takes {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            );
        }
        for (i, (m, &(r, c))) in inputs.iter().zip(&sig.inputs).enumerate() {
            if (m.rows(), m.cols()) != (r, c) {
                anyhow::bail!(
                    "artifact {name} input {i}: expected {r}x{c}, got {}x{} (pad first)",
                    m.rows(),
                    m.cols()
                );
            }
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request {
                name: name.to_string(),
                inputs,
                reply: rtx,
            })
            .map_err(|_| anyhow!("pjrt service thread is gone"))?;
        rrx.recv().map_err(|_| anyhow!("pjrt service dropped reply"))?
    }
}

#[cfg(feature = "pjrt")]
fn service_loop(manifest: Manifest, rx: mpsc::Receiver<Request>) {
    // All PJRT state is thread-local to this loop.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the same cause.
            while let Ok(req) = rx.recv() {
                let _ = req.reply.send(Err(anyhow!("PJRT CPU client failed: {e}")));
            }
            return;
        }
    };
    let mut executables: BTreeMap<String, xla::PjRtLoadedExecutable> = BTreeMap::new();

    while let Ok(req) = rx.recv() {
        let result = (|| -> Result<Vec<DenseMatrix>> {
            if !executables.contains_key(&req.name) {
                let path = manifest.hlo_path(&req.name);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e}", req.name))?;
                executables.insert(req.name.clone(), exe);
            }
            let exe = &executables[&req.name];
            let sig = manifest.sig(&req.name)?;
            let literals = matrices_to_literals(&req.inputs)?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {}: {e}", req.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {}: {e}", req.name))?;
            // aot.py lowers with return_tuple=True: unpack N outputs.
            let items = out
                .to_tuple()
                .map_err(|e| anyhow!("untupling result of {}: {e}", req.name))?;
            if items.len() != sig.outputs.len() {
                anyhow::bail!(
                    "{}: runtime returned {} outputs, manifest says {}",
                    req.name,
                    items.len(),
                    sig.outputs.len()
                );
            }
            items
                .into_iter()
                .zip(&sig.outputs)
                .map(|(lit, &(r, c))| literal_to_dense(&lit, r, c))
                .collect()
        })();
        // Receiver may have timed out/vanished; that's fine.
        let _ = req.reply.send(result);
    }
}

static GLOBAL: OnceLock<Option<PjrtService>> = OnceLock::new();

/// Process-wide service over `$RUSTDSLIB_ARTIFACTS` (default `artifacts/`,
/// resolved against the crate root for test runs). `None` when artifacts
/// have not been built — callers fall back to native block math.
pub fn global() -> Option<&'static PjrtService> {
    GLOBAL
        .get_or_init(|| {
            let dir = std::env::var("RUSTDSLIB_ARTIFACTS").unwrap_or_else(|_| {
                let local = Path::new("artifacts");
                if local.join("manifest.json").exists() {
                    "artifacts".to_string()
                } else {
                    // Fall back to the crate root (tests run from odd cwds).
                    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
                }
            });
            PjrtService::start(Path::new(&dir)).ok()
        })
        .as_ref()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end PJRT checks live in rust/tests/pjrt_integration.rs; here
    /// we only verify service startup error handling.
    #[test]
    fn start_fails_cleanly_without_artifacts() {
        let dir = std::env::temp_dir().join(format!("no_artifacts_{}", std::process::id()));
        assert!(PjrtService::start(&dir).is_err());
    }
}
