//! Artifact manifest: what `python/compile/aot.py` produced, with the
//! input/output shapes the Rust side must honor.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Shape signature of one compiled entry point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSig {
    pub name: String,
    /// (rows, cols) per input, in call order.
    pub inputs: Vec<(usize, usize)>,
    /// (rows, cols) per output, in tuple order.
    pub outputs: Vec<(usize, usize)>,
}

/// Parsed `manifest.json` + artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactSig>,
}

fn parse_shapes(v: &Json, what: &str) -> Result<Vec<(usize, usize)>> {
    let arr = v
        .as_arr()
        .with_context(|| format!("{what}: expected array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let pair = item.as_arr().with_context(|| format!("{what}: entry"))?;
        let dims = pair
            .first()
            .and_then(|d| d.as_arr())
            .with_context(|| format!("{what}: dims"))?;
        let dtype = pair.get(1).and_then(|d| d.as_str()).unwrap_or("");
        if dtype != "float32" {
            bail!("{what}: unsupported dtype {dtype} (only f32 artifacts)");
        }
        let (r, c) = match dims {
            [r, c] => (
                r.as_usize().context("rows")?,
                c.as_usize().context("cols")?,
            ),
            [n] => (1, n.as_usize().context("len")?),
            [] => (1, 1),
            _ => bail!("{what}: only rank <= 2 artifacts supported, got {dims:?}"),
        };
        out.push((r, c));
    }
    Ok(out)
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let obj = root.as_obj().context("manifest root must be an object")?;
        let mut entries = BTreeMap::new();
        for (name, entry) in obj {
            let sig = ArtifactSig {
                name: name.clone(),
                inputs: parse_shapes(
                    entry.get("inputs").context("inputs")?,
                    &format!("{name}.inputs"),
                )?,
                outputs: parse_shapes(
                    entry.get("outputs").context("outputs")?,
                    &format!("{name}.outputs"),
                )?,
            };
            entries.insert(name.clone(), sig);
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn sig(&self, name: &str) -> Result<&ArtifactSig> {
        self.entries
            .get(name)
            .with_context(|| format!("no artifact `{name}` in manifest"))
    }

    /// Path of the HLO text for an entry point.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Validate that every entry's HLO file exists.
    pub fn validate_files(&self) -> Result<()> {
        for name in self.entries.keys() {
            let p = self.hlo_path(name);
            if !p.exists() {
                bail!("artifact file missing: {}", p.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_manifest_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn parses_real_shape_signatures() {
        let dir = tmpdir("ok");
        write_manifest(
            &dir,
            r#"{
              "gemm_64": {"inputs": [[[64,64],"float32"],[[64,64],"float32"],[[64,64],"float32"]],
                          "outputs": [[[64,64],"float32"]]},
              "kmeans_64_k8": {"inputs": [[[64,64],"float32"],[[8,64],"float32"],[[64,1],"float32"]],
                               "outputs": [[[8,64],"float32"],[[1,8],"float32"],[[1,1],"float32"]]}
            }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let g = m.sig("gemm_64").unwrap();
        assert_eq!(g.inputs, vec![(64, 64); 3]);
        let k = m.sig("kmeans_64_k8").unwrap();
        assert_eq!(k.outputs, vec![(8, 64), (1, 8), (1, 1)]);
        assert!(m.sig("nope").is_err());
        assert_eq!(m.hlo_path("gemm_64"), dir.join("gemm_64.hlo.txt"));
        // Files absent -> validate fails.
        assert!(m.validate_files().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_non_f32() {
        let dir = tmpdir("dtype");
        write_manifest(
            &dir,
            r#"{"x": {"inputs": [[[4,4],"int32"]], "outputs": [[[4,4],"float32"]]}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_repo_manifest_if_built() {
        // Integration hook: when `make artifacts` has run, the real manifest
        // must parse and be internally consistent.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() >= 6);
        m.validate_files().unwrap();
        let g = m.sig("gemm_64").unwrap();
        assert_eq!(g.inputs.len(), 3);
    }
}
