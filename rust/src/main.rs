//! `dsarray` — CLI for the ds-array reproduction.
//!
//! Subcommands:
//!   version                       build info
//!   bench --fig 6|7|8|9|tasks|all paper-figure reproductions (simulated cluster)
//!   ablation --which blocks|collections
//!   calibrate                     local micro-measurements feeding the cost model
//!   demo                          end-to-end sanity run (expr chain + KMeans fit)
//!   worker --listen <addr>        cluster worker daemon (block storage over TCP)
//!   fit --estimator k --out p     fit on synthetic data, save a model artifact
//!   serve --models name=path,…    host artifacts for online predict traffic
//!   predict --addr a --model m    score rows against a running server
//!
//! Global flags: --config <toml>, --cores a,b,c, --seed, --workers,
//! --backend local|sim|cluster, --cluster-workers N,
//! --cluster-addr host:port,…, --no-recovery, --replicate-blocks k,
//! --heartbeat-ms N, --straggler-factor F, the serving knobs
//! --batch-window-ms/--max-batch-rows/--max-pending-rows, and the sim.*
//! overrides (see config.rs). The worker subcommand also takes
//! --fault-plan <spec> (deterministic chaos, e.g. `die@7`, `slow@3`) and
//! --join <coordinator-addr> to enroll into a running fleet; `worker
//! --drain <worker-addr> --join <coordinator-addr>` sends a one-shot
//! graceful decommission request instead of starting a daemon. A worker
//! started with --join also drains *itself* on SIGTERM: it asks the
//! coordinator to fence and migrate its blocks, then exits cleanly.

use anyhow::Result;

use rustdslib::bench::{experiments, report};
use rustdslib::config::Config;
use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::linreg::LinearRegression;
use rustdslib::estimators::pca::Pca;
use rustdslib::estimators::scaler::StandardScaler;
use rustdslib::estimators::Estimator;
use rustdslib::serving::{ModelArtifact, ModelServer, PredictOutcome, ServingClient};
use rustdslib::tasking::{Runtime, WorkerOptions};
use rustdslib::util::cli::Args;
use rustdslib::util::rng::Xoshiro256;
use rustdslib::DenseMatrix;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("version") => {
            println!("rustdslib {} — ds-array (CS.DC 2021) reproduction", env!("CARGO_PKG_VERSION"));
        }
        Some("bench") => bench(&args)?,
        Some("ablation") => ablation(&args)?,
        Some("calibrate") => calibrate(&args)?,
        Some("demo") => demo(&args)?,
        Some("worker") => worker(&args)?,
        Some("fit") => fit(&args)?,
        Some("serve") => serve(&args)?,
        Some("predict") => predict(&args)?,
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand `{cmd}`\n");
            }
            eprintln!(
                "usage: dsarray <version|bench|ablation|calibrate|demo|worker|fit|serve|predict> [flags]"
            );
            eprintln!("  dsarray bench --fig all");
            eprintln!("  dsarray bench --fig 6 --cores 48,96,192");
            eprintln!("  dsarray ablation --which collections");
            eprintln!("  dsarray worker --listen 127.0.0.1:7401");
            eprintln!("  dsarray worker --join <coordinator-addr>        (enroll into a running fleet)");
            eprintln!("  dsarray worker --drain 127.0.0.1:7401 --join <coordinator-addr>");
            eprintln!("  dsarray demo --backend cluster --cluster-addr 127.0.0.1:7401,127.0.0.1:7402");
            eprintln!("  dsarray fit --estimator kmeans --out /tmp/model.dsma");
            eprintln!("  dsarray serve --models demo=/tmp/model.dsma --listen 127.0.0.1:7510");
            eprintln!("  dsarray predict --addr 127.0.0.1:7510 --model demo --rows \"0.1,0.2;0.3,0.4\"");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Cluster worker daemon: bind, announce `LISTENING <addr>` on stdout (the
/// coordinator and CI parse it — port 0 picks a free port), then serve
/// blocks until a Shutdown frame or SIGKILL. With `--join
/// <coordinator-addr>` the worker also enrolls itself into the running
/// fleet; with `--drain <worker-addr>` no daemon starts at all — the
/// process just asks the coordinator to decommission that member and
/// exits.
fn worker(args: &Args) -> Result<()> {
    if let Some(target) = args.get("drain") {
        let coordinator = args.get("join").ok_or_else(|| {
            anyhow::anyhow!("--drain needs --join <coordinator-addr> to send the request to")
        })?;
        rustdslib::tasking::cluster::request_drain(coordinator, target)?;
        println!("DRAINED {target}");
        return Ok(());
    }
    let listen = args.get_str("listen", "127.0.0.1:0");
    // A malformed budget must be a startup error, not a silently unbounded
    // worker that OOMs mid-run far from the configuration mistake.
    let budget = match (args.get("memory-budget-bytes"), args.get("memory-budget-mb")) {
        (Some(v), _) => Some(
            v.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad --memory-budget-bytes `{v}`: {e}"))?,
        ),
        (None, Some(v)) => Some(
            v.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad --memory-budget-mb `{v}`: {e}"))?
                * 1024
                * 1024,
        ),
        (None, None) => None,
    };
    let listener = std::net::TcpListener::bind(listen)?;
    println!("LISTENING {}", listener.local_addr()?);
    use std::io::Write as _;
    std::io::stdout().flush()?;
    #[cfg(unix)]
    install_sigterm_drain(
        args.get("join").map(|s| s.to_string()),
        listener.local_addr()?.to_string(),
    );
    if let Some(coordinator) = args.get("join") {
        // The coordinator's enroll path connects back and pings this
        // worker before acknowledging, so the join request must go out
        // while the daemon below is already accepting — hence the thread.
        // A refused join kills the process: an unenrolled daemon nobody
        // knows about is an orphan, not a worker.
        let coordinator = coordinator.to_string();
        let me = listener.local_addr()?.to_string();
        std::thread::spawn(move || {
            match rustdslib::tasking::cluster::request_join(&coordinator, &me) {
                Ok(()) => {
                    println!("JOINED {coordinator}");
                    let _ = std::io::stdout().flush();
                }
                Err(e) => {
                    eprintln!("join via {coordinator} failed: {e:#}");
                    std::process::exit(1);
                }
            }
        });
    }
    rustdslib::tasking::cluster::serve_worker(
        listener,
        WorkerOptions {
            memory_budget_bytes: budget,
            // Deterministic fault schedule from the chaos harness
            // (`die@N` / `drop@N`, comma-separated).
            fault_spec: args.get("fault-plan").map(|s| s.to_string()),
            // Real worker daemons die for real: injected crashes exit the
            // process, SIGKILL-style.
            crash_exits: true,
        },
    )
}

/// Worker-initiated graceful shutdown. SIGTERM means "leave the fleet
/// politely": a joined worker asks the coordinator to drain it — fence
/// placement, migrate its blocks to survivors — and only exits once the
/// drain is acknowledged, so the departure costs zero lost blocks and zero
/// recovery work. The signal handler itself only flips an atomic (the only
/// async-signal-safe thing it may do); a watcher thread notices the flag
/// and runs the blocking drain conversation while the daemon thread keeps
/// answering the coordinator's migration pulls. A worker with no
/// coordinator to talk to (static fleet, no `--join`) just exits cleanly
/// and lets lineage recovery absorb the loss, same as a crash.
#[cfg(unix)]
fn install_sigterm_drain(coordinator: Option<String>, me: String) {
    use std::sync::atomic::{AtomicBool, Ordering};
    static TERM: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    extern "C" {
        // Raw libc symbol so we need no signal-handling crate; the
        // sighandler_t return value is pointer-sized and unused.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term);
    }
    std::thread::spawn(move || {
        use std::io::Write as _;
        while !TERM.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        if let Some(coordinator) = &coordinator {
            println!("DRAINING {me}");
            let _ = std::io::stdout().flush();
            match rustdslib::tasking::cluster::request_drain(coordinator, &me) {
                Ok(()) => println!("DRAINED {me}"),
                Err(e) => eprintln!("drain via {coordinator} failed: {e:#}"),
            }
        }
        let _ = std::io::stdout().flush();
        std::process::exit(0);
    });
}

fn bench(args: &Args) -> Result<()> {
    let mut cfg = Config::resolve(args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768];
    }
    let fig = args.get_str("fig", "all");
    let iters = args.get_usize("iters", 10);
    if fig == "6" || fig == "all" {
        print!("{}", experiments::fig6_strong(&cfg, 768)?.render());
        print!("{}", experiments::fig6_weak(&cfg)?.render());
    }
    if fig == "7" || fig == "all" {
        print!("{}", experiments::fig7_als(&cfg, args.get_usize("grid", 192), iters)?.render());
    }
    if fig == "8" || fig == "all" {
        let mut c8 = cfg.clone();
        if args.get("cores").is_none() {
            c8.sim_cores.push(1536);
        }
        print!("{}", experiments::fig8_shuffle(&c8)?.render());
    }
    if fig == "9" || fig == "all" {
        let mut c9 = cfg.clone();
        if args.get("cores").is_none() {
            c9.sim_cores.push(1536);
        }
        print!("{}", experiments::fig9_kmeans(&c9, args.get_usize("kmeans-iters", 5))?.render());
    }
    if fig == "tasks" || fig == "all" {
        let rows = experiments::task_count_table(&cfg, &[8, 32, 128, 512])?;
        let kv: Vec<(String, String)> = rows
            .iter()
            .map(|(n, dtr, atr, dsh, ash, ashn)| {
                (
                    format!("N={n}"),
                    format!(
                        "transpose {dtr} vs {atr}; shuffle {dsh} vs {ash} (nocoll {ashn})"
                    ),
                )
            })
            .collect();
        print!("{}", report::kv_table("task counts (Dataset vs ds-array)", &kv));
    }
    Ok(())
}

fn ablation(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    match args.get_str("which", "collections") {
        "blocks" => {
            let rows = experiments::ablation_blocks(
                &cfg,
                &args.get_usize_list("grids", &[24, 48, 96, 192]),
                args.get_usize("iters", 3),
            )?;
            for (g, t, tasks) in rows {
                println!("grid {g:>4} ({:>6} blocks): {t:>10.2}s, {tasks} tasks", g * g);
            }
        }
        _ => {
            let rows = experiments::ablation_collections(&cfg)?;
            for (cores, w, wo, tw, two) in rows {
                println!(
                    "{cores:>5} cores: with {w:>9.2}s/{tw} tasks, without {wo:>9.2}s/{two} tasks ({:.1}x)",
                    wo / w
                );
            }
        }
    }
    Ok(())
}

/// Measure real per-task latencies on the local executor — the numbers the
/// cost model's worker-side constants are sanity-checked against.
fn calibrate(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let rt = Runtime::builder()
        .workers(cfg.local_workers)
        .optimizer(cfg.optimizer)
        .build()?;
    let t0 = std::time::Instant::now();
    let a = creation::random(&rt, (2048, 512), (128, 128), cfg.seed)?;
    rt.barrier()?;
    let create_s = t0.elapsed().as_secs_f64();
    let n_create = rt.metrics().total_tasks();

    let t0 = std::time::Instant::now();
    a.transpose()?;
    rt.barrier()?;
    let tr_s = t0.elapsed().as_secs_f64();

    let rows = vec![
        (
            "create 2048x512 / 128² blocks".to_string(),
            format!("{create_s:.3}s ({:.2} ms/task)", 1e3 * create_s / n_create as f64),
        ),
        ("transpose (16 row tasks)".to_string(), format!("{tr_s:.3}s")),
        (
            "local per-task overhead".to_string(),
            format!("{:.3} ms", 1e3 * tr_s / 16.0),
        ),
        (
            "sim master_task_s @48 cores".to_string(),
            format!("{:.3} ms (calibrated to paper)", 1e3 * cfg.sim_at(48).master_task_s()),
        ),
    ];
    print!("{}", report::kv_table("calibration", &rows));
    Ok(())
}

fn demo(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let rt = Runtime::builder().from_config(&cfg).build()?;
    if rt.is_sim() {
        println!("demo needs a value-producing backend; use --backend local|cluster");
        return Ok(());
    }
    if let Some(control) = rt.cluster_control_addr() {
        // Printed so operators can grow the fleet mid-run:
        // `dsarray worker --join <this address>`.
        println!("control: {control}");
    }
    let a = creation::random(&rt, (256, 128), (64, 64), cfg.seed)?;
    let expr = a.transpose()?.norm_axis(1)?.pow(2.0)?.sqrt()?;
    let v = expr.collect()?;
    println!(
        "demo: sqrt(||Aᵀ||²) over random 256x128 -> first values {:.3} {:.3} {:.3}",
        v.get(0, 0),
        v.get(0, 1),
        v.get(0, 2)
    );
    // A full estimator fit on the selected backend — the CI cluster-smoke
    // job drives this through `--backend cluster` against live workers.
    let x = creation::random(&rt, (240, 16), (48, 16), cfg.seed)?;
    let mut km = KMeans::new(KMeansConfig {
        k: 4,
        max_iter: 5,
        tol: 1e-6,
        seed: cfg.seed,
    });
    km.fit_dsarray(&x)?;
    println!("kmeans: k=4 on 240x16 -> inertia {:.4} after {} iters", km.inertia, km.n_iter);
    println!("metrics: {}", report::metrics_json(&rt.metrics()));
    println!(
        "pjrt: {}",
        if rustdslib::runtime::global().is_some() { "available" } else { "artifacts not built" }
    );
    Ok(())
}

/// Fit an estimator on deterministic synthetic data and persist it as a
/// DSMA artifact — the producer half of the serve/predict pair, and what
/// the CI serving-smoke lane runs to get a model on disk. Blocks span the
/// full feature width so that served predictions stay bit-identical to the
/// batch path (see `docs/SERVING.md`).
fn fit(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let rt = Runtime::builder().from_config(&cfg).build()?;
    if rt.is_sim() {
        anyhow::bail!("fit needs a value-producing backend; use --backend local|cluster");
    }
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("fit needs --out <path> for the artifact"))?;
    let n = args.get_usize("rows", 256);
    let f = args.get_usize("features", 8);
    let br = args.get_usize("block-rows", 64).min(n.max(1));
    // Four well-separated blobs: meaningful for kmeans, harmless for the
    // rest, and fully reproducible from --seed.
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let xm = DenseMatrix::from_fn(n, f, |i, _| (i % 4) as f32 * 5.0 + rng.next_normal());
    let x = creation::from_matrix(&rt, &xm, (br, f))?;
    let artifact = match args.get_str("estimator", "kmeans") {
        "kmeans" => {
            let mut km = KMeans::new(KMeansConfig {
                k: args.get_usize("k", 4),
                max_iter: 10,
                tol: 1e-6,
                seed: cfg.seed,
            });
            km.fit_dsarray(&x)?;
            ModelArtifact::from_kmeans(&km)?
        }
        "linreg" => {
            let ym = DenseMatrix::from_fn(n, 1, |i, _| {
                (0..f).map(|j| xm.get(i, j)).sum::<f32>() * 0.5 + 0.7
            });
            let y = creation::from_matrix(&rt, &ym, (br, 1))?;
            let mut lr = LinearRegression::default();
            lr.fit(&x, Some(&y))?;
            ModelArtifact::from_linreg(&lr)?
        }
        "scaler" => {
            let mut sc = StandardScaler::default();
            sc.fit(&x)?;
            ModelArtifact::from_scaler(&sc)?
        }
        "pca" => {
            let mut p = Pca::new(args.get_usize("components", 2).min(f));
            p.fit(&x, None)?;
            ModelArtifact::from_pca(&p)?
        }
        other => anyhow::bail!("unknown --estimator `{other}` (want kmeans|linreg|scaler|pca)"),
    };
    let bytes = artifact.save_path(out)?;
    println!("FITTED {} {n}x{f} -> {out} ({bytes} bytes)", artifact.kind_name());
    Ok(())
}

/// Serving coordinator: load DSMA artifacts, pin their parameters as
/// replicated runtime blocks, and answer `Predict` frames until a client
/// sends `Shutdown`. Prints `SERVING <addr>` once accepting (CI and tests
/// parse it — port 0 picks a free port) and a final metrics line on exit.
fn serve(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let rt = Runtime::builder().from_config(&cfg).build()?;
    if rt.is_sim() {
        anyhow::bail!("serve needs a value-producing backend; use --backend local|cluster");
    }
    let spec = args
        .get("models")
        .ok_or_else(|| anyhow::anyhow!("serve needs --models name=path[,name=path]"))?;
    let server = ModelServer::new(rt.clone(), cfg.serve_options());
    for part in spec.split(',') {
        let (name, path) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad --models entry `{part}` (want name=path)"))?;
        server.register(name, ModelArtifact::load_path(path)?)?;
        println!("MODEL {name} <- {path}");
    }
    let listener = std::net::TcpListener::bind(args.get_str("listen", "127.0.0.1:0"))?;
    let handle = server.serve(listener)?;
    println!("SERVING {}", handle.addr());
    if let Some(control) = rt.cluster_control_addr() {
        println!("control: {control}");
    }
    use std::io::Write as _;
    std::io::stdout().flush()?;
    while !handle.is_shut_down() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("metrics: {}", report::metrics_json(&handle.metrics()));
    Ok(())
}

/// One-shot serving client: score `--rows "v,v;v,v"` against a running
/// server row by row (each row is one request, so concurrent invocations
/// exercise the micro-batcher), printing `PREDICTION <vals>` or `SHED
/// <reason>` per row. `--shutdown` ends the server afterwards — with no
/// --model it only shuts down.
fn predict(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("predict needs --addr <host:port>"))?;
    let mut client = ServingClient::connect(addr)?;
    if let Some(model) = args.get("model") {
        let rows = parse_rows(
            args.get("rows")
                .ok_or_else(|| anyhow::anyhow!("predict needs --rows \"v,v;v,v\""))?,
        )?;
        for i in 0..rows.rows() {
            let row = rows.slice(i, 0, 1, rows.cols())?;
            match client.predict(model, &row)? {
                PredictOutcome::Predicted(out) => {
                    let vals: Vec<String> =
                        (0..out.cols()).map(|j| format!("{:.6}", out.get(0, j))).collect();
                    println!("PREDICTION {}", vals.join(","));
                }
                PredictOutcome::Shed(reason) => println!("SHED {reason}"),
            }
        }
    }
    if args.get("shutdown").is_some() {
        client.shutdown()?;
        println!("SHUTDOWN {addr}");
    }
    Ok(())
}

/// Parse a `"1,2;3,4"` rows spec into a dense matrix (rows split on `;`,
/// values on `,`; all rows must have the same width).
fn parse_rows(spec: &str) -> Result<DenseMatrix> {
    let rows: Vec<Vec<f32>> = spec
        .split(';')
        .map(|r| {
            r.split(',')
                .map(|v| {
                    v.trim()
                        .parse::<f32>()
                        .map_err(|e| anyhow::anyhow!("bad value `{v}` in --rows: {e}"))
                })
                .collect::<Result<Vec<f32>>>()
        })
        .collect::<Result<Vec<Vec<f32>>>>()?;
    let width = rows.first().map(|r| r.len()).unwrap_or(0);
    if width == 0 || rows.iter().any(|r| r.len() != width) {
        anyhow::bail!("--rows must be non-empty and rectangular, e.g. \"1,2;3,4\"");
    }
    Ok(DenseMatrix::from_fn(rows.len(), width, |i, j| rows[i][j]))
}
