//! `dsarray` — CLI for the ds-array reproduction.
//!
//! Subcommands:
//!   version                       build info
//!   bench --fig 6|7|8|9|tasks|all paper-figure reproductions (simulated cluster)
//!   ablation --which blocks|collections
//!   calibrate                     local micro-measurements feeding the cost model
//!   demo                          end-to-end sanity run (expr chain + KMeans fit)
//!   worker --listen <addr>        cluster worker daemon (block storage over TCP)
//!
//! Global flags: --config <toml>, --cores a,b,c, --seed, --workers,
//! --backend local|sim|cluster, --cluster-workers N,
//! --cluster-addr host:port,…, --no-recovery, --replicate-blocks k,
//! --heartbeat-ms N, --straggler-factor F, and the sim.* overrides (see
//! config.rs). The worker subcommand also takes --fault-plan <spec>
//! (deterministic chaos, e.g. `die@7`, `slow@3`) and --join
//! <coordinator-addr> to enroll into a running fleet; `worker --drain
//! <worker-addr> --join <coordinator-addr>` sends a one-shot graceful
//! decommission request instead of starting a daemon.

use anyhow::Result;

use rustdslib::bench::{experiments, report};
use rustdslib::config::Config;
use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::tasking::{Runtime, WorkerOptions};
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("version") => {
            println!("rustdslib {} — ds-array (CS.DC 2021) reproduction", env!("CARGO_PKG_VERSION"));
        }
        Some("bench") => bench(&args)?,
        Some("ablation") => ablation(&args)?,
        Some("calibrate") => calibrate(&args)?,
        Some("demo") => demo(&args)?,
        Some("worker") => worker(&args)?,
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand `{cmd}`\n");
            }
            eprintln!("usage: dsarray <version|bench|ablation|calibrate|demo|worker> [flags]");
            eprintln!("  dsarray bench --fig all");
            eprintln!("  dsarray bench --fig 6 --cores 48,96,192");
            eprintln!("  dsarray ablation --which collections");
            eprintln!("  dsarray worker --listen 127.0.0.1:7401");
            eprintln!("  dsarray worker --join <coordinator-addr>        (enroll into a running fleet)");
            eprintln!("  dsarray worker --drain 127.0.0.1:7401 --join <coordinator-addr>");
            eprintln!("  dsarray demo --backend cluster --cluster-addr 127.0.0.1:7401,127.0.0.1:7402");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Cluster worker daemon: bind, announce `LISTENING <addr>` on stdout (the
/// coordinator and CI parse it — port 0 picks a free port), then serve
/// blocks until a Shutdown frame or SIGKILL. With `--join
/// <coordinator-addr>` the worker also enrolls itself into the running
/// fleet; with `--drain <worker-addr>` no daemon starts at all — the
/// process just asks the coordinator to decommission that member and
/// exits.
fn worker(args: &Args) -> Result<()> {
    if let Some(target) = args.get("drain") {
        let coordinator = args.get("join").ok_or_else(|| {
            anyhow::anyhow!("--drain needs --join <coordinator-addr> to send the request to")
        })?;
        rustdslib::tasking::cluster::request_drain(coordinator, target)?;
        println!("DRAINED {target}");
        return Ok(());
    }
    let listen = args.get_str("listen", "127.0.0.1:0");
    // A malformed budget must be a startup error, not a silently unbounded
    // worker that OOMs mid-run far from the configuration mistake.
    let budget = match (args.get("memory-budget-bytes"), args.get("memory-budget-mb")) {
        (Some(v), _) => Some(
            v.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad --memory-budget-bytes `{v}`: {e}"))?,
        ),
        (None, Some(v)) => Some(
            v.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad --memory-budget-mb `{v}`: {e}"))?
                * 1024
                * 1024,
        ),
        (None, None) => None,
    };
    let listener = std::net::TcpListener::bind(listen)?;
    println!("LISTENING {}", listener.local_addr()?);
    use std::io::Write as _;
    std::io::stdout().flush()?;
    if let Some(coordinator) = args.get("join") {
        // The coordinator's enroll path connects back and pings this
        // worker before acknowledging, so the join request must go out
        // while the daemon below is already accepting — hence the thread.
        // A refused join kills the process: an unenrolled daemon nobody
        // knows about is an orphan, not a worker.
        let coordinator = coordinator.to_string();
        let me = listener.local_addr()?.to_string();
        std::thread::spawn(move || {
            match rustdslib::tasking::cluster::request_join(&coordinator, &me) {
                Ok(()) => {
                    println!("JOINED {coordinator}");
                    let _ = std::io::stdout().flush();
                }
                Err(e) => {
                    eprintln!("join via {coordinator} failed: {e:#}");
                    std::process::exit(1);
                }
            }
        });
    }
    rustdslib::tasking::cluster::serve_worker(
        listener,
        WorkerOptions {
            memory_budget_bytes: budget,
            // Deterministic fault schedule from the chaos harness
            // (`die@N` / `drop@N`, comma-separated).
            fault_spec: args.get("fault-plan").map(|s| s.to_string()),
            // Real worker daemons die for real: injected crashes exit the
            // process, SIGKILL-style.
            crash_exits: true,
        },
    )
}

fn bench(args: &Args) -> Result<()> {
    let mut cfg = Config::resolve(args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768];
    }
    let fig = args.get_str("fig", "all");
    let iters = args.get_usize("iters", 10);
    if fig == "6" || fig == "all" {
        print!("{}", experiments::fig6_strong(&cfg, 768)?.render());
        print!("{}", experiments::fig6_weak(&cfg)?.render());
    }
    if fig == "7" || fig == "all" {
        print!("{}", experiments::fig7_als(&cfg, args.get_usize("grid", 192), iters)?.render());
    }
    if fig == "8" || fig == "all" {
        let mut c8 = cfg.clone();
        if args.get("cores").is_none() {
            c8.sim_cores.push(1536);
        }
        print!("{}", experiments::fig8_shuffle(&c8)?.render());
    }
    if fig == "9" || fig == "all" {
        let mut c9 = cfg.clone();
        if args.get("cores").is_none() {
            c9.sim_cores.push(1536);
        }
        print!("{}", experiments::fig9_kmeans(&c9, args.get_usize("kmeans-iters", 5))?.render());
    }
    if fig == "tasks" || fig == "all" {
        let rows = experiments::task_count_table(&cfg, &[8, 32, 128, 512])?;
        let kv: Vec<(String, String)> = rows
            .iter()
            .map(|(n, dtr, atr, dsh, ash, ashn)| {
                (
                    format!("N={n}"),
                    format!(
                        "transpose {dtr} vs {atr}; shuffle {dsh} vs {ash} (nocoll {ashn})"
                    ),
                )
            })
            .collect();
        print!("{}", report::kv_table("task counts (Dataset vs ds-array)", &kv));
    }
    Ok(())
}

fn ablation(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    match args.get_str("which", "collections") {
        "blocks" => {
            let rows = experiments::ablation_blocks(
                &cfg,
                &args.get_usize_list("grids", &[24, 48, 96, 192]),
                args.get_usize("iters", 3),
            )?;
            for (g, t, tasks) in rows {
                println!("grid {g:>4} ({:>6} blocks): {t:>10.2}s, {tasks} tasks", g * g);
            }
        }
        _ => {
            let rows = experiments::ablation_collections(&cfg)?;
            for (cores, w, wo, tw, two) in rows {
                println!(
                    "{cores:>5} cores: with {w:>9.2}s/{tw} tasks, without {wo:>9.2}s/{two} tasks ({:.1}x)",
                    wo / w
                );
            }
        }
    }
    Ok(())
}

/// Measure real per-task latencies on the local executor — the numbers the
/// cost model's worker-side constants are sanity-checked against.
fn calibrate(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let rt = Runtime::local(cfg.local_workers);
    let t0 = std::time::Instant::now();
    let a = creation::random(&rt, (2048, 512), (128, 128), cfg.seed)?;
    rt.barrier()?;
    let create_s = t0.elapsed().as_secs_f64();
    let n_create = rt.metrics().total_tasks();

    let t0 = std::time::Instant::now();
    a.transpose()?;
    rt.barrier()?;
    let tr_s = t0.elapsed().as_secs_f64();

    let rows = vec![
        (
            "create 2048x512 / 128² blocks".to_string(),
            format!("{create_s:.3}s ({:.2} ms/task)", 1e3 * create_s / n_create as f64),
        ),
        ("transpose (16 row tasks)".to_string(), format!("{tr_s:.3}s")),
        (
            "local per-task overhead".to_string(),
            format!("{:.3} ms", 1e3 * tr_s / 16.0),
        ),
        (
            "sim master_task_s @48 cores".to_string(),
            format!("{:.3} ms (calibrated to paper)", 1e3 * cfg.sim_at(48).master_task_s()),
        ),
    ];
    print!("{}", report::kv_table("calibration", &rows));
    Ok(())
}

fn demo(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let rt = cfg.runtime()?;
    if rt.is_sim() {
        println!("demo needs a value-producing backend; use --backend local|cluster");
        return Ok(());
    }
    if let Some(control) = rt.cluster_control_addr() {
        // Printed so operators can grow the fleet mid-run:
        // `dsarray worker --join <this address>`.
        println!("control: {control}");
    }
    let a = creation::random(&rt, (256, 128), (64, 64), cfg.seed)?;
    let expr = a.transpose()?.norm_axis(1)?.pow(2.0)?.sqrt()?;
    let v = expr.collect()?;
    println!(
        "demo: sqrt(||Aᵀ||²) over random 256x128 -> first values {:.3} {:.3} {:.3}",
        v.get(0, 0),
        v.get(0, 1),
        v.get(0, 2)
    );
    // A full estimator fit on the selected backend — the CI cluster-smoke
    // job drives this through `--backend cluster` against live workers.
    let x = creation::random(&rt, (240, 16), (48, 16), cfg.seed)?;
    let mut km = KMeans::new(KMeansConfig {
        k: 4,
        max_iter: 5,
        tol: 1e-6,
        seed: cfg.seed,
    });
    km.fit_dsarray(&x)?;
    println!("kmeans: k=4 on 240x16 -> inertia {:.4} after {} iters", km.inertia, km.n_iter);
    println!("metrics: {}", report::metrics_json(&rt.metrics()));
    println!(
        "pjrt: {}",
        if rustdslib::runtime::global().is_some() { "available" } else { "artifacts not built" }
    );
    Ok(())
}
