//! File loaders/writers — the `load_txt` / SVMLight equivalents of dislib's
//! data-loading routines (paper §3.2.1). CSV maps to dense blocks; SVMLight
//! (`label idx:val idx:val ...`) maps to CSR + a label column.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::dense::DenseMatrix;
use super::sparse::CsrMatrix;

/// Read a delimiter-separated numeric file into a dense matrix.
pub fn read_csv(path: &Path, delimiter: char) -> Result<DenseMatrix> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut data = Vec::new();
    let mut cols = None;
    let mut rows = 0;
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut n = 0;
        for field in line.split(delimiter) {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let v: f32 = field
                .parse()
                .with_context(|| format!("{}:{}: bad number `{field}`", path.display(), lineno + 1))?;
            data.push(v);
            n += 1;
        }
        match cols {
            None => cols = Some(n),
            Some(c) if c != n => bail!(
                "{}:{}: ragged row ({n} fields, expected {c})",
                path.display(),
                lineno + 1
            ),
            _ => {}
        }
        rows += 1;
    }
    let cols = cols.unwrap_or(0);
    DenseMatrix::from_vec(rows, cols, data)
}

pub fn write_csv(path: &Path, m: &DenseMatrix, delimiter: char) -> Result<()> {
    let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..m.rows() {
        let row = m.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                write!(w, "{delimiter}")?;
            }
            write!(w, "{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Read an SVMLight file: returns (samples as CSR, labels as n x 1 dense).
/// `n_features` fixes the column count (features are 1-based in the format).
pub fn read_svmlight(path: &Path, n_features: usize) -> Result<(CsrMatrix, DenseMatrix)> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut triplets = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f32 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("{}:{}: bad label", path.display(), lineno + 1))?;
        let row = labels.len();
        labels.push(label);
        for p in parts {
            let (idx, val) = p
                .split_once(':')
                .with_context(|| format!("{}:{}: bad feature `{p}`", path.display(), lineno + 1))?;
            let idx: usize = idx.parse().context("feature index")?;
            let val: f32 = val.parse().context("feature value")?;
            if idx == 0 || idx > n_features {
                bail!(
                    "{}:{}: feature index {idx} out of range 1..={n_features}",
                    path.display(),
                    lineno + 1
                );
            }
            triplets.push((row, idx - 1, val));
        }
    }
    let n = labels.len();
    let samples = CsrMatrix::from_triplets(n, n_features, &triplets)?;
    let labels = DenseMatrix::from_vec(n, 1, labels)?;
    Ok((samples, labels))
}

pub fn write_svmlight(path: &Path, samples: &CsrMatrix, labels: &DenseMatrix) -> Result<()> {
    if labels.rows() != samples.rows() || labels.cols() != 1 {
        bail!(
            "labels must be {}x1, got {}x{}",
            samples.rows(),
            labels.rows(),
            labels.cols()
        );
    }
    let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..samples.rows() {
        write!(w, "{}", labels.get(i, 0))?;
        let (cols, vals) = samples.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            write!(w, " {}:{}", c + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_io_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn csv_round_trip() {
        let m = DenseMatrix::from_fn(3, 4, |i, j| i as f32 * 0.5 - j as f32);
        let p = tmp("rt.csv");
        write_csv(&p, &m, ',').unwrap();
        let r = read_csv(&p, ',').unwrap();
        assert_eq!(r, m);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_skips_comments_rejects_ragged() {
        let p = tmp("cmt.csv");
        std::fs::write(&p, "# header\n1,2\n3,4\n").unwrap();
        let m = read_csv(&p, ',').unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p, ',').is_err());
        std::fs::write(&p, "1,x\n").unwrap();
        assert!(read_csv(&p, ',').is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn svmlight_round_trip() {
        let samples =
            CsrMatrix::from_triplets(3, 5, &[(0, 0, 1.5), (0, 4, 2.0), (2, 2, -1.0)]).unwrap();
        let labels = DenseMatrix::from_vec(3, 1, vec![1.0, -1.0, 1.0]).unwrap();
        let p = tmp("rt.svm");
        write_svmlight(&p, &samples, &labels).unwrap();
        let (s, l) = read_svmlight(&p, 5).unwrap();
        assert_eq!(s.to_dense(), samples.to_dense());
        assert_eq!(l, labels);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn svmlight_rejects_bad_index() {
        let p = tmp("bad.svm");
        std::fs::write(&p, "1 6:2.0\n").unwrap();
        assert!(read_svmlight(&p, 5).is_err());
        std::fs::write(&p, "1 0:2.0\n").unwrap();
        assert!(read_svmlight(&p, 5).is_err());
        std::fs::remove_file(&p).ok();
    }
}
