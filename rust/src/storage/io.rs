//! File loaders/writers — the `load_txt` / SVMLight / NPY equivalents of
//! dislib's data-loading routines (paper §3.2.1). CSV maps to dense blocks;
//! SVMLight (`label idx:val idx:val ...`) maps to CSR + a label column; NPY
//! is the binary fast path (fixed row stride, exact byte-range splits).
//!
//! Besides the whole-file readers, this module provides the *partitioned*
//! primitives the parallel ds-array loaders (`crate::dsarray::io`) fan out
//! over: [`partition_lines`] scans a text file once with O(1) memory and
//! returns byte offsets at block-row boundaries, and the `*_range` readers
//! parse only their slice of the file — so ingestion parallelism equals the
//! block-row count and no single process ever materializes the full matrix.
//!
//! Float formatting: all writers go through [`fmt_f32`], which relies on
//! Rust's shortest-round-trip float `Display` — `write` then `read` returns
//! bit-identical finite values (locked in by property tests below).

use std::fmt::Write as FmtWrite;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::dense::DenseMatrix;
use super::sparse::CsrMatrix;

/// Format one `f32` with the shortest representation that parses back to
/// the same bits. Rust's float `Display` guarantees shortest-round-trip
/// output (and its `inf`/`-inf`/`NaN` spellings are accepted by
/// `f32::from_str`), so this is a thin, documented pin of that contract —
/// the writers below must never lose precision to a fixed digit count.
pub fn fmt_f32(out: &mut String, v: f32) {
    let _ = write!(out, "{v}");
}

/// Parse one CSV data line (already trimmed, non-empty, non-comment) into
/// `data`; returns the number of fields appended. Shared by the whole-file
/// and byte-range readers so both report identical line-numbered errors.
fn parse_csv_line(
    line: &str,
    delimiter: char,
    data: &mut Vec<f32>,
    path: &Path,
    lineno: usize,
) -> Result<usize> {
    let mut n = 0;
    for field in line.split(delimiter) {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        let v: f32 = field
            .parse()
            .with_context(|| format!("{}:{}: bad number `{field}`", path.display(), lineno))?;
        data.push(v);
        n += 1;
    }
    Ok(n)
}

/// Read a delimiter-separated numeric file into a dense matrix.
pub fn read_csv(path: &Path, delimiter: char) -> Result<DenseMatrix> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut data = Vec::new();
    let mut cols = None;
    let mut rows = 0;
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let n = parse_csv_line(line, delimiter, &mut data, path, lineno + 1)?;
        match cols {
            None => cols = Some(n),
            Some(c) if c != n => bail!(
                "{}:{}: ragged row ({n} fields, expected {c})",
                path.display(),
                lineno + 1
            ),
            _ => {}
        }
        rows += 1;
    }
    let cols = cols.unwrap_or(0);
    DenseMatrix::from_vec(rows, cols, data)
}

pub fn write_csv(path: &Path, m: &DenseMatrix, delimiter: char) -> Result<()> {
    let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    let mut line = String::new();
    for i in 0..m.rows() {
        line.clear();
        for (j, &v) in m.row(i).iter().enumerate() {
            if j > 0 {
                line.push(delimiter);
            }
            fmt_f32(&mut line, v);
        }
        line.push('\n');
        w.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// One block-row's slice of a partitioned text file: where its first data
/// line starts, how many data lines it holds, and the 1-based file line
/// number of its first line (for error reporting inside range readers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinePartition {
    pub offset: u64,
    pub rows: usize,
    pub lineno: usize,
}

/// Scan a text file once (streaming, O(1) memory) and split its *data*
/// lines — non-empty, first non-whitespace char not `#`, matching the
/// skip rules of [`read_csv`]/[`read_svmlight`] — into partitions of
/// `rows_per_chunk` lines. Returns one [`LinePartition`] per block-row;
/// only the last may be short. This is the master-side cost of a parallel
/// load: a byte scan, never a parse, never a materialization.
pub fn partition_lines(path: &Path, rows_per_chunk: usize) -> Result<Vec<LinePartition>> {
    if rows_per_chunk == 0 {
        bail!("rows_per_chunk must be positive");
    }
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::with_capacity(64 * 1024, file);
    let mut parts: Vec<LinePartition> = Vec::new();
    let mut pos = 0u64;
    let mut line_start = 0u64;
    let mut lineno = 1usize;
    let mut first_nonws: Option<u8> = None;
    let mut data_rows = 0usize;
    let finish_line = |parts: &mut Vec<LinePartition>,
                           line_start: u64,
                           lineno: usize,
                           first_nonws: Option<u8>,
                           data_rows: &mut usize| {
        let is_data = matches!(first_nonws, Some(c) if c != b'#');
        if is_data {
            if *data_rows % rows_per_chunk == 0 {
                parts.push(LinePartition {
                    offset: line_start,
                    rows: 0,
                    lineno,
                });
            }
            parts.last_mut().expect("pushed above or earlier").rows += 1;
            *data_rows += 1;
        }
    };
    loop {
        let buf = r.fill_buf()?;
        if buf.is_empty() {
            break;
        }
        let n = buf.len();
        for &b in buf {
            if b == b'\n' {
                finish_line(&mut parts, line_start, lineno, first_nonws, &mut data_rows);
                line_start = pos + 1;
                lineno += 1;
                first_nonws = None;
            } else if first_nonws.is_none() && !b.is_ascii_whitespace() {
                first_nonws = Some(b);
            }
            pos += 1;
        }
        r.consume(n);
    }
    // Final line without a trailing newline.
    if pos > line_start {
        finish_line(&mut parts, line_start, lineno, first_nonws, &mut data_rows);
    }
    Ok(parts)
}

/// Column count of the first data line (the shape probe of a parallel CSV
/// load — reads a few bytes, parses one line).
pub fn probe_csv_cols(path: &Path, delimiter: char) -> Result<usize> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut probe = Vec::new();
        return parse_csv_line(line, delimiter, &mut probe, path, lineno + 1);
    }
    Ok(0)
}

/// Parse `n_rows` data lines starting at byte `offset` (a line boundary
/// from [`partition_lines`]). `expect_cols` pins the width; `first_lineno`
/// is the 1-based file line number at `offset` so errors carry global
/// positions. This is the worker-side body of a parallel CSV load.
pub fn read_csv_range(
    path: &Path,
    offset: u64,
    n_rows: usize,
    delimiter: char,
    expect_cols: usize,
    first_lineno: usize,
) -> Result<DenseMatrix> {
    let mut file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    file.seek(SeekFrom::Start(offset))?;
    let mut data = Vec::with_capacity(n_rows * expect_cols);
    let mut rows = 0;
    for (k, line) in BufReader::new(file).lines().enumerate() {
        if rows == n_rows {
            break;
        }
        let line = line?;
        let line = line.trim();
        let lineno = first_lineno + k;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let n = parse_csv_line(line, delimiter, &mut data, path, lineno)?;
        if n != expect_cols {
            bail!(
                "{}:{}: ragged row ({n} fields, expected {expect_cols})",
                path.display(),
                lineno
            );
        }
        rows += 1;
    }
    if rows != n_rows {
        bail!(
            "{}: range at byte {offset} ended after {rows} data rows, expected {n_rows}",
            path.display()
        );
    }
    DenseMatrix::from_vec(n_rows, expect_cols, data)
}

/// Parse one SVMLight data line (comment-stripped, non-empty): returns the
/// label and appends `(row, col, val)` triplets. All errors carry
/// `path:lineno`; feature indices are validated against `1..=n_features`
/// (out-of-range indices are a hard, line-numbered error — never a silent
/// out-of-bounds write).
fn parse_svmlight_line(
    line: &str,
    path: &Path,
    lineno: usize,
    n_features: usize,
    row: usize,
    triplets: &mut Vec<(usize, usize, f32)>,
) -> Result<f32> {
    let mut parts = line.split_whitespace();
    let label: f32 = parts
        .next()
        .expect("caller passes non-empty lines")
        .parse()
        .with_context(|| format!("{}:{}: bad label", path.display(), lineno))?;
    for p in parts {
        let (idx, val) = p.split_once(':').with_context(|| {
            format!("{}:{}: bad feature `{p}` (expected idx:val)", path.display(), lineno)
        })?;
        let idx: usize = idx.parse().with_context(|| {
            format!("{}:{}: bad feature index `{idx}`", path.display(), lineno)
        })?;
        let val: f32 = val.parse().with_context(|| {
            format!("{}:{}: bad feature value `{val}`", path.display(), lineno)
        })?;
        if idx == 0 || idx > n_features {
            bail!(
                "{}:{}: feature index {idx} out of range 1..={n_features}",
                path.display(),
                lineno
            );
        }
        triplets.push((row, idx - 1, val));
    }
    Ok(label)
}

/// Read an SVMLight file: returns (samples as CSR, labels as n x 1 dense).
/// `n_features` fixes the column count (features are 1-based in the format).
pub fn read_svmlight(path: &Path, n_features: usize) -> Result<(CsrMatrix, DenseMatrix)> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut triplets = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let row = labels.len();
        labels.push(parse_svmlight_line(
            line,
            path,
            lineno + 1,
            n_features,
            row,
            &mut triplets,
        )?);
    }
    let n = labels.len();
    let samples = CsrMatrix::from_triplets(n, n_features, &triplets)?;
    let labels = DenseMatrix::from_vec(n, 1, labels)?;
    Ok((samples, labels))
}

/// Parse `n_rows` SVMLight data lines starting at byte `offset` (from
/// [`partition_lines`]) — the worker-side body of a parallel SVMLight load.
pub fn read_svmlight_range(
    path: &Path,
    offset: u64,
    n_rows: usize,
    n_features: usize,
    first_lineno: usize,
) -> Result<(CsrMatrix, DenseMatrix)> {
    let mut file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    file.seek(SeekFrom::Start(offset))?;
    let mut triplets = Vec::new();
    let mut labels = Vec::with_capacity(n_rows);
    for (k, line) in BufReader::new(file).lines().enumerate() {
        if labels.len() == n_rows {
            break;
        }
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let row = labels.len();
        labels.push(parse_svmlight_line(
            line,
            path,
            first_lineno + k,
            n_features,
            row,
            &mut triplets,
        )?);
    }
    if labels.len() != n_rows {
        bail!(
            "{}: range at byte {offset} ended after {} data rows, expected {n_rows}",
            path.display(),
            labels.len()
        );
    }
    let samples = CsrMatrix::from_triplets(n_rows, n_features, &triplets)?;
    let labels = DenseMatrix::from_vec(n_rows, 1, labels)?;
    Ok((samples, labels))
}

pub fn write_svmlight(path: &Path, samples: &CsrMatrix, labels: &DenseMatrix) -> Result<()> {
    if labels.rows() != samples.rows() || labels.cols() != 1 {
        bail!(
            "labels must be {}x1, got {}x{}",
            samples.rows(),
            labels.rows(),
            labels.cols()
        );
    }
    let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    let mut line = String::new();
    for i in 0..samples.rows() {
        line.clear();
        fmt_f32(&mut line, labels.get(i, 0));
        let (cols, vals) = samples.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let _ = write!(line, " {}:", c + 1);
            fmt_f32(&mut line, v);
        }
        line.push('\n');
        w.write_all(line.as_bytes())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// NPY — NumPy's binary array format (v1.0 headers, C-order f4/f8).
// ---------------------------------------------------------------------------

/// Parsed `.npy` header: logical shape, element width, and the byte offset
/// where row-major data begins. Fixed row stride makes byte-range splits
/// exact — the parallel loader seeks straight to `data_offset + r0 * cols *
/// itemsize` with no master-side scan at all.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NpyHeader {
    pub rows: usize,
    pub cols: usize,
    /// Element type is little-endian f64 (`'<f8'`); otherwise f32 (`'<f4'`).
    pub f8: bool,
    pub data_offset: u64,
}

impl NpyHeader {
    pub fn itemsize(&self) -> usize {
        if self.f8 {
            8
        } else {
            4
        }
    }
}

fn npy_dict_field<'a>(dict: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("'{key}':");
    let at = dict
        .find(&pat)
        .with_context(|| format!("npy header missing `{key}`"))?;
    Ok(dict[at + pat.len()..].trim_start())
}

/// Read and validate a `.npy` header (format versions 1.0/2.0, C-order,
/// `<f4`/`<f8`). 1-D arrays are treated as a single column.
pub fn read_npy_header(path: &Path) -> Result<NpyHeader> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut head = [0u8; 8];
    r.read_exact(&mut head)
        .with_context(|| format!("{}: truncated npy preamble", path.display()))?;
    if &head[..6] != b"\x93NUMPY" {
        bail!("{}: not an npy file (bad magic)", path.display());
    }
    let (major, _minor) = (head[6], head[7]);
    let header_len = match major {
        1 => {
            let mut b = [0u8; 2];
            r.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 => {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => bail!("{}: unsupported npy format version {v}", path.display()),
    };
    let data_offset = if major == 1 { 10 } else { 12 } as u64 + header_len as u64;
    let mut dict = vec![0u8; header_len];
    r.read_exact(&mut dict)
        .with_context(|| format!("{}: truncated npy header", path.display()))?;
    let dict = std::str::from_utf8(&dict)
        .with_context(|| format!("{}: npy header is not ASCII", path.display()))?;

    let descr = npy_dict_field(dict, "descr")?;
    let f8 = if descr.starts_with("'<f4'") || descr.starts_with("'|f4'") {
        false
    } else if descr.starts_with("'<f8'") || descr.starts_with("'|f8'") {
        true
    } else {
        bail!(
            "{}: unsupported npy dtype {} (need '<f4' or '<f8')",
            path.display(),
            descr.split(',').next().unwrap_or(descr)
        );
    };
    let order = npy_dict_field(dict, "fortran_order")?;
    if !order.starts_with("False") {
        bail!("{}: fortran-order npy arrays are not supported", path.display());
    }
    let shape = npy_dict_field(dict, "shape")?;
    let open = shape
        .find('(')
        .with_context(|| format!("{}: npy shape is not a tuple", path.display()))?;
    let close = shape
        .find(')')
        .with_context(|| format!("{}: npy shape is not a tuple", path.display()))?;
    let dims: Vec<usize> = shape[open + 1..close]
        .split(',')
        .map(|d| d.trim())
        .filter(|d| !d.is_empty())
        .map(|d| {
            d.parse()
                .with_context(|| format!("{}: bad npy shape dim `{d}`", path.display()))
        })
        .collect::<Result<_>>()?;
    let (rows, cols) = match dims.len() {
        1 => (dims[0], 1),
        2 => (dims[0], dims[1]),
        n => bail!("{}: {n}-D npy arrays are not supported", path.display()),
    };
    Ok(NpyHeader {
        rows,
        cols,
        f8,
        data_offset,
    })
}

/// Read rows `[r0, r0 + nrows)` of an npy file as f32 (f8 files are
/// narrowed). Seeks directly to the row range — the worker-side body of the
/// parallel NPY load.
pub fn read_npy_rows(path: &Path, h: &NpyHeader, r0: usize, nrows: usize) -> Result<DenseMatrix> {
    if r0 + nrows > h.rows {
        bail!(
            "{}: npy row range [{r0}, {}) out of bounds for {} rows",
            path.display(),
            r0 + nrows,
            h.rows
        );
    }
    let mut file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    file.seek(SeekFrom::Start(
        h.data_offset + (r0 * h.cols * h.itemsize()) as u64,
    ))?;
    let n = nrows * h.cols;
    let mut raw = vec![0u8; n * h.itemsize()];
    file.read_exact(&mut raw)
        .with_context(|| format!("{}: truncated npy payload", path.display()))?;
    let data: Vec<f32> = if h.f8 {
        raw.chunks_exact(8)
            .map(|b| f64::from_le_bytes(b.try_into().unwrap()) as f32)
            .collect()
    } else {
        raw.chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect()
    };
    DenseMatrix::from_vec(nrows, h.cols, data)
}

/// Read a whole `.npy` file into a dense matrix.
pub fn read_npy(path: &Path) -> Result<DenseMatrix> {
    let h = read_npy_header(path)?;
    read_npy_rows(path, &h, 0, h.rows)
}

/// Create a `.npy` file: write a v1.0 `<f4` C-order header and pre-size the
/// file to its final length, so concurrent writers can then fill disjoint
/// row ranges in place ([`write_npy_rows_at`]) — the parallel save path.
/// Returns the data offset.
pub fn create_npy(path: &Path, rows: usize, cols: usize) -> Result<u64> {
    let mut dict = format!("{{'descr': '<f4', 'fortran_order': False, 'shape': ({rows}, {cols}), }}");
    // Pad with spaces so preamble + header is 64-byte aligned, newline-terminated.
    let unpadded = 10 + dict.len() + 1;
    dict.push_str(&" ".repeat(unpadded.div_ceil(64) * 64 - unpadded));
    dict.push('\n');
    if dict.len() > u16::MAX as usize {
        bail!("npy header too large");
    }
    let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(b"\x93NUMPY\x01\x00")?;
    w.write_all(&(dict.len() as u16).to_le_bytes())?;
    w.write_all(dict.as_bytes())?;
    w.flush()?;
    let data_offset = 10 + dict.len() as u64;
    w.get_ref().set_len(data_offset + (rows * cols * 4) as u64)?;
    Ok(data_offset)
}

/// Write `m` as rows `[r0, r0 + m.rows())` of a pre-sized npy file created
/// by [`create_npy`] with shape `(rows, cols)`. Disjoint row ranges may be
/// written concurrently; ranges past the declared shape are an error (the
/// header would silently hide them).
pub fn write_npy_rows_at(
    path: &Path,
    data_offset: u64,
    rows: usize,
    cols: usize,
    r0: usize,
    m: &DenseMatrix,
) -> Result<()> {
    if m.cols() != cols {
        bail!("npy row panel has {} cols, file has {cols}", m.cols());
    }
    if r0 + m.rows() > rows {
        bail!(
            "npy row range [{r0}, {}) out of bounds for {rows} rows",
            r0 + m.rows()
        );
    }
    let mut file = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("opening {} for writing", path.display()))?;
    file.seek(SeekFrom::Start(data_offset + (r0 * cols * 4) as u64))?;
    let mut w = BufWriter::new(file);
    super::store::write_f32s(&mut w, m.data())?;
    w.flush()?;
    Ok(())
}

/// Write a whole matrix as a `.npy` file (v1.0, `<f4`, C-order).
pub fn write_npy(path: &Path, m: &DenseMatrix) -> Result<()> {
    let off = create_npy(path, m.rows(), m.cols())?;
    write_npy_rows_at(path, off, m.rows(), m.cols(), 0, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_io_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn csv_round_trip() {
        let m = DenseMatrix::from_fn(3, 4, |i, j| i as f32 * 0.5 - j as f32);
        let p = tmp("rt.csv");
        write_csv(&p, &m, ',').unwrap();
        let r = read_csv(&p, ',').unwrap();
        assert_eq!(r, m);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_skips_comments_rejects_ragged() {
        let p = tmp("cmt.csv");
        std::fs::write(&p, "# header\n1,2\n3,4\n").unwrap();
        let m = read_csv(&p, ',').unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p, ',').is_err());
        std::fs::write(&p, "1,x\n").unwrap();
        assert!(read_csv(&p, ',').is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_write_read_round_trips_extreme_floats_property() {
        // Shortest-round-trip formatting must reproduce every finite f32
        // bit pattern exactly — subnormals, extremes, and negative zero.
        let p = tmp("prop.csv");
        prop::check("csv f32 round trip", |g| {
            let rows = g.usize_in(1, 5);
            let cols = g.usize_in(1, 5);
            let data: Vec<f32> = (0..rows * cols)
                .map(|_| match g.usize_in(0, 7) {
                    0 => f32::from_bits(1), // smallest subnormal
                    1 => f32::MAX,
                    2 => -f32::MIN_POSITIVE,
                    3 => 0.1,
                    4 => -0.0,
                    _ => f32::from_bits(g.rng.next_u64() as u32),
                })
                .map(|v| if v.is_nan() { 1.25 } else { v })
                .collect();
            let m = DenseMatrix::from_vec(rows, cols, data).unwrap();
            write_csv(&p, &m, ',').map_err(|e| e.to_string())?;
            let r = read_csv(&p, ',').map_err(|e| e.to_string())?;
            for (a, b) in m.data().iter().zip(r.data()) {
                crate::prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "wrote {a:?} ({:#010x}), read {b:?} ({:#010x})",
                    a.to_bits(),
                    b.to_bits()
                );
            }
            Ok(())
        });
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_round_trips_non_finite_values() {
        let p = tmp("nonfinite.csv");
        let m = DenseMatrix::from_vec(1, 3, vec![f32::INFINITY, f32::NEG_INFINITY, f32::NAN])
            .unwrap();
        write_csv(&p, &m, ',').unwrap();
        let r = read_csv(&p, ',').unwrap();
        assert_eq!(r.get(0, 0), f32::INFINITY);
        assert_eq!(r.get(0, 1), f32::NEG_INFINITY);
        assert!(r.get(0, 2).is_nan());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn svmlight_round_trip() {
        let samples =
            CsrMatrix::from_triplets(3, 5, &[(0, 0, 1.5), (0, 4, 2.0), (2, 2, -1.0)]).unwrap();
        let labels = DenseMatrix::from_vec(3, 1, vec![1.0, -1.0, 1.0]).unwrap();
        let p = tmp("rt.svm");
        write_svmlight(&p, &samples, &labels).unwrap();
        let (s, l) = read_svmlight(&p, 5).unwrap();
        assert_eq!(s.to_dense(), samples.to_dense());
        assert_eq!(l, labels);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn svmlight_write_read_round_trips_property() {
        let p = tmp("prop.svm");
        prop::check("svmlight f32 round trip", |g| {
            let rows = g.usize_in(1, 6);
            let cols = g.usize_in(1, 8);
            let nnz = g.usize_in(0, rows * cols);
            let trips: Vec<(usize, usize, f32)> = (0..nnz)
                .map(|_| {
                    let v = f32::from_bits(g.rng.next_u64() as u32);
                    (
                        g.usize_in(0, rows - 1),
                        g.usize_in(0, cols - 1),
                        if v.is_nan() { -0.5 } else { v },
                    )
                })
                .collect();
            let samples = CsrMatrix::from_triplets(rows, cols, &trips).unwrap();
            let labels =
                DenseMatrix::from_vec(rows, 1, g.f32_vec(rows, 1e30)).unwrap();
            write_svmlight(&p, &samples, &labels).map_err(|e| e.to_string())?;
            let (s, l) = read_svmlight(&p, cols).map_err(|e| e.to_string())?;
            let (da, db) = (samples.to_dense(), s.to_dense());
            for (a, b) in da.data().iter().zip(db.data()) {
                crate::prop_assert!(a.to_bits() == b.to_bits(), "sample {a:?} != {b:?}");
            }
            for (a, b) in labels.data().iter().zip(l.data()) {
                crate::prop_assert!(a.to_bits() == b.to_bits(), "label {a:?} != {b:?}");
            }
            Ok(())
        });
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn svmlight_rejects_bad_index_with_line_numbers() {
        let p = tmp("bad.svm");
        std::fs::write(&p, "1 1:1.0\n1 6:2.0\n").unwrap();
        let err = read_svmlight(&p, 5).unwrap_err().to_string();
        assert!(err.contains(":2"), "error should carry the line number: {err}");
        assert!(err.contains("out of range 1..=5"), "{err}");
        std::fs::write(&p, "1 0:2.0\n").unwrap();
        assert!(read_svmlight(&p, 5).is_err());
        // Unparsable index and value are line-numbered errors, not panics.
        std::fs::write(&p, "1 1:1.0\n1 x:2.0\n").unwrap();
        let err = read_svmlight(&p, 5).unwrap_err().to_string();
        assert!(err.contains(":2") && err.contains("bad feature index"), "{err}");
        std::fs::write(&p, "1 2:zz\n").unwrap();
        let err = read_svmlight(&p, 5).unwrap_err().to_string();
        assert!(err.contains(":1") && err.contains("bad feature value"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn partition_lines_splits_on_data_lines_only() {
        let p = tmp("parts.csv");
        std::fs::write(&p, "# header\n1,2\n3,4\n\n5,6\n# mid\n7,8\n9,10").unwrap();
        let parts = partition_lines(&p, 2).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].rows, 2);
        assert_eq!(parts[1].rows, 2);
        assert_eq!(parts[2].rows, 1); // final line has no trailing newline
        assert_eq!(parts[0].offset, 9); // after "# header\n"
        assert_eq!(parts[0].lineno, 2);
        assert_eq!(parts[1].lineno, 5); // "5,6" after the blank line
        // Ranges parse independently and agree with the whole-file read.
        let full = read_csv(&p, ',').unwrap();
        let mut r0 = 0;
        for part in &parts {
            let m = read_csv_range(&p, part.offset, part.rows, ',', 2, part.lineno).unwrap();
            assert_eq!(m, full.slice(r0, 0, part.rows, 2).unwrap());
            r0 += part.rows;
        }
        assert_eq!(probe_csv_cols(&p, ',').unwrap(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_range_errors_carry_global_line_numbers() {
        let p = tmp("rangeerr.csv");
        std::fs::write(&p, "1,2\n3,4\n5,x\n").unwrap();
        let parts = partition_lines(&p, 2).unwrap();
        let err = read_csv_range(&p, parts[1].offset, parts[1].rows, ',', 2, parts[1].lineno)
            .unwrap_err()
            .to_string();
        assert!(err.contains(":3"), "global line number expected: {err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_round_trip_and_row_ranges() {
        let p = tmp("rt.npy");
        let m = DenseMatrix::from_fn(7, 3, |i, j| (i * 3 + j) as f32 * 0.5 - 1.0);
        write_npy(&p, &m).unwrap();
        let h = read_npy_header(&p).unwrap();
        assert_eq!((h.rows, h.cols, h.f8), (7, 3, false));
        assert_eq!(read_npy(&p).unwrap(), m);
        let mid = read_npy_rows(&p, &h, 2, 4).unwrap();
        assert_eq!(mid, m.slice(2, 0, 4, 3).unwrap());
        assert!(read_npy_rows(&p, &h, 5, 3).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_parallel_style_writes_fill_disjoint_ranges() {
        let p = tmp("par.npy");
        let m = DenseMatrix::from_fn(6, 4, |i, j| (i * 4 + j) as f32);
        let off = create_npy(&p, 6, 4).unwrap();
        write_npy_rows_at(&p, off, 6, 4, 3, &m.slice(3, 0, 3, 4).unwrap()).unwrap();
        write_npy_rows_at(&p, off, 6, 4, 0, &m.slice(0, 0, 3, 4).unwrap()).unwrap();
        assert_eq!(read_npy(&p).unwrap(), m);
        // Writing past the declared shape is refused, not silently grown.
        assert!(write_npy_rows_at(&p, off, 6, 4, 5, &m.slice(0, 0, 3, 4).unwrap()).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_rejects_unsupported_layouts() {
        let p = tmp("bad.npy");
        std::fs::write(&p, b"NOTNPY\x01\x00").unwrap();
        assert!(read_npy_header(&p).is_err());
        // Fortran order is refused.
        let dict = "{'descr': '<f4', 'fortran_order': True, 'shape': (2, 2), }\n";
        let mut bytes = b"\x93NUMPY\x01\x00".to_vec();
        bytes.extend((dict.len() as u16).to_le_bytes());
        bytes.extend(dict.as_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_npy_header(&p).unwrap_err().to_string().contains("fortran"));
        // Unsupported dtype is refused.
        let dict = "{'descr': '<i8', 'fortran_order': False, 'shape': (2, 2), }\n";
        let mut bytes = b"\x93NUMPY\x01\x00".to_vec();
        bytes.extend((dict.len() as u16).to_le_bytes());
        bytes.extend(dict.as_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_npy_header(&p).unwrap_err().to_string().contains("dtype"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_f8_narrowing_read() {
        // Hand-built '<f8' file: 2x2 [1.5, -2.0, 0.25, 1e9].
        let p = tmp("f8.npy");
        let dict = "{'descr': '<f8', 'fortran_order': False, 'shape': (2, 2), }\n";
        let mut bytes = b"\x93NUMPY\x01\x00".to_vec();
        bytes.extend((dict.len() as u16).to_le_bytes());
        bytes.extend(dict.as_bytes());
        for v in [1.5f64, -2.0, 0.25, 1e9] {
            bytes.extend(v.to_le_bytes());
        }
        std::fs::write(&p, &bytes).unwrap();
        let m = read_npy(&p).unwrap();
        assert_eq!(m.data(), &[1.5, -2.0, 0.25, 1e9]);
        std::fs::remove_file(&p).ok();
    }
}
