//! The [`Block`] sum type moved around by the tasking runtime.
//!
//! A ds-array block is dense or CSR (paper §4.2). The third variant,
//! [`Block::Phantom`], carries only metadata and exists for the
//! discrete-event simulator: at MareNostrum scale (e.g. 5·10⁷×1 000 f32 =
//! 200 GB) the data cannot be materialized in this container, but the task
//! graphs must still be *built by the same library code*, so creation
//! routines produce phantom blocks in sim mode and every operation
//! propagates metadata through them (DESIGN.md §2).

use anyhow::{bail, Result};

use super::dense::DenseMatrix;
use super::sparse::CsrMatrix;

/// Shape + occupancy metadata; always available, even for phantom blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockMeta {
    pub rows: usize,
    pub cols: usize,
    /// Stored non-zeros; for dense blocks this is rows*cols.
    pub nnz: usize,
    pub sparse: bool,
}

impl BlockMeta {
    pub fn dense(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            nnz: rows * cols,
            sparse: false,
        }
    }

    pub fn sparse(rows: usize, cols: usize, nnz: usize) -> Self {
        Self {
            rows,
            cols,
            nnz,
            sparse: true,
        }
    }

    /// Payload size in bytes (dense: f32; CSR: data + indices + indptr).
    pub fn bytes(&self) -> usize {
        if self.sparse {
            self.nnz * (4 + 4) + (self.rows + 1) * 8
        } else {
            self.rows * self.cols * 4
        }
    }

    pub fn transposed(&self) -> Self {
        Self {
            rows: self.cols,
            cols: self.rows,
            ..*self
        }
    }
}

#[derive(Clone, Debug)]
pub enum Block {
    Dense(DenseMatrix),
    Csr(CsrMatrix),
    /// Metadata-only block for simulated executions.
    Phantom(BlockMeta),
}

impl Block {
    pub fn meta(&self) -> BlockMeta {
        match self {
            Block::Dense(m) => BlockMeta::dense(m.rows(), m.cols()),
            Block::Csr(m) => BlockMeta::sparse(m.rows(), m.cols(), m.nnz()),
            Block::Phantom(meta) => *meta,
        }
    }

    pub fn rows(&self) -> usize {
        self.meta().rows
    }

    pub fn cols(&self) -> usize {
        self.meta().cols
    }

    pub fn is_phantom(&self) -> bool {
        matches!(self, Block::Phantom(_))
    }

    pub fn is_sparse(&self) -> bool {
        self.meta().sparse
    }

    /// Borrow as dense; errors on CSR/phantom (callers that can handle both
    /// densities match on the enum instead).
    pub fn as_dense(&self) -> Result<&DenseMatrix> {
        match self {
            Block::Dense(m) => Ok(m),
            Block::Csr(_) => bail!("expected dense block, got CSR"),
            Block::Phantom(_) => bail!("expected dense block, got phantom (sim-mode data)"),
        }
    }

    pub fn as_csr(&self) -> Result<&CsrMatrix> {
        match self {
            Block::Csr(m) => Ok(m),
            Block::Dense(_) => bail!("expected CSR block, got dense"),
            Block::Phantom(_) => bail!("expected CSR block, got phantom (sim-mode data)"),
        }
    }

    /// Materialize as dense regardless of backend (errors on phantom).
    pub fn to_dense(&self) -> Result<DenseMatrix> {
        match self {
            Block::Dense(m) => Ok(m.clone()),
            Block::Csr(m) => Ok(m.to_dense()),
            Block::Phantom(_) => bail!("cannot densify a phantom block"),
        }
    }

    /// Transpose preserving backend; phantom transposes metadata.
    pub fn transpose(&self) -> Block {
        match self {
            Block::Dense(m) => Block::Dense(m.transpose()),
            Block::Csr(m) => Block::Csr(m.transpose()),
            Block::Phantom(meta) => Block::Phantom(meta.transposed()),
        }
    }

    /// Sub-matrix copy; phantom slices metadata (nnz scaled proportionally).
    pub fn slice(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Result<Block> {
        match self {
            Block::Dense(m) => Ok(Block::Dense(m.slice(r0, c0, nr, nc)?)),
            Block::Csr(m) => Ok(Block::Csr(m.slice(r0, c0, nr, nc)?)),
            Block::Phantom(meta) => {
                if r0 + nr > meta.rows || c0 + nc > meta.cols {
                    bail!(
                        "phantom slice [{r0}+{nr}, {c0}+{nc}) out of bounds for {}x{}",
                        meta.rows,
                        meta.cols
                    );
                }
                let frac = (nr * nc) as f64 / (meta.rows * meta.cols).max(1) as f64;
                let nnz = if meta.sparse {
                    (meta.nnz as f64 * frac).round() as usize
                } else {
                    nr * nc
                };
                Ok(Block::Phantom(BlockMeta {
                    rows: nr,
                    cols: nc,
                    nnz,
                    sparse: meta.sparse,
                }))
            }
        }
    }

    /// Gather arbitrary rows in index order, preserving backend (CSR stays
    /// CSR); phantom scales metadata like [`Block::slice`].
    pub fn take_rows(&self, idx: &[usize]) -> Result<Block> {
        match self {
            Block::Dense(m) => Ok(Block::Dense(m.take_rows(idx)?)),
            Block::Csr(m) => Ok(Block::Csr(m.take_rows(idx)?)),
            Block::Phantom(meta) => {
                for &i in idx {
                    if i >= meta.rows {
                        bail!("row index {i} out of bounds for {} rows", meta.rows);
                    }
                }
                let frac = idx.len() as f64 / meta.rows.max(1) as f64;
                let nnz = if meta.sparse {
                    (meta.nnz as f64 * frac).round() as usize
                } else {
                    idx.len() * meta.cols
                };
                Ok(Block::Phantom(BlockMeta {
                    rows: idx.len(),
                    cols: meta.cols,
                    nnz,
                    sparse: meta.sparse,
                }))
            }
        }
    }

    /// Gather arbitrary columns in index order, preserving backend.
    pub fn take_cols(&self, idx: &[usize]) -> Result<Block> {
        match self {
            Block::Dense(m) => Ok(Block::Dense(m.take_cols(idx)?)),
            Block::Csr(m) => Ok(Block::Csr(m.take_cols(idx)?)),
            Block::Phantom(meta) => {
                for &j in idx {
                    if j >= meta.cols {
                        bail!("column index {j} out of bounds for {} columns", meta.cols);
                    }
                }
                let frac = idx.len() as f64 / meta.cols.max(1) as f64;
                let nnz = if meta.sparse {
                    (meta.nnz as f64 * frac).round() as usize
                } else {
                    meta.rows * idx.len()
                };
                Ok(Block::Phantom(BlockMeta {
                    rows: meta.rows,
                    cols: idx.len(),
                    nnz,
                    sparse: meta.sparse,
                }))
            }
        }
    }
}

impl From<DenseMatrix> for Block {
    fn from(m: DenseMatrix) -> Self {
        Block::Dense(m)
    }
}

impl From<CsrMatrix> for Block {
    fn from(m: CsrMatrix) -> Self {
        Block::Csr(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_bytes() {
        let d = BlockMeta::dense(64, 64);
        assert_eq!(d.bytes(), 64 * 64 * 4);
        let s = BlockMeta::sparse(100, 1000, 1200);
        assert_eq!(s.bytes(), 1200 * 8 + 101 * 8);
        assert!(s.sparse && !d.sparse);
    }

    #[test]
    fn block_meta_from_backends() {
        let d = Block::from(DenseMatrix::zeros(3, 5));
        assert_eq!(d.meta(), BlockMeta::dense(3, 5));
        let c = Block::from(CsrMatrix::from_triplets(3, 5, &[(0, 0, 1.0)]).unwrap());
        assert_eq!(c.meta(), BlockMeta::sparse(3, 5, 1));
        let p = Block::Phantom(BlockMeta::dense(10, 10));
        assert_eq!(p.meta().rows, 10);
        assert!(p.is_phantom());
    }

    #[test]
    fn phantom_refuses_data_access() {
        let p = Block::Phantom(BlockMeta::dense(2, 2));
        assert!(p.as_dense().is_err());
        assert!(p.as_csr().is_err());
        assert!(p.to_dense().is_err());
    }

    #[test]
    fn transpose_preserves_backend() {
        let d = Block::from(DenseMatrix::from_fn(2, 3, |i, j| (i + j) as f32));
        assert!(matches!(d.transpose(), Block::Dense(_)));
        assert_eq!(d.transpose().rows(), 3);
        let p = Block::Phantom(BlockMeta::sparse(4, 7, 9)).transpose();
        assert_eq!(p.meta(), BlockMeta::sparse(7, 4, 9));
    }

    #[test]
    fn take_preserves_backend() {
        let d = Block::from(DenseMatrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32));
        let t = d.take_rows(&[2, 0]).unwrap();
        assert!(matches!(t, Block::Dense(_)));
        assert_eq!(t.to_dense().unwrap().row(0), d.as_dense().unwrap().row(2));
        let c = Block::from(CsrMatrix::from_triplets(3, 4, &[(1, 2, 5.0)]).unwrap());
        let tc = c.take_cols(&[2, 2, 0]).unwrap();
        assert!(matches!(tc, Block::Csr(_)));
        assert_eq!(tc.to_dense().unwrap().get(1, 0), 5.0);
        assert_eq!(tc.to_dense().unwrap().get(1, 1), 5.0);
        let p = Block::Phantom(BlockMeta::sparse(10, 10, 40)).take_rows(&[0, 1, 2, 3, 4]).unwrap();
        assert_eq!(p.meta(), BlockMeta::sparse(5, 10, 20));
        assert!(Block::Phantom(BlockMeta::dense(2, 2)).take_cols(&[2]).is_err());
    }

    #[test]
    fn phantom_slice_scales_nnz() {
        let p = Block::Phantom(BlockMeta::sparse(10, 10, 50));
        let s = p.slice(0, 0, 5, 10).unwrap();
        assert_eq!(s.meta().nnz, 25);
        assert!(p.slice(8, 0, 5, 10).is_err());
    }
}
