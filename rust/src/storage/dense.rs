//! Dense row-major f32 matrix — the NumPy-array block backend equivalent.
//!
//! This is deliberately a small, predictable type: contiguous `Vec<f32>`,
//! row-major, with the operations the ds-array layer and the estimators
//! need. The FLOP-heavy paths (matmul, K-means distance step) normally run
//! through the AOT-compiled Pallas kernels via PJRT (`crate::runtime`); the
//! implementations here are the native fallbacks and test oracles.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn full(rows: usize, cols: usize, value: f32) -> Self {
        Self {
            rows,
            cols,
            data: vec![value; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            bail!(
                "dense shape mismatch: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            );
        }
        Ok(Self { rows, cols, data })
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of the sub-matrix `[r0, r0+nr) x [c0, c0+nc)`.
    pub fn slice(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Result<Self> {
        if r0 + nr > self.rows || c0 + nc > self.cols {
            bail!(
                "slice [{r0}+{nr}, {c0}+{nc}) out of bounds for {}x{}",
                self.rows,
                self.cols
            );
        }
        let mut data = Vec::with_capacity(nr * nc);
        for i in r0..r0 + nr {
            data.extend_from_slice(&self.data[i * self.cols + c0..i * self.cols + c0 + nc]);
        }
        Ok(Self {
            rows: nr,
            cols: nc,
            data,
        })
    }

    /// Copy of arbitrary rows in index order (duplicates allowed) — the
    /// dense backend of ds-array fancy indexing.
    pub fn take_rows(&self, idx: &[usize]) -> Result<Self> {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            if i >= self.rows {
                bail!("row index {i} out of bounds for {} rows", self.rows);
            }
            data.extend_from_slice(self.row(i));
        }
        Ok(Self {
            rows: idx.len(),
            cols: self.cols,
            data,
        })
    }

    /// Copy of arbitrary columns in index order (duplicates allowed).
    pub fn take_cols(&self, idx: &[usize]) -> Result<Self> {
        for &j in idx {
            if j >= self.cols {
                bail!("column index {j} out of bounds for {} columns", self.cols);
            }
        }
        let mut data = Vec::with_capacity(idx.len() * self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            for &j in idx {
                data.push(row[j]);
            }
        }
        Ok(Self {
            rows: self.rows,
            cols: idx.len(),
            data,
        })
    }

    /// Write `src` into this matrix at offset (r0, c0).
    pub fn paste(&mut self, r0: usize, c0: usize, src: &DenseMatrix) -> Result<()> {
        if r0 + src.rows > self.rows || c0 + src.cols > self.cols {
            bail!(
                "paste of {}x{} at ({r0},{c0}) out of bounds for {}x{}",
                src.rows,
                src.cols,
                self.rows,
                self.cols
            );
        }
        for i in 0..src.rows {
            let dst = (r0 + i) * self.cols + c0;
            self.data[dst..dst + src.cols].copy_from_slice(src.row(i));
        }
        Ok(())
    }

    /// Zero-padded copy with the given (larger or equal) physical shape —
    /// used to bring edge blocks to the canonical AOT kernel shape.
    /// Already-canonical matrices are returned as a plain clone (§Perf:
    /// skips a zeros+paste pass on the PJRT hot path).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Result<Self> {
        if rows < self.rows || cols < self.cols {
            bail!(
                "pad_to target {rows}x{cols} smaller than {}x{}",
                self.rows,
                self.cols
            );
        }
        if (rows, cols) == (self.rows, self.cols) {
            return Ok(self.clone());
        }
        let mut out = Self::zeros(rows, cols);
        out.paste(0, 0, self)?;
        Ok(out)
    }

    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        // Blocked loop for cache friendliness on large blocks.
        const TB: usize = 32;
        for ib in (0..self.rows).step_by(TB) {
            for jb in (0..self.cols).step_by(TB) {
                for i in ib..(ib + TB).min(self.rows) {
                    for j in jb..(jb + TB).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Native matmul: `self (m,k) @ rhs (k,n)` — a zeroed accumulator fed
    /// through the tiled [`DenseMatrix::gemm_acc`] kernel; used as the
    /// fallback/oracle next to the PJRT gemm artifact.
    pub fn matmul(&self, rhs: &DenseMatrix) -> Result<Self> {
        if self.cols != rhs.rows {
            bail!(
                "matmul shape mismatch: {}x{} @ {}x{}",
                self.rows,
                self.cols,
                rhs.rows,
                rhs.cols
            );
        }
        let mut out = Self::zeros(self.rows, rhs.cols);
        out.gemm_acc(self, rhs)?;
        Ok(out)
    }

    /// `self += a @ b` without materializing the product — the accumulate
    /// kernel behind blocked matmul/tn_matmul/Gram/TSQR chains, which used
    /// to allocate a temporary product per k-step and `axpy` it (two full
    /// passes over the output per step).
    ///
    /// Dispatches through the kernel layer: the scalar table keeps the
    /// cache-tiled ikj loop, the SIMD table adds a packed-B register-blocked
    /// micro-kernel inside the same tiles. Big products additionally split
    /// into disjoint row ranges over the executor's deques
    /// ([`crate::kernels::parallel_for`]); every element accumulates `p`
    /// ascending under every table and split plan, so the result is
    /// bit-identical regardless of table, split, or worker count.
    pub fn gemm_acc(&mut self, a: &DenseMatrix, b: &DenseMatrix) -> Result<()> {
        if a.cols != b.rows || self.rows != a.rows || self.cols != b.cols {
            bail!(
                "gemm_acc shape mismatch: {}x{} += {}x{} @ {}x{}",
                self.rows,
                self.cols,
                a.rows,
                a.cols,
                b.rows,
                b.cols
            );
        }
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let ker = crate::kernels::active();
        crate::kernels::record_hit(ker);
        let parts = crate::kernels::plan_parts(m * k * n, m.div_ceil(16));
        if parts <= 1 {
            (ker.gemm_acc)(&mut self.data, &a.data, &b.data, m, k, n);
            return Ok(());
        }
        let rchunk = m.div_ceil(parts);
        let base = crate::kernels::SendPtr::new(self.data.as_mut_ptr());
        crate::kernels::parallel_for(parts, &|p| {
            let r0 = p * rchunk;
            if r0 >= m {
                return;
            }
            let r1 = (r0 + rchunk).min(m);
            // SAFETY: parts cover disjoint row ranges of C, and
            // parallel_for does not return until every part finished.
            let c = unsafe { std::slice::from_raw_parts_mut(base.get().add(r0 * n), (r1 - r0) * n) };
            (ker.gemm_acc)(c, &a.data[r0 * k..r1 * k], &b.data, r1 - r0, k, n);
        });
        Ok(())
    }

    /// Pairwise squared Euclidean distances between the rows of `self`
    /// (m×f) and `other` (n×f): an m×n matrix with `out[i][j] =
    /// ||self.row(i) − other.row(j)||²` — the KMeans/kNN inner loop, routed
    /// through the kernel layer's striped-accumulation `dist2` (identical
    /// binning under the scalar and SIMD tables). Large products split over
    /// disjoint row ranges of the output.
    pub fn pairwise_dist2(&self, other: &DenseMatrix) -> Result<Self> {
        if self.cols != other.cols {
            bail!(
                "pairwise_dist2 feature mismatch: {}x{} vs {}x{}",
                self.rows,
                self.cols,
                other.rows,
                other.cols
            );
        }
        let (mx, my, f) = (self.rows, other.rows, self.cols);
        let ker = crate::kernels::active();
        crate::kernels::record_hit(ker);
        let mut out = Self::zeros(mx, my);
        let parts = crate::kernels::plan_parts(mx * my * f.max(1) * 3, mx);
        if parts <= 1 {
            for i in 0..mx {
                let xr = self.row(i);
                for j in 0..my {
                    out.data[i * my + j] = (ker.dist2)(xr, other.row(j));
                }
            }
            return Ok(out);
        }
        let rchunk = mx.div_ceil(parts);
        let base = crate::kernels::SendPtr::new(out.data.as_mut_ptr());
        crate::kernels::parallel_for(parts, &|p| {
            let r0 = p * rchunk;
            if r0 >= mx {
                return;
            }
            let r1 = (r0 + rchunk).min(mx);
            for i in r0..r1 {
                let xr = self.row(i);
                for j in 0..my {
                    // SAFETY: each part writes only its own output rows.
                    unsafe { *base.get().add(i * my + j) = (ker.dist2)(xr, other.row(j)) };
                }
            }
        });
        Ok(out)
    }

    /// `self += alpha * other` (shape-checked).
    pub fn axpy(&mut self, alpha: f32, other: &DenseMatrix) -> Result<()> {
        if self.rows != other.rows || self.cols != other.cols {
            bail!(
                "axpy shape mismatch: {}x{} vs {}x{}",
                self.rows,
                self.cols,
                other.rows,
                other.cols
            );
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip_map(&self, other: &DenseMatrix, f: impl Fn(f32, f32) -> f32) -> Result<Self> {
        if self.rows != other.rows || self.cols != other.cols {
            bail!(
                "zip_map shape mismatch: {}x{} vs {}x{}",
                self.rows,
                self.cols,
                other.rows,
                other.cols
            );
        }
        Ok(Self {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    /// Sum along an axis: axis 0 -> 1 x cols (column sums); axis 1 -> rows x 1.
    pub fn sum_axis(&self, axis: usize) -> Self {
        match axis {
            0 => {
                let mut out = Self::zeros(1, self.cols);
                for i in 0..self.rows {
                    for (o, &v) in out.data.iter_mut().zip(self.row(i)) {
                        *o += v;
                    }
                }
                out
            }
            _ => {
                let mut out = Self::zeros(self.rows, 1);
                for i in 0..self.rows {
                    out.data[i] = self.row(i).iter().sum();
                }
                out
            }
        }
    }

    /// Element-wise fold along an axis with an arbitrary combiner.
    pub fn fold_axis(&self, axis: usize, init: f32, f: impl Fn(f32, f32) -> f32) -> Self {
        match axis {
            0 => {
                let mut out = Self::full(1, self.cols, init);
                for i in 0..self.rows {
                    for (o, &v) in out.data.iter_mut().zip(self.row(i)) {
                        *o = f(*o, v);
                    }
                }
                out
            }
            _ => {
                let mut out = Self::full(self.rows, 1, init);
                for i in 0..self.rows {
                    out.data[i] = self.row(i).iter().fold(init, |acc, &v| f(acc, v));
                }
                out
            }
        }
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32
    }

    /// Max |a - b| over all elements, for test assertions.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f32 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Vertically stack matrices (all must share `cols`).
    pub fn vstack(parts: &[&DenseMatrix]) -> Result<Self> {
        if parts.is_empty() {
            bail!("vstack of zero matrices");
        }
        let cols = parts[0].cols;
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            if p.cols != cols {
                bail!("vstack col mismatch: {} vs {}", p.cols, cols);
            }
            data.extend_from_slice(&p.data);
            rows += p.rows;
        }
        Ok(Self { rows, cols, data })
    }

    /// Horizontally stack matrices (all must share `rows`).
    pub fn hstack(parts: &[&DenseMatrix]) -> Result<Self> {
        if parts.is_empty() {
            bail!("hstack of zero matrices");
        }
        let rows = parts[0].rows;
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let mut out = Self::zeros(rows, cols);
        let mut c0 = 0;
        for p in parts {
            if p.rows != rows {
                bail!("hstack row mismatch: {} vs {}", p.rows, rows);
            }
            out.paste(0, c0, p)?;
            c0 += p.cols;
        }
        Ok(out)
    }

    /// Thin QR decomposition via Householder reflections: `self (m,n)` with
    /// `m >= n` → `(Q (m,n), R (n,n))`, `Q` orthonormal columns, `R` upper
    /// triangular. Backbone of the distributed TSQR (dsarray::decomposition).
    pub fn qr_thin(&self) -> Result<(Self, Self)> {
        let (m, n) = (self.rows, self.cols);
        if m < n {
            bail!("qr_thin needs rows >= cols, got {m}x{n}");
        }
        // Work in f64 for stability. Householder vectors live below the
        // diagonal of `a` (raw v_i for i > k) with the head components in
        // `v0s` and scaling factors in `betas`.
        let mut a: Vec<f64> = self.data.iter().map(|&x| x as f64).collect();
        let mut betas = vec![0.0f64; n];
        let mut v0s = vec![0.0f64; n];
        for k in 0..n {
            let mut norm2 = 0.0;
            for i in k..m {
                let v = a[i * n + k];
                norm2 += v * v;
            }
            let norm = norm2.sqrt();
            if norm < 1e-300 {
                continue; // zero column: skip reflector
            }
            let a_kk = a[k * n + k];
            let alpha = if a_kk >= 0.0 { -norm } else { norm };
            let v0 = a_kk - alpha;
            let vtv = v0 * v0 + (norm2 - a_kk * a_kk);
            if vtv <= 0.0 {
                continue;
            }
            betas[k] = 2.0 / vtv;
            v0s[k] = v0;
            a[k * n + k] = alpha;
            // Apply the reflector to the trailing columns.
            for j in k + 1..n {
                let mut dot = v0 * a[k * n + j];
                for i in k + 1..m {
                    dot += a[i * n + k] * a[i * n + j];
                }
                let s = betas[k] * dot;
                a[k * n + j] -= s * v0;
                for i in k + 1..m {
                    a[i * n + j] -= s * a[i * n + k];
                }
            }
        }
        // Extract R (upper triangle of the reduced matrix).
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r.data[i * n + j] = a[i * n + j] as f32;
            }
        }
        // Form the thin Q by applying reflectors (in reverse) to I's first
        // n columns.
        let mut q = vec![0.0f64; m * n];
        for j in 0..n {
            q[j * n + j] = 1.0;
        }
        for k in (0..n).rev() {
            if betas[k] == 0.0 {
                continue;
            }
            let v0 = v0s[k];
            for j in 0..n {
                let mut dot = v0 * q[k * n + j];
                for i in k + 1..m {
                    dot += a[i * n + k] * q[i * n + j];
                }
                let s = betas[k] * dot;
                q[k * n + j] -= s * v0;
                for i in k + 1..m {
                    q[i * n + j] -= s * a[i * n + k];
                }
            }
        }
        let qm = DenseMatrix::from_vec(m, n, q.iter().map(|&x| x as f32).collect())?;
        Ok((qm, r))
    }

    /// Solve the symmetric positive-definite system `A x = b` in-place via
    /// Cholesky (A must be square, b is (n, m)). Used for the small d×d ALS
    /// normal-equation solves that stay on the Rust side (DESIGN.md §4).
    pub fn solve_spd(&self, b: &DenseMatrix) -> Result<Self> {
        if self.rows != self.cols {
            bail!("solve_spd needs square A, got {}x{}", self.rows, self.cols);
        }
        if b.rows != self.rows {
            bail!("solve_spd rhs rows {} != n {}", b.rows, self.rows);
        }
        let n = self.rows;
        // Cholesky factor L (lower), in f64 for stability.
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = self.data[i * n + j] as f64;
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if s <= 0.0 {
                        bail!("solve_spd: matrix not positive definite (pivot {s} at {i})");
                    }
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        // Forward/back substitution per rhs column.
        let m = b.cols;
        let mut x = DenseMatrix::zeros(n, m);
        let mut y = vec![0.0f64; n];
        for c in 0..m {
            for i in 0..n {
                let mut s = b.data[i * m + c] as f64;
                for k in 0..i {
                    s -= l[i * n + k] * y[k];
                }
                y[i] = s / l[i * n + i];
            }
            for i in (0..n).rev() {
                let mut s = y[i];
                for k in i + 1..n {
                    s -= l[k * n + i] * x.data[k * m + c] as f64;
                }
                x.data[i * m + c] = (s / l[i * n + i]) as f32;
            }
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{all_close, check};

    #[test]
    fn construction_and_access() {
        let m = DenseMatrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0]).is_err());
    }

    #[test]
    fn identity_matmul_is_noop() {
        let a = DenseMatrix::from_fn(3, 3, |i, j| (i + 2 * j) as f32);
        let i3 = DenseMatrix::identity(3);
        assert_eq!(a.matmul(&i3).unwrap(), a);
        assert_eq!(i3.matmul(&a).unwrap(), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = DenseMatrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
        assert!(a.matmul(&DenseMatrix::zeros(3, 2)).is_err());
    }

    #[test]
    fn gemm_acc_accumulates_in_place() {
        let a = DenseMatrix::from_fn(5, 7, |i, j| ((i * 7 + j) % 5) as f32 - 2.0);
        let b = DenseMatrix::from_fn(7, 4, |i, j| ((i + 2 * j) % 3) as f32 * 0.5);
        let mut c = DenseMatrix::from_fn(5, 4, |i, j| (i + j) as f32);
        let want = {
            let mut w = c.clone();
            w.axpy(1.0, &a.matmul(&b).unwrap()).unwrap();
            w
        };
        c.gemm_acc(&a, &b).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-5);
        // Shape checks.
        assert!(c.gemm_acc(&b, &a).is_err());
        let mut wrong = DenseMatrix::zeros(5, 5);
        assert!(wrong.gemm_acc(&a, &b).is_err());
    }

    #[test]
    fn gemm_acc_tiling_covers_edge_sizes() {
        // Sizes straddling the IB=64 / KB=256 tile boundaries must match a
        // naive triple-loop oracle exactly.
        for (m, k, n) in [(1, 1, 1), (65, 3, 2), (3, 300, 5), (66, 257, 9)] {
            let a = DenseMatrix::from_fn(m, k, |i, j| ((i * 13 + j * 7) % 11) as f32 - 5.0);
            let b = DenseMatrix::from_fn(k, n, |i, j| ((i * 5 + j * 3) % 7) as f32 * 0.25);
            let mut got = DenseMatrix::zeros(m, n);
            got.gemm_acc(&a, &b).unwrap();
            let mut want = DenseMatrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f32;
                    for p in 0..k {
                        s += a.get(i, p) * b.get(p, j);
                    }
                    want.set(i, j, s);
                }
            }
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "gemm_acc mismatch at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn pairwise_dist2_matches_naive_oracle() {
        let x = DenseMatrix::from_fn(7, 13, |i, j| ((i * 13 + j * 5) % 9) as f32 - 4.0);
        let y = DenseMatrix::from_fn(5, 13, |i, j| ((i * 7 + j * 3) % 11) as f32 * 0.5);
        let d = x.pairwise_dist2(&y).unwrap();
        assert_eq!((d.rows(), d.cols()), (7, 5));
        for i in 0..7 {
            for j in 0..5 {
                let want: f32 = (0..13).map(|c| (x.get(i, c) - y.get(j, c)).powi(2)).sum();
                assert!(
                    (d.get(i, j) - want).abs() <= 1e-4 * want.max(1.0),
                    "d[{i}][{j}] = {} want {want}",
                    d.get(i, j)
                );
            }
        }
        // Feature-count mismatch is an error; empty feature dim is zeros.
        assert!(x.pairwise_dist2(&DenseMatrix::zeros(3, 12)).is_err());
        let e = DenseMatrix::zeros(2, 0).pairwise_dist2(&DenseMatrix::zeros(3, 0)).unwrap();
        assert_eq!(e.data(), &[0.0; 6]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = DenseMatrix::from_fn(5, 7, |i, j| (i * 7 + j) as f32);
        let t = a.transpose();
        assert_eq!(t.rows(), 7);
        assert_eq!(t.get(3, 4), a.get(4, 3));
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn slice_paste_pad() {
        let a = DenseMatrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let s = a.slice(1, 2, 2, 2).unwrap();
        assert_eq!(s.data(), &[6.0, 7.0, 10.0, 11.0]);
        assert!(a.slice(3, 3, 2, 2).is_err());

        let p = s.pad_to(3, 4).unwrap();
        assert_eq!(p.get(0, 0), 6.0);
        assert_eq!(p.get(2, 3), 0.0);
        assert!(p.slice(0, 0, 2, 2).unwrap().data() == s.data());

        let mut z = DenseMatrix::zeros(4, 4);
        z.paste(2, 2, &s).unwrap();
        assert_eq!(z.get(3, 3), 11.0);
        assert!(z.paste(3, 3, &s).is_err());
    }

    #[test]
    fn take_rows_and_cols() {
        let a = DenseMatrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32);
        let r = a.take_rows(&[3, 0, 0]).unwrap();
        assert_eq!((r.rows(), r.cols()), (3, 3));
        assert_eq!(r.row(0), a.row(3));
        assert_eq!(r.row(1), a.row(0));
        assert_eq!(r.row(2), a.row(0));
        assert!(a.take_rows(&[4]).is_err());

        let c = a.take_cols(&[2, 0]).unwrap();
        assert_eq!((c.rows(), c.cols()), (4, 2));
        assert_eq!(c.get(1, 0), a.get(1, 2));
        assert_eq!(c.get(1, 1), a.get(1, 0));
        assert!(a.take_cols(&[3]).is_err());
    }

    #[test]
    fn axis_reductions() {
        let a = DenseMatrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(a.sum_axis(0).data(), &[5.0, 7.0, 9.0]);
        assert_eq!(a.sum_axis(1).data(), &[6.0, 15.0]);
        assert_eq!(a.sum(), 21.0);
        let mx = a.fold_axis(0, f32::NEG_INFINITY, f32::max);
        assert_eq!(mx.data(), &[4.0, 5.0, 6.0]);
        let mn = a.fold_axis(1, f32::INFINITY, f32::min);
        assert_eq!(mn.data(), &[1.0, 4.0]);
    }

    #[test]
    fn stacking() {
        let a = DenseMatrix::full(1, 2, 1.0);
        let b = DenseMatrix::full(2, 2, 2.0);
        let v = DenseMatrix::vstack(&[&a, &b]).unwrap();
        assert_eq!((v.rows(), v.cols()), (3, 2));
        assert_eq!(v.get(2, 1), 2.0);

        let c = DenseMatrix::full(3, 1, 3.0);
        let h = DenseMatrix::hstack(&[&v, &c]).unwrap();
        assert_eq!((h.rows(), h.cols()), (3, 3));
        assert_eq!(h.get(0, 2), 3.0);
        assert!(DenseMatrix::hstack(&[&a, &c]).is_err());
    }

    #[test]
    fn solve_spd_recovers_solution() {
        // A = M^T M + I is SPD for any M.
        let m = DenseMatrix::from_fn(4, 4, |i, j| ((i * j + 1) % 5) as f32 * 0.3);
        let mut a = m.transpose().matmul(&m).unwrap();
        for i in 0..4 {
            let v = a.get(i, i) + 1.0;
            a.set(i, i, v);
        }
        let x_true = DenseMatrix::from_fn(4, 2, |i, j| (i + j) as f32 * 0.5 - 0.7);
        let b = a.matmul(&x_true).unwrap();
        let x = a.solve_spd(&b).unwrap();
        assert!(x.max_abs_diff(&x_true) < 1e-4, "diff {}", x.max_abs_diff(&x_true));
    }

    #[test]
    fn solve_spd_rejects_indefinite() {
        let a = DenseMatrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        assert!(a.solve_spd(&DenseMatrix::zeros(2, 1)).is_err());
    }

    #[test]
    fn qr_thin_reconstructs_and_is_orthonormal() {
        let a = DenseMatrix::from_fn(8, 4, |i, j| ((i * 7 + j * 3) % 11) as f32 - 5.0);
        let (q, r) = a.qr_thin().unwrap();
        assert_eq!((q.rows(), q.cols()), (8, 4));
        assert_eq!((r.rows(), r.cols()), (4, 4));
        // QR = A.
        let qr = q.matmul(&r).unwrap();
        assert!(qr.max_abs_diff(&a) < 1e-4, "QR != A: {}", qr.max_abs_diff(&a));
        // QᵀQ = I.
        let qtq = q.transpose().matmul(&q).unwrap();
        assert!(qtq.max_abs_diff(&DenseMatrix::identity(4)) < 1e-4);
        // R upper triangular.
        for i in 0..4 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
        // Wide input rejected.
        assert!(DenseMatrix::zeros(2, 5).qr_thin().is_err());
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // Column 2 = column 0: still must satisfy QR = A.
        let a = DenseMatrix::from_fn(6, 3, |i, j| match j {
            0 | 2 => i as f32 + 1.0,
            _ => (i * i) as f32 * 0.1,
        });
        let (q, r) = a.qr_thin().unwrap();
        assert!(q.matmul(&r).unwrap().max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn prop_matmul_associativity_with_identity_chain() {
        check("dense-matmul-identity", |g| {
            let (m, k) = (g.sized(), g.sized());
            let a = DenseMatrix::from_vec(m, k, g.f32_vec(m * k, 2.0)).unwrap();
            let ik = DenseMatrix::identity(k);
            let r = a.matmul(&ik).map_err(|e| e.to_string())?;
            crate::prop_assert!(
                all_close(r.data(), a.data(), 1e-6),
                "A @ I != A for {m}x{k}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_transpose_involution() {
        check("dense-transpose-involution", |g| {
            let (m, n) = (g.sized(), g.sized());
            let a = DenseMatrix::from_vec(m, n, g.f32_vec(m * n, 10.0)).unwrap();
            crate::prop_assert!(a.transpose().transpose() == a, "(A^T)^T != A for {m}x{n}");
            Ok(())
        });
    }

    #[test]
    fn prop_sum_axis_consistent_with_total() {
        check("dense-sum-axes-agree", |g| {
            let (m, n) = (g.sized(), g.sized());
            let a = DenseMatrix::from_vec(m, n, g.f32_vec(m * n, 1.0)).unwrap();
            let s0 = a.sum_axis(0).sum();
            let s1 = a.sum_axis(1).sum();
            let s = a.sum();
            crate::prop_assert!(
                (s0 - s).abs() < 1e-3 && (s1 - s).abs() < 1e-3,
                "axis sums disagree: {s0} {s1} {s}"
            );
            Ok(())
        });
    }
}
