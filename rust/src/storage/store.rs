//! Out-of-core block store — the spill backend of the memory-budget policy.
//!
//! When a runtime is created with a `memory_budget_bytes` high-water mark
//! (see [`crate::tasking::LocalOptions`]), blocks that are still referenced
//! but push the resident set over budget are *spilled* here: the payload is
//! written to one file per block under a per-runtime directory, the
//! in-memory value is dropped, and task-input resolution (or `wait`)
//! transparently *faults* it back in on next use. Dense and CSR blocks are
//! both supported; phantom blocks carry no payload and are never spilled.
//!
//! The file format is a minimal self-describing binary record (no external
//! serialization crate in the offline build):
//!
//! ```text
//! magic  b"DSBK"            4 B
//! version u8 = 1            1 B
//! kind    u8                1 B   0 = dense, 1 = CSR
//! rows    u64 LE            8 B
//! cols    u64 LE            8 B
//! dense:  rows*cols f32 LE          (row-major)
//! csr:    nnz u64 LE, indptr (rows+1)*u64 LE, indices nnz*u32 LE,
//!         data nnz*f32 LE
//! ```
//!
//! Lifecycle: the store owns its directory; dropping the store (runtime
//! teardown) removes the directory and every spill file in it. Files of
//! individual blocks are unlinked earlier when refcount reclamation proves
//! the block dead (see `Graph::try_evict`).

use std::fs::{self, File};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::block::Block;
use super::dense::DenseMatrix;
use super::sparse::CsrMatrix;

const MAGIC: &[u8; 4] = b"DSBK";
const VERSION: u8 = 1;
const KIND_DENSE: u8 = 0;
const KIND_CSR: u8 = 1;

/// Distinguishes spill directories of runtimes created in the same process.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-runtime spill directory: one file per spilled block, keyed by the
/// block's `DataId`. All methods are `&self`; callers (the executor)
/// serialize access through their own scheduler lock.
pub struct BlockStore {
    dir: PathBuf,
}

impl BlockStore {
    /// Open a store rooted at `dir` (created if absent). The store takes
    /// ownership of the directory: it is removed on drop.
    pub fn new(dir: PathBuf) -> Result<Self> {
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill directory {}", dir.display()))?;
        Ok(Self { dir })
    }

    /// Open a store in a fresh, uniquely-named subdirectory of `parent`.
    /// The store owns (and removes on drop) only its own subdirectory —
    /// never the caller's directory — and concurrent runtimes pointed at
    /// the same `parent` cannot collide on block file names.
    pub fn new_unique_under(parent: &Path) -> Result<Self> {
        let seq = STORE_SEQ.fetch_add(1, Ordering::Relaxed);
        Self::new(parent.join(format!("rustdslib-spill-{}-{seq}", std::process::id())))
    }

    /// Open a store in a fresh unique directory under the system temp dir.
    pub fn in_temp() -> Result<Self> {
        Self::new_unique_under(&std::env::temp_dir())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, id: u32) -> PathBuf {
        self.dir.join(format!("d{id:08}.blk"))
    }

    /// Write `block`'s payload to this block's spill file. Returns the
    /// bytes written. Phantom blocks have no payload and error.
    pub fn spill(&self, id: u32, block: &Block) -> Result<u64> {
        let path = self.path(id);
        let file = File::create(&path)
            .with_context(|| format!("creating spill file {}", path.display()))?;
        let mut w = BufWriter::new(file);
        let written = write_block(&mut w, block)
            .with_context(|| format!("spilling block {id} to {}", path.display()))?;
        w.flush()?;
        Ok(written)
    }

    /// Read this block's spill file back into memory.
    pub fn fault(&self, id: u32) -> Result<Block> {
        let path = self.path(id);
        let file = File::open(&path)
            .with_context(|| format!("opening spill file {}", path.display()))?;
        read_block(&mut BufReader::new(file))
            .with_context(|| format!("faulting block {id} from {}", path.display()))
    }

    /// Unlink this block's spill file (the block died while spilled, or its
    /// clean on-disk copy became garbage). Missing files are ignored.
    pub fn remove(&self, id: u32) {
        let _ = fs::remove_file(self.path(id));
    }
}

impl Drop for BlockStore {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.dir);
    }
}

/// Chunked encoder for 4-byte little-endian elements (f32/u32) — one
/// buffered implementation shared by every 4-byte section writer.
fn write_le4<T: Copy>(
    w: &mut impl Write,
    xs: &[T],
    enc: impl Fn(T) -> [u8; 4],
) -> std::io::Result<()> {
    let mut buf = [0u8; 4096];
    for chunk in xs.chunks(1024) {
        for (i, &v) in chunk.iter().enumerate() {
            buf[i * 4..i * 4 + 4].copy_from_slice(&enc(v));
        }
        w.write_all(&buf[..chunk.len() * 4])?;
    }
    Ok(())
}

/// Chunked decoder twin of [`write_le4`].
fn read_le4<T>(
    r: &mut impl Read,
    n: usize,
    dec: impl Fn([u8; 4]) -> T,
) -> std::io::Result<Vec<T>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = [0u8; 4096];
    let mut left = n;
    while left > 0 {
        let take = left.min(1024);
        r.read_exact(&mut buf[..take * 4])?;
        for i in 0..take {
            out.push(dec(buf[i * 4..i * 4 + 4].try_into().unwrap()));
        }
        left -= take;
    }
    Ok(out)
}

/// f32 section codec, shared with the NPY writer.
pub(crate) fn write_f32s(w: &mut impl Write, xs: &[f32]) -> std::io::Result<()> {
    write_le4(w, xs, f32::to_le_bytes)
}

fn read_f32s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f32>> {
    read_le4(r, n, f32::from_le_bytes)
}

fn write_u32s(w: &mut impl Write, xs: &[u32]) -> std::io::Result<()> {
    write_le4(w, xs, u32::to_le_bytes)
}

fn read_u32s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<u32>> {
    read_le4(r, n, u32::from_le_bytes)
}

fn write_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Serialize one block in the spill format; returns the payload size in
/// bytes (header + sections).
pub fn write_block(w: &mut impl Write, block: &Block) -> Result<u64> {
    w.write_all(MAGIC)?;
    w.write_all(&[VERSION])?;
    match block {
        Block::Dense(m) => {
            w.write_all(&[KIND_DENSE])?;
            write_u64(w, m.rows() as u64)?;
            write_u64(w, m.cols() as u64)?;
            write_f32s(w, m.data())?;
            Ok(22 + 4 * m.data().len() as u64)
        }
        Block::Csr(m) => {
            w.write_all(&[KIND_CSR])?;
            write_u64(w, m.rows() as u64)?;
            write_u64(w, m.cols() as u64)?;
            write_u64(w, m.nnz() as u64)?;
            for &p in m.indptr() {
                write_u64(w, p as u64)?;
            }
            write_u32s(w, m.indices())?;
            write_f32s(w, m.values())?;
            Ok(30 + 8 * (m.rows() as u64 + 1) + 8 * m.nnz() as u64)
        }
        Block::Phantom(_) => bail!("phantom blocks carry no payload and cannot be spilled"),
    }
}

/// Deserialize one block from the spill format.
pub fn read_block(r: &mut impl Read) -> Result<Block> {
    let mut head = [0u8; 6];
    r.read_exact(&mut head)?;
    if &head[..4] != MAGIC {
        bail!("bad spill file magic {:?}", &head[..4]);
    }
    if head[4] != VERSION {
        bail!("unsupported spill format version {}", head[4]);
    }
    let kind = head[5];
    let rows = read_u64(r)? as usize;
    let cols = read_u64(r)? as usize;
    match kind {
        KIND_DENSE => {
            let data = read_f32s(r, rows * cols)?;
            Ok(Block::Dense(DenseMatrix::from_vec(rows, cols, data)?))
        }
        KIND_CSR => {
            let nnz = read_u64(r)? as usize;
            let mut indptr = Vec::with_capacity(rows + 1);
            for _ in 0..=rows {
                indptr.push(read_u64(r)? as usize);
            }
            let indices = read_u32s(r, nnz)?;
            let data = read_f32s(r, nnz)?;
            Ok(Block::Csr(CsrMatrix::from_raw_parts(
                rows, cols, indptr, indices, data,
            )?))
        }
        k => bail!("unknown spill block kind {k}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_spill_fault_round_trip() {
        let store = BlockStore::in_temp().unwrap();
        let m = DenseMatrix::from_fn(7, 5, |i, j| i as f32 * 0.25 - j as f32);
        let written = store.spill(3, &Block::Dense(m.clone())).unwrap();
        assert_eq!(written, 22 + 4 * 35);
        let back = store.fault(3).unwrap();
        assert_eq!(back.as_dense().unwrap(), &m);
    }

    #[test]
    fn csr_spill_fault_round_trip() {
        let store = BlockStore::in_temp().unwrap();
        let m = CsrMatrix::from_triplets(4, 6, &[(0, 5, 1.5), (2, 0, -2.0), (3, 3, 0.25)])
            .unwrap();
        store.spill(9, &Block::Csr(m.clone())).unwrap();
        let back = store.fault(9).unwrap();
        assert_eq!(back.as_csr().unwrap(), &m);
    }

    #[test]
    fn phantom_refused_missing_file_errors() {
        let store = BlockStore::in_temp().unwrap();
        let p = Block::Phantom(crate::storage::BlockMeta::dense(2, 2));
        assert!(store.spill(0, &p).is_err());
        assert!(store.fault(42).is_err());
    }

    #[test]
    fn remove_unlinks_and_drop_cleans_directory() {
        let store = BlockStore::in_temp().unwrap();
        let dir = store.dir().to_path_buf();
        store
            .spill(1, &Block::Dense(DenseMatrix::zeros(2, 2)))
            .unwrap();
        assert!(dir.join("d00000001.blk").exists());
        store.remove(1);
        assert!(!dir.join("d00000001.blk").exists());
        store.remove(1); // idempotent
        drop(store);
        assert!(!dir.exists());
    }

    #[test]
    fn corrupt_header_rejected() {
        let mut bytes = Vec::new();
        write_block(&mut bytes, &Block::Dense(DenseMatrix::zeros(1, 1))).unwrap();
        bytes[0] = b'X';
        assert!(read_block(&mut bytes.as_slice()).is_err());
    }
}
