//! CSR sparse matrix — the SciPy-CSR block backend equivalent.
//!
//! ds-arrays store sparse blocks as CSR (paper §4.2); the ALS workload
//! (Netflix-shape ratings, density ≈ 1.2 %) is the main consumer. The type
//! supports construction from triplets, row/column slicing (column slicing
//! is what ds-arrays make cheap and Datasets cannot do), transpose, SpMM
//! against dense, and dense round-trips.

use anyhow::{bail, Result};

use super::dense::DenseMatrix;

#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row pointer, len = rows + 1.
    indptr: Vec<usize>,
    /// Column indices, len = nnz, sorted within each row.
    indices: Vec<u32>,
    data: Vec<f32>,
}

impl CsrMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            indptr: vec![0; rows + 1],
            indices: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f32)],
    ) -> Result<Self> {
        for &(r, c, _) in triplets {
            if r >= rows || c >= cols {
                bail!("triplet ({r},{c}) out of bounds for {rows}x{cols}");
            }
        }
        // Counting sort by row.
        let mut counts = vec![0usize; rows + 1];
        for &(r, _, _) in triplets {
            counts[r + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let indptr_draft = counts.clone();
        let mut order: Vec<usize> = vec![0; triplets.len()];
        {
            let mut next = indptr_draft.clone();
            for (t, &(r, _, _)) in triplets.iter().enumerate() {
                order[next[r]] = t;
                next[r] += 1;
            }
        }
        // Within each row: sort by column, merging duplicates.
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(triplets.len());
        let mut data: Vec<f32> = Vec::with_capacity(triplets.len());
        for r in 0..rows {
            let row_ts = &order[indptr_draft[r]..indptr_draft[r + 1]];
            let mut entries: Vec<(usize, f32)> =
                row_ts.iter().map(|&t| (triplets[t].1, triplets[t].2)).collect();
            entries.sort_unstable_by_key(|&(c, _)| c);
            for (c, v) in entries {
                if let Some(last) = indices.last() {
                    if indices.len() > indptr[r] && *last as usize == c {
                        *data.last_mut().unwrap() += v;
                        continue;
                    }
                }
                indices.push(c as u32);
                data.push(v);
            }
            indptr[r + 1] = indices.len();
        }
        Ok(Self {
            rows,
            cols,
            indptr,
            indices,
            data,
        })
    }

    pub fn from_dense(m: &DenseMatrix, eps: f32) -> Self {
        let mut indptr = vec![0usize; m.rows() + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > eps {
                    indices.push(j as u32);
                    data.push(v);
                }
            }
            indptr[i + 1] = indices.len();
        }
        Self {
            rows: m.rows(),
            cols: m.cols(),
            indptr,
            indices,
            data,
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn nnz(&self) -> usize {
        self.data.len()
    }
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// Row pointer array (`len == rows + 1`) — raw CSR access for
    /// serialization (the spill store, file writers).
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Column indices (`len == nnz`, sorted within each row).
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Stored values (`len == nnz`), parallel to [`CsrMatrix::indices`].
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.data
    }

    /// Rebuild from raw CSR arrays (the inverse of the accessors above).
    /// Validates monotone row pointers, array lengths and column bounds —
    /// the spill store round-trips through this on fault-in.
    pub fn from_raw_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f32>,
    ) -> Result<Self> {
        if indptr.len() != rows + 1 || indptr.first() != Some(&0) {
            bail!("csr indptr must have len rows+1 and start at 0");
        }
        if indptr.windows(2).any(|w| w[0] > w[1]) || *indptr.last().unwrap() != indices.len() {
            bail!("csr indptr not monotone or inconsistent with nnz {}", indices.len());
        }
        if indices.len() != data.len() {
            bail!(
                "csr indices/data length mismatch: {} vs {}",
                indices.len(),
                data.len()
            );
        }
        if indices.iter().any(|&c| c as usize >= cols) {
            bail!("csr column index out of bounds for {cols} columns");
        }
        Ok(Self {
            rows,
            cols,
            indptr,
            indices,
            data,
        })
    }

    /// (column indices, values) of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let r = out.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                r[c as usize] = v;
            }
        }
        out
    }

    /// Transpose by a two-pass counting construction — O(nnz + rows + cols).
    pub fn transpose(&self) -> Self {
        let mut indptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0f32; self.nnz()];
        let mut next = indptr.clone();
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let pos = next[c as usize];
                indices[pos] = r as u32;
                data[pos] = v;
                next[c as usize] += 1;
            }
        }
        Self {
            rows: self.cols,
            cols: self.rows,
            indptr,
            indices,
            data,
        }
    }

    /// Copy of the row range `[r0, r0+nr)` (all columns).
    pub fn row_slice(&self, r0: usize, nr: usize) -> Result<Self> {
        if r0 + nr > self.rows {
            bail!("row_slice [{r0}+{nr}) out of bounds for {} rows", self.rows);
        }
        let (s, e) = (self.indptr[r0], self.indptr[r0 + nr]);
        let indptr = self.indptr[r0..=r0 + nr].iter().map(|&p| p - s).collect();
        Ok(Self {
            rows: nr,
            cols: self.cols,
            indptr,
            indices: self.indices[s..e].to_vec(),
            data: self.data[s..e].to_vec(),
        })
    }

    /// Copy of the sub-matrix `[r0, r0+nr) x [c0, c0+nc)`.
    pub fn slice(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Result<Self> {
        if r0 + nr > self.rows || c0 + nc > self.cols {
            bail!(
                "slice [{r0}+{nr}, {c0}+{nc}) out of bounds for {}x{}",
                self.rows,
                self.cols
            );
        }
        let mut indptr = vec![0usize; nr + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        let (lo, hi) = (c0 as u32, (c0 + nc) as u32);
        for i in 0..nr {
            let (cols, vals) = self.row(r0 + i);
            // Columns are sorted: binary search the window.
            let a = cols.partition_point(|&c| c < lo);
            let b = cols.partition_point(|&c| c < hi);
            for (&c, &v) in cols[a..b].iter().zip(&vals[a..b]) {
                indices.push(c - lo);
                data.push(v);
            }
            indptr[i + 1] = indices.len();
        }
        Ok(Self {
            rows: nr,
            cols: nc,
            indptr,
            indices,
            data,
        })
    }

    /// Gather arbitrary rows in index order (duplicates allowed), staying
    /// CSR — the sparse backend of ds-array fancy indexing.
    pub fn take_rows(&self, idx: &[usize]) -> Result<Self> {
        let mut indptr = vec![0usize; idx.len() + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for (k, &i) in idx.iter().enumerate() {
            if i >= self.rows {
                bail!("row index {i} out of bounds for {} rows", self.rows);
            }
            let (cols, vals) = self.row(i);
            indices.extend_from_slice(cols);
            data.extend_from_slice(vals);
            indptr[k + 1] = indices.len();
        }
        Ok(Self {
            rows: idx.len(),
            cols: self.cols,
            indptr,
            indices,
            data,
        })
    }

    /// Gather arbitrary columns in index order (duplicates allowed),
    /// staying CSR. Per stored row, each wanted column is located by binary
    /// search (column indices are sorted within rows).
    pub fn take_cols(&self, idx: &[usize]) -> Result<Self> {
        for &j in idx {
            if j >= self.cols {
                bail!("column index {j} out of bounds for {} columns", self.cols);
            }
        }
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (k, &j) in idx.iter().enumerate() {
                if let Ok(pos) = cols.binary_search(&(j as u32)) {
                    indices.push(k as u32);
                    data.push(vals[pos]);
                }
            }
            indptr[i + 1] = indices.len();
        }
        Ok(Self {
            rows: self.rows,
            cols: idx.len(),
            indptr,
            indices,
            data,
        })
    }

    /// SpMM: `self (m,k) @ dense (k,n) -> dense (m,n)`.
    pub fn matmul_dense(&self, rhs: &DenseMatrix) -> Result<DenseMatrix> {
        let mut out = DenseMatrix::zeros(self.rows, rhs.cols());
        self.matmul_dense_acc(rhs, &mut out)?;
        Ok(out)
    }

    /// SpMM accumulate: `out += self @ rhs` — the sparse twin of
    /// [`DenseMatrix::gemm_acc`], so blocked matmul chains accumulate CSR
    /// k-steps without a temporary product block.
    pub fn matmul_dense_acc(&self, rhs: &DenseMatrix, out: &mut DenseMatrix) -> Result<()> {
        if self.cols != rhs.rows() {
            bail!(
                "spmm shape mismatch: {}x{} @ {}x{}",
                self.rows,
                self.cols,
                rhs.rows(),
                rhs.cols()
            );
        }
        if out.rows() != self.rows || out.cols() != rhs.cols() {
            bail!(
                "spmm accumulator {}x{} != output shape {}x{}",
                out.rows(),
                out.cols(),
                self.rows,
                rhs.cols()
            );
        }
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let brow = rhs.row(c as usize);
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += v * b;
                }
            }
        }
        Ok(())
    }

    /// Vertically stack CSR parts (all must share `cols`).
    pub fn vstack(parts: &[&CsrMatrix]) -> Result<Self> {
        if parts.is_empty() {
            bail!("vstack of zero matrices");
        }
        let cols = parts[0].cols;
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            if p.cols != cols {
                bail!("vstack col mismatch: {} vs {}", p.cols, cols);
            }
            let base = *indptr.last().unwrap();
            indptr.extend(p.indptr[1..].iter().map(|&x| x + base));
            indices.extend_from_slice(&p.indices);
            data.extend_from_slice(&p.data);
            rows += p.rows;
        }
        Ok(Self {
            rows,
            cols,
            indptr,
            indices,
            data,
        })
    }

    /// Horizontally stack CSR parts (all must share `rows`).
    pub fn hstack(parts: &[&CsrMatrix]) -> Result<Self> {
        if parts.is_empty() {
            bail!("hstack of zero matrices");
        }
        let rows = parts[0].rows;
        for p in parts {
            if p.rows != rows {
                bail!("hstack row mismatch: {} vs {}", p.rows, rows);
            }
        }
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let nnz: usize = parts.iter().map(|p| p.nnz()).sum();
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        for i in 0..rows {
            let mut offset = 0u32;
            for p in parts {
                let (cols_i, vals_i) = p.row(i);
                indices.extend(cols_i.iter().map(|&c| c + offset));
                data.extend_from_slice(vals_i);
                offset += p.cols as u32;
            }
            indptr[i + 1] = indices.len();
        }
        Ok(Self {
            rows,
            cols,
            indptr,
            indices,
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Xoshiro256;

    fn random_csr(g: &mut crate::util::prop::Gen, rows: usize, cols: usize) -> CsrMatrix {
        let nnz = g.usize_in(0, rows * cols);
        let mut trips = Vec::new();
        for _ in 0..nnz {
            trips.push((
                g.usize_in(0, rows.saturating_sub(1)),
                g.usize_in(0, cols.saturating_sub(1)),
                g.f32_in(-2.0, 2.0),
            ));
        }
        CsrMatrix::from_triplets(rows, cols, &trips).unwrap()
    }

    #[test]
    fn triplets_round_trip_dense() {
        let trips = vec![(0, 1, 2.0), (2, 0, -1.0), (0, 3, 4.0), (1, 2, 5.0)];
        let m = CsrMatrix::from_triplets(3, 4, &trips).unwrap();
        assert_eq!(m.nnz(), 4);
        let d = m.to_dense();
        assert_eq!(d.get(0, 1), 2.0);
        assert_eq!(d.get(2, 0), -1.0);
        assert_eq!(d.get(1, 1), 0.0);
        assert_eq!(CsrMatrix::from_dense(&d, 0.0), m);
    }

    #[test]
    fn duplicate_triplets_are_summed() {
        let m = CsrMatrix::from_triplets(1, 2, &[(0, 0, 1.0), (0, 0, 2.5)]).unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.to_dense().get(0, 0), 3.5);
    }

    #[test]
    fn out_of_bounds_triplet_rejected() {
        assert!(CsrMatrix::from_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let trips = vec![(0, 1, 2.0), (2, 0, -1.0), (1, 3, 7.0)];
        let m = CsrMatrix::from_triplets(3, 4, &trips).unwrap();
        let t = m.transpose();
        assert_eq!((t.rows(), t.cols()), (4, 3));
        assert_eq!(t.to_dense(), m.to_dense().transpose());
    }

    #[test]
    fn slices_match_dense_slices() {
        let trips = vec![(0, 0, 1.0), (1, 2, 2.0), (2, 4, 3.0), (3, 1, 4.0)];
        let m = CsrMatrix::from_triplets(4, 5, &trips).unwrap();
        let s = m.slice(1, 1, 2, 3).unwrap();
        assert_eq!(s.to_dense(), m.to_dense().slice(1, 1, 2, 3).unwrap());
        let rs = m.row_slice(1, 2).unwrap();
        assert_eq!(rs.to_dense(), m.to_dense().slice(1, 0, 2, 5).unwrap());
        assert!(m.slice(3, 3, 2, 3).is_err());
    }

    #[test]
    fn take_rows_and_cols_match_dense() {
        let trips = vec![(0, 0, 1.0), (1, 2, 2.0), (2, 4, 3.0), (3, 1, 4.0)];
        let m = CsrMatrix::from_triplets(4, 5, &trips).unwrap();
        let idx = [3, 0, 3, 2];
        let t = m.take_rows(&idx).unwrap();
        assert_eq!(t.to_dense(), m.to_dense().take_rows(&idx).unwrap());
        assert!(m.take_rows(&[4]).is_err());

        let cidx = [4, 0, 0, 2];
        let c = m.take_cols(&cidx).unwrap();
        assert_eq!(c.to_dense(), m.to_dense().take_cols(&cidx).unwrap());
        assert!(m.take_cols(&[5]).is_err());
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let trips = vec![(0, 0, 1.0), (0, 2, 2.0), (1, 1, -3.0)];
        let a = CsrMatrix::from_triplets(2, 3, &trips).unwrap();
        let b = DenseMatrix::from_fn(3, 2, |i, j| (i + j) as f32);
        let c = a.matmul_dense(&b).unwrap();
        let c_ref = a.to_dense().matmul(&b).unwrap();
        assert_eq!(c, c_ref);
    }

    #[test]
    fn spmm_acc_accumulates_and_checks_shapes() {
        let a = CsrMatrix::from_triplets(2, 3, &[(0, 1, 2.0), (1, 0, -1.0)]).unwrap();
        let b = DenseMatrix::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let mut acc = DenseMatrix::full(2, 2, 5.0);
        a.matmul_dense_acc(&b, &mut acc).unwrap();
        let mut want = DenseMatrix::full(2, 2, 5.0);
        want.axpy(1.0, &a.to_dense().matmul(&b).unwrap()).unwrap();
        assert_eq!(acc, want);
        // Mismatched accumulator shape rejected.
        let mut wrong = DenseMatrix::zeros(3, 2);
        assert!(a.matmul_dense_acc(&b, &mut wrong).is_err());
        assert!(a.matmul_dense_acc(&DenseMatrix::zeros(4, 2), &mut acc).is_err());
    }

    #[test]
    fn stacking_matches_dense() {
        let a = CsrMatrix::from_triplets(2, 3, &[(0, 0, 1.0), (1, 2, 2.0)]).unwrap();
        let b = CsrMatrix::from_triplets(1, 3, &[(0, 1, 5.0)]).unwrap();
        let v = CsrMatrix::vstack(&[&a, &b]).unwrap();
        assert_eq!(
            v.to_dense(),
            DenseMatrix::vstack(&[&a.to_dense(), &b.to_dense()]).unwrap()
        );
        let c = CsrMatrix::from_triplets(2, 2, &[(1, 0, 9.0)]).unwrap();
        let h = CsrMatrix::hstack(&[&a, &c]).unwrap();
        assert_eq!(
            h.to_dense(),
            DenseMatrix::hstack(&[&a.to_dense(), &c.to_dense()]).unwrap()
        );
    }

    #[test]
    fn density_netflix_scale_sanity() {
        // Netflix: 17,770 x 480,189 with ~100.5M nnz => density ~1.18%.
        let rows = 17_770usize;
        let cols = 480_189usize;
        let nnz = 100_480_507f64;
        let density = nnz / (rows as f64 * cols as f64);
        assert!((0.011..0.013).contains(&density));
        // And our constructor handles a scaled-down version.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let (r, c) = (100, 500);
        let trips: Vec<_> = (0..((r * c) / 85))
            .map(|_| {
                (
                    rng.next_below(r as u64) as usize,
                    rng.next_below(c as u64) as usize,
                    1.0 + rng.next_f32() * 4.0,
                )
            })
            .collect();
        let m = CsrMatrix::from_triplets(r, c, &trips).unwrap();
        assert!((m.density() - 0.0117).abs() < 0.004, "density {}", m.density());
    }

    #[test]
    fn prop_transpose_involution_and_dense_agreement() {
        check("csr-transpose-involution", |g| {
            let (r, c) = (g.sized(), g.sized());
            let m = random_csr(g, r, c);
            let tt = m.transpose().transpose();
            crate::prop_assert!(tt.to_dense() == m.to_dense(), "(M^T)^T != M for {r}x{c}");
            crate::prop_assert!(
                m.transpose().to_dense() == m.to_dense().transpose(),
                "sparse/dense transpose disagree"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_slice_agrees_with_dense() {
        check("csr-slice-dense-agree", |g| {
            let (r, c) = (g.usize_in(1, g.size), g.usize_in(1, g.size));
            let m = random_csr(g, r, c);
            let r0 = g.usize_in(0, r - 1);
            let c0 = g.usize_in(0, c - 1);
            let nr = g.usize_in(1, r - r0);
            let nc = g.usize_in(1, c - c0);
            let s = m.slice(r0, c0, nr, nc).map_err(|e| e.to_string())?;
            let d = m.to_dense().slice(r0, c0, nr, nc).map_err(|e| e.to_string())?;
            crate::prop_assert!(s.to_dense() == d, "slice mismatch at ({r0},{c0},{nr},{nc})");
            Ok(())
        });
    }
}
