//! Block storage backends.
//!
//! The paper stores ds-array blocks as NumPy arrays or SciPy CSR matrices
//! depending on data density; this module provides the equivalent Rust
//! backends ([`DenseMatrix`], [`CsrMatrix`]) plus the [`Block`] sum type the
//! tasking runtime moves around. A third variant, `Block::Phantom`, carries
//! only metadata and is what the discrete-event simulator schedules when the
//! data would be too large to materialize (DESIGN.md §2).

pub mod block;
pub mod dense;
pub mod io;
pub mod sparse;

pub use block::{Block, BlockMeta};
pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;
