//! Block storage backends.
//!
//! The paper stores ds-array blocks as NumPy arrays or SciPy CSR matrices
//! depending on data density; this module provides the equivalent Rust
//! backends ([`DenseMatrix`], [`CsrMatrix`]) plus the [`Block`] sum type the
//! tasking runtime moves around. A third variant, `Block::Phantom`, carries
//! only metadata and is what the discrete-event simulator schedules when the
//! data would be too large to materialize (DESIGN.md §2).
//!
//! Two disk-facing pieces complete the layer: [`io`] holds the partitioned
//! file readers/writers (CSV, SVMLight, NPY — including the byte-range
//! readers the parallel ds-array loaders fan out over), and [`store`] holds
//! the [`BlockStore`] spill backend that lets a budgeted runtime keep live
//! blocks on disk (out-of-core execution — see `docs/IO.md`).

pub mod block;
pub mod dense;
pub mod io;
pub mod sparse;
pub mod store;

pub use block::{Block, BlockMeta};
pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;
pub use store::BlockStore;
