//! Kernel layer: packed SIMD micro-kernels and intra-block work splitting
//! for the dense hot paths (§Perf optimization, ROADMAP item 2).
//!
//! Every FLOP-heavy block operation funnels through a [`Kernels`] vtable of
//! plain function pointers — one entry per kernel shape that dominates the
//! estimator family: elementwise unary maps, elementwise binary/broadcast
//! ops, the tiled gemm-accumulate, and pairwise squared distances. Two
//! tables exist:
//!
//! * [`scalar`] — the portable reference implementation. Plain loops, no
//!   architecture assumptions; also the oracle the property tests compare
//!   against.
//! * the SIMD table — explicit f32x8 micro-kernels written with stable
//!   `core::arch::x86_64` AVX intrinsics (the storage dtype is f32, so the
//!   8-lane table is the one that ships; the dispatch layer is
//!   dtype-agnostic and an f64x4 table slots in alongside it when an f64
//!   block backend lands).
//!
//! [`active`] picks one table **once per process** (a `OnceLock`): runtime
//! feature detection via `is_x86_feature_detected!("avx2")`, overridable
//! with `DSARRAY_NO_SIMD=1` (the CI lane that keeps the scalar fallback
//! honest). Per-task code never re-runs feature detection — the resolved
//! table is stored in the `Runtime` and captured by fused-task closures at
//! submission time.
//!
//! **Bit-identicality.** The SIMD kernels are bit-identical to the scalar
//! reference, not merely close: no FMA contraction (separate mul + add,
//! matching scalar rounding), accumulation order fixed per element (gemm
//! accumulates `p` ascending whether or not the tile is register-blocked),
//! `abs`/`neg` are sign-bit ops, and the pairwise distance uses the same
//! 8-bin striped accumulation + fixed reduction tree in both tables.
//! Transcendentals (`pow`, `exp`) and the branchy `DivOrZero` run scalar
//! under both tables — there is no closed-form lane op bit-identical to
//! libm, so they are excluded from vectorization rather than allowed to
//! drift. The cluster parity suite and the SIMD-disabled CI lane both lean
//! on this property.
//!
//! **Intra-block parallelism.** A single fat block task (a gemm over a big
//! tile grid, a fused chain over a long block) no longer serializes one
//! worker while its siblings idle: [`parallel_for`] splits the work into
//! sub-range items and offers them to the executor through the [`IntraPool`]
//! installed in each worker thread (the local executor pushes helper tokens
//! onto the existing per-worker deques). Splits are gated by a size
//! threshold ([`set_split_min`]) and deterministic **by construction**:
//! parts are disjoint output ranges and no element's accumulation order
//! depends on the split plan or worker count, so split, unsplit, 1-worker
//! and N-worker runs produce bit-identical blocks. Threads without a pool
//! (cluster executor threads, plain callers) run the parts inline, in
//! order.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Unary elementwise operation kinds — the closed set the fused expression
/// engine interprets over SIMD lanes (`dsarray/expr.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UnaryKind {
    AddScalar(f32),
    MulScalar(f32),
    /// `x.powf(e)` — transcendental, runs scalar under both tables.
    Pow(f32),
    Sqrt,
    Abs,
    /// `x.exp()` — transcendental, runs scalar under both tables.
    Exp,
    Neg,
    /// `max(x, 0)` as `if x > 0 { x } else { 0 }` — NaN and `-0.0` both map
    /// to `+0.0`, which is exactly what the lane op (`and(x, x > 0)`)
    /// produces, so the two tables agree bitwise.
    Relu,
}

impl UnaryKind {
    /// Scalar reference semantics of the op — the single source of truth
    /// every vectorized path must match bit for bit.
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            UnaryKind::AddScalar(s) => x + s,
            UnaryKind::MulScalar(s) => x * s,
            UnaryKind::Pow(e) => x.powf(e),
            UnaryKind::Sqrt => x.sqrt(),
            UnaryKind::Abs => x.abs(),
            UnaryKind::Exp => x.exp(),
            UnaryKind::Neg => -x,
            UnaryKind::Relu => {
                if x > 0.0 {
                    x
                } else {
                    0.0
                }
            }
        }
    }
}

/// Binary elementwise operation kinds (array∘array and row-broadcast).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinaryKind {
    Add,
    Sub,
    Mul,
    Div,
    /// `if b != 0 { a / b } else { 0 }` (broadcast divide's safe form) —
    /// branchy, runs scalar under both tables.
    DivOrZero,
}

impl BinaryKind {
    /// Scalar reference semantics of the op.
    #[inline]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            BinaryKind::Add => a + b,
            BinaryKind::Sub => a - b,
            BinaryKind::Mul => a * b,
            BinaryKind::Div => a / b,
            BinaryKind::DivOrZero => {
                if b != 0.0 {
                    a / b
                } else {
                    0.0
                }
            }
        }
    }
}

/// One kernel vtable: plain function pointers, selected once per process.
pub struct Kernels {
    /// Human-readable table name (shows up in bench notes).
    pub name: &'static str,
    /// Whether this table uses SIMD lanes (drives `simd_kernel_hits`).
    pub simd: bool,
    /// `xs[i] = op(xs[i])` in place.
    pub unary: fn(UnaryKind, &mut [f32]),
    /// `a[i] = op(a[i], b[i])` in place over `min(len)` elements.
    pub binary: fn(BinaryKind, &mut [f32], &[f32]),
    /// `c += a @ b` for row-major `c (m×n)`, `a (m×k)`, `b (k×n)`.
    /// Accumulates `p` ascending per element — callers may split over
    /// disjoint row ranges of `c`/`a` without changing any result bit.
    pub gemm_acc: fn(&mut [f32], &[f32], &[f32], usize, usize, usize),
    /// Squared Euclidean distance between two equal-length vectors,
    /// 8-bin striped accumulation + fixed reduction tree.
    pub dist2: fn(&[f32], &[f32]) -> f32,
    /// Fused elementwise epilogue: apply the whole `ops` chain to every
    /// element in one traversal (the planner grafts scale/bias/ReLU chains
    /// onto gemm outputs while the tile is still cache-hot). Elementwise
    /// unary ops commute with traversal order, so a per-element fold is
    /// bit-identical to applying the chain as sequential full passes — the
    /// contract the property test pins. Chains containing a transcendental
    /// (`Pow`/`Exp`) run the scalar fold under both tables.
    pub epilogue: fn(&mut [f32], &[UnaryKind]),
}

// ---------------------------------------------------------------------------
// Scalar reference kernels (portable fallback and property-test oracle).
// ---------------------------------------------------------------------------

fn unary_scalar(op: UnaryKind, xs: &mut [f32]) {
    for x in xs {
        *x = op.apply(*x);
    }
}

fn binary_scalar(op: BinaryKind, a: &mut [f32], b: &[f32]) {
    for (x, &y) in a.iter_mut().zip(b) {
        *x = op.apply(*x, y);
    }
}

/// Tiled scalar gemm-accumulate. Same tiling as the pre-kernel-layer
/// `DenseMatrix::gemm_acc`, minus its `a == 0.0` skip: skipping terms is
/// not bit-stable (`0·inf = NaN`, `-0.0 + 0.0 = +0.0`), so both tables
/// include every term, in the same ascending-`p` order per element.
fn gemm_acc_scalar(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    const IB: usize = 64;
    const KB: usize = 256;
    for ib in (0..m).step_by(IB) {
        let iend = (ib + IB).min(m);
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in ib..iend {
                let crow = &mut c[i * n..(i + 1) * n];
                let arow = &a[i * k..(i + 1) * k];
                for p in kb..kend {
                    let av = arow[p];
                    let brow = &b[p * n..(p + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// Fixed 8-bin reduction tree shared by both dist2 implementations —
/// matching trees is what makes the horizontal sum bit-identical.
#[inline]
fn reduce8(b: &[f32; 8]) -> f32 {
    let s0 = b[0] + b[4];
    let s1 = b[1] + b[5];
    let s2 = b[2] + b[6];
    let s3 = b[3] + b[7];
    (s0 + s2) + (s1 + s3)
}

/// Per-element fold of a whole unary chain — one traversal, chain applied
/// in order to each element. The oracle for the vectorized epilogue.
fn epilogue_scalar(xs: &mut [f32], ops: &[UnaryKind]) {
    for x in xs {
        let mut v = *x;
        for op in ops {
            v = op.apply(v);
        }
        *x = v;
    }
}

/// Scalar dist2 with the same striped accumulation the 8-lane kernel uses:
/// element `i` lands in bin `i % 8`, bins combine through [`reduce8`].
fn dist2_scalar(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let mut bins = [0.0f32; 8];
    for i in 0..n {
        let d = x[i] - y[i];
        bins[i % 8] += d * d;
    }
    reduce8(&bins)
}

static SCALAR: Kernels = Kernels {
    name: "scalar",
    simd: false,
    unary: unary_scalar,
    binary: binary_scalar,
    gemm_acc: gemm_acc_scalar,
    dist2: dist2_scalar,
    epilogue: epilogue_scalar,
};

// ---------------------------------------------------------------------------
// f32x8 AVX kernels (x86-64 only; selected after runtime detection).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{BinaryKind, Kernels, UnaryKind};
    use std::arch::x86_64::*;

    pub(super) static KERNELS: Kernels = Kernels {
        name: "avx2 (f32x8)",
        simd: true,
        unary: unary,
        binary: binary,
        gemm_acc: gemm_acc,
        dist2: dist2,
        epilogue: epilogue,
    };

    fn unary(op: UnaryKind, xs: &mut [f32]) {
        // SAFETY: this table is only reachable after
        // `is_x86_feature_detected!("avx2")` returned true.
        unsafe { unary_impl(op, xs) }
    }

    fn binary(op: BinaryKind, a: &mut [f32], b: &[f32]) {
        // SAFETY: as above — avx2 verified before table selection.
        unsafe { binary_impl(op, a, b) }
    }

    fn gemm_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        // SAFETY: as above — avx2 verified before table selection.
        unsafe { gemm_acc_impl(c, a, b, m, k, n) }
    }

    fn dist2(x: &[f32], y: &[f32]) -> f32 {
        // SAFETY: as above — avx2 verified before table selection.
        unsafe { dist2_impl(x, y) }
    }

    fn epilogue(xs: &mut [f32], ops: &[UnaryKind]) {
        // SAFETY: as above — avx2 verified before table selection.
        unsafe { epilogue_impl(xs, ops) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn unary_impl(op: UnaryKind, xs: &mut [f32]) {
        let n = xs.len();
        let p = xs.as_mut_ptr();
        let mut i = 0;
        match op {
            UnaryKind::AddScalar(s) => {
                let vs = _mm256_set1_ps(s);
                while i + 8 <= n {
                    let v = _mm256_loadu_ps(p.add(i));
                    _mm256_storeu_ps(p.add(i), _mm256_add_ps(v, vs));
                    i += 8;
                }
            }
            UnaryKind::MulScalar(s) => {
                let vs = _mm256_set1_ps(s);
                while i + 8 <= n {
                    let v = _mm256_loadu_ps(p.add(i));
                    _mm256_storeu_ps(p.add(i), _mm256_mul_ps(v, vs));
                    i += 8;
                }
            }
            UnaryKind::Sqrt => {
                while i + 8 <= n {
                    let v = _mm256_loadu_ps(p.add(i));
                    _mm256_storeu_ps(p.add(i), _mm256_sqrt_ps(v));
                    i += 8;
                }
            }
            UnaryKind::Abs => {
                // Clear the sign bit: bit-identical to `f32::abs`.
                let mask = _mm256_set1_ps(-0.0);
                while i + 8 <= n {
                    let v = _mm256_loadu_ps(p.add(i));
                    _mm256_storeu_ps(p.add(i), _mm256_andnot_ps(mask, v));
                    i += 8;
                }
            }
            UnaryKind::Neg => {
                // Flip the sign bit: bit-identical to scalar negation.
                let mask = _mm256_set1_ps(-0.0);
                while i + 8 <= n {
                    let v = _mm256_loadu_ps(p.add(i));
                    _mm256_storeu_ps(p.add(i), _mm256_xor_ps(v, mask));
                    i += 8;
                }
            }
            UnaryKind::Relu => {
                // `and(x, x > 0)`: lanes where x > 0 keep their bits, all
                // others (including NaN and -0.0) become +0.0 — exactly the
                // scalar branch's result.
                let zero = _mm256_setzero_ps();
                while i + 8 <= n {
                    let v = _mm256_loadu_ps(p.add(i));
                    let keep = _mm256_cmp_ps::<_CMP_GT_OQ>(v, zero);
                    _mm256_storeu_ps(p.add(i), _mm256_and_ps(v, keep));
                    i += 8;
                }
            }
            // Transcendentals stay scalar: the tail loop below (entered
            // with i == 0) processes the whole slice via `op.apply`.
            UnaryKind::Pow(_) | UnaryKind::Exp => {}
        }
        while i < n {
            *p.add(i) = op.apply(*p.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn binary_impl(op: BinaryKind, a: &mut [f32], b: &[f32]) {
        let n = a.len().min(b.len());
        let pa = a.as_mut_ptr();
        let pb = b.as_ptr();
        let mut i = 0;
        match op {
            BinaryKind::Add => {
                while i + 8 <= n {
                    let va = _mm256_loadu_ps(pa.add(i));
                    let vb = _mm256_loadu_ps(pb.add(i));
                    _mm256_storeu_ps(pa.add(i), _mm256_add_ps(va, vb));
                    i += 8;
                }
            }
            BinaryKind::Sub => {
                while i + 8 <= n {
                    let va = _mm256_loadu_ps(pa.add(i));
                    let vb = _mm256_loadu_ps(pb.add(i));
                    _mm256_storeu_ps(pa.add(i), _mm256_sub_ps(va, vb));
                    i += 8;
                }
            }
            BinaryKind::Mul => {
                while i + 8 <= n {
                    let va = _mm256_loadu_ps(pa.add(i));
                    let vb = _mm256_loadu_ps(pb.add(i));
                    _mm256_storeu_ps(pa.add(i), _mm256_mul_ps(va, vb));
                    i += 8;
                }
            }
            BinaryKind::Div => {
                while i + 8 <= n {
                    let va = _mm256_loadu_ps(pa.add(i));
                    let vb = _mm256_loadu_ps(pb.add(i));
                    _mm256_storeu_ps(pa.add(i), _mm256_div_ps(va, vb));
                    i += 8;
                }
            }
            // Branchy op stays scalar (tail loop covers the whole slice).
            BinaryKind::DivOrZero => {}
        }
        while i < n {
            *pa.add(i) = op.apply(*pa.add(i), *pb.add(i));
            i += 1;
        }
    }

    /// Register-blocked gemm-accumulate: k-strips of `KB`, B packed into a
    /// contiguous `KB×8` column panel per j-block (A rows are already
    /// contiguous along k), 4×8 micro-kernel holding four accumulators in
    /// registers across the whole strip. Per element the arithmetic is the
    /// scalar reference's exact sequence: load `c`, add `a·b` for `p`
    /// ascending, store — mul and add kept separate (no FMA contraction).
    #[target_feature(enable = "avx2")]
    unsafe fn gemm_acc_impl(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        const KB: usize = 256;
        const NR: usize = 8;
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let cp = c.as_mut_ptr();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let jmax = n - n % NR;
        let mut bpack = [0.0f32; KB * NR];
        let mut kb = 0;
        while kb < k {
            let kend = (kb + KB).min(k);
            let kl = kend - kb;
            let mut jb = 0;
            while jb < jmax {
                // Pack the KB×8 B panel (strided rows → contiguous).
                for t in 0..kl {
                    std::ptr::copy_nonoverlapping(
                        bp.add((kb + t) * n + jb),
                        bpack.as_mut_ptr().add(t * NR),
                        NR,
                    );
                }
                let bpp = bpack.as_ptr();
                let mut i = 0;
                while i + 4 <= m {
                    let r0 = i * n + jb;
                    let r1 = (i + 1) * n + jb;
                    let r2 = (i + 2) * n + jb;
                    let r3 = (i + 3) * n + jb;
                    let mut acc0 = _mm256_loadu_ps(cp.add(r0));
                    let mut acc1 = _mm256_loadu_ps(cp.add(r1));
                    let mut acc2 = _mm256_loadu_ps(cp.add(r2));
                    let mut acc3 = _mm256_loadu_ps(cp.add(r3));
                    for t in 0..kl {
                        let vb = _mm256_loadu_ps(bpp.add(t * NR));
                        let a0 = _mm256_set1_ps(*ap.add(i * k + kb + t));
                        let a1 = _mm256_set1_ps(*ap.add((i + 1) * k + kb + t));
                        let a2 = _mm256_set1_ps(*ap.add((i + 2) * k + kb + t));
                        let a3 = _mm256_set1_ps(*ap.add((i + 3) * k + kb + t));
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(a0, vb));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(a1, vb));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(a2, vb));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(a3, vb));
                    }
                    _mm256_storeu_ps(cp.add(r0), acc0);
                    _mm256_storeu_ps(cp.add(r1), acc1);
                    _mm256_storeu_ps(cp.add(r2), acc2);
                    _mm256_storeu_ps(cp.add(r3), acc3);
                    i += 4;
                }
                while i < m {
                    let mut acc = _mm256_loadu_ps(cp.add(i * n + jb));
                    for t in 0..kl {
                        let vb = _mm256_loadu_ps(bpp.add(t * NR));
                        let av = _mm256_set1_ps(*ap.add(i * k + kb + t));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, vb));
                    }
                    _mm256_storeu_ps(cp.add(i * n + jb), acc);
                    i += 1;
                }
                jb += NR;
            }
            // Column tail (n % 8): scalar, same ascending-p order.
            for i in 0..m {
                for j in jmax..n {
                    let mut acc = *cp.add(i * n + j);
                    for p in kb..kend {
                        acc += *ap.add(i * k + p) * *bp.add(p * n + j);
                    }
                    *cp.add(i * n + j) = acc;
                }
            }
            kb = kend;
        }
    }

    /// Vectorized epilogue: the whole unary chain stays in one register per
    /// 8-lane strip, applied op by op (the same order the scalar fold
    /// uses). Chains containing a transcendental fall back to the scalar
    /// fold wholesale — mixing lane ops with scalar `powf`/`exp` per strip
    /// would still be bit-identical, but delegating keeps one oracle.
    #[target_feature(enable = "avx2")]
    unsafe fn epilogue_impl(xs: &mut [f32], ops: &[UnaryKind]) {
        if ops
            .iter()
            .any(|op| matches!(op, UnaryKind::Pow(_) | UnaryKind::Exp))
        {
            for x in xs {
                let mut v = *x;
                for op in ops {
                    v = op.apply(v);
                }
                *x = v;
            }
            return;
        }
        let n = xs.len();
        let p = xs.as_mut_ptr();
        let sign = _mm256_set1_ps(-0.0);
        let zero = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let mut v = _mm256_loadu_ps(p.add(i));
            for &op in ops {
                v = match op {
                    UnaryKind::AddScalar(s) => _mm256_add_ps(v, _mm256_set1_ps(s)),
                    UnaryKind::MulScalar(s) => _mm256_mul_ps(v, _mm256_set1_ps(s)),
                    UnaryKind::Sqrt => _mm256_sqrt_ps(v),
                    UnaryKind::Abs => _mm256_andnot_ps(sign, v),
                    UnaryKind::Neg => _mm256_xor_ps(v, sign),
                    UnaryKind::Relu => _mm256_and_ps(v, _mm256_cmp_ps::<_CMP_GT_OQ>(v, zero)),
                    // Excluded above.
                    UnaryKind::Pow(_) | UnaryKind::Exp => unreachable!(),
                };
            }
            _mm256_storeu_ps(p.add(i), v);
            i += 8;
        }
        while i < n {
            let mut v = *p.add(i);
            for op in ops {
                v = op.apply(v);
            }
            *p.add(i) = v;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dist2_impl(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let px = x.as_ptr();
        let py = y.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(px.add(i)), _mm256_loadu_ps(py.add(i)));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            i += 8;
        }
        // Lane l of `acc` holds exactly the i ≡ l (mod 8) partials, in
        // ascending order — the scalar reference's bins. Tail elements
        // append to the same bins, then both sides share `reduce8`.
        let mut bins = [0.0f32; 8];
        _mm256_storeu_ps(bins.as_mut_ptr(), acc);
        while i < n {
            let d = *px.add(i) - *py.add(i);
            bins[i % 8] += d * d;
            i += 1;
        }
        super::reduce8(&bins)
    }
}

// ---------------------------------------------------------------------------
// Table selection — once per process.
// ---------------------------------------------------------------------------

/// The portable scalar reference table (also the property-test oracle).
pub fn scalar() -> &'static Kernels {
    &SCALAR
}

/// The best table this CPU supports, ignoring the `DSARRAY_NO_SIMD`
/// override — what benches use to measure scalar-vs-SIMD side by side.
pub fn detected() -> &'static Kernels {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return &avx2::KERNELS;
        }
    }
    &SCALAR
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The process-wide table: feature detection runs once, on first use, and
/// honors `DSARRAY_NO_SIMD=1` (the scalar-fallback CI lane). All hot paths
/// (and the `Runtime`, which stores the resolved reference) go through
/// this.
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(|| {
        let forced_off = std::env::var("DSARRAY_NO_SIMD").map(|v| v == "1").unwrap_or(false);
        if forced_off {
            &SCALAR
        } else {
            detected()
        }
    })
}

// ---------------------------------------------------------------------------
// SIMD hit accounting (process-global; overlaid onto Metrics snapshots).
// ---------------------------------------------------------------------------

static SIMD_HITS: AtomicU64 = AtomicU64::new(0);

/// Record one block-level kernel dispatch against `k` (counted only when
/// the table is a SIMD one). Process-global so every executor backend is
/// covered by the same counter; `Runtime::metrics` folds it into the
/// snapshot as `simd_kernel_hits`.
#[inline]
pub fn record_hit(k: &Kernels) {
    if k.simd {
        SIMD_HITS.fetch_add(1, Ordering::Relaxed);
    }
}

/// Total block-level SIMD kernel dispatches in this process.
pub fn simd_kernel_hits() -> u64 {
    SIMD_HITS.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Intra-block parallelism: split plan + executor hook.
// ---------------------------------------------------------------------------

/// Below this many scalar ops a block task never splits (the sub-task
/// machinery costs more than it saves). Default: 256 Ki ops.
const DEFAULT_SPLIT_MIN: usize = 1 << 18;

/// Hard cap on parts per split — deques hold at most this many helper
/// tokens per fat task.
pub const MAX_PARTS: usize = 8;

static SPLIT_MIN: AtomicUsize = AtomicUsize::new(DEFAULT_SPLIT_MIN);

/// Current split threshold, in approximate scalar ops per block task.
pub fn split_min() -> usize {
    SPLIT_MIN.load(Ordering::Relaxed)
}

/// Set the split threshold (tests/benches force or forbid splitting with
/// tiny/huge values; `usize::MAX` disables splitting entirely). Returns the
/// previous value so callers can restore it.
pub fn set_split_min(min: usize) -> usize {
    SPLIT_MIN.swap(min.max(1), Ordering::Relaxed)
}

/// How many parts a task of `work` scalar ops should split into: 1 below
/// the threshold, otherwise `work / split_min` clamped by `max_parts` (the
/// caller's structural limit, e.g. row count) and [`MAX_PARTS`]. The plan
/// depends only on `work` and the threshold — never on worker count — and
/// parts are disjoint output ranges, so results are split-plan independent.
pub fn plan_parts(work: usize, max_parts: usize) -> usize {
    let min = SPLIT_MIN.load(Ordering::Relaxed).max(1);
    if max_parts <= 1 || work < min.saturating_mul(2) {
        return 1;
    }
    (work / min).min(max_parts).min(MAX_PARTS)
}

/// Executor-side helper pool: `run(parts, f)` executes `f(0..parts)` with
/// sibling workers' help and returns true, or returns false when it cannot
/// help (caller then runs the parts inline). Implementations must not
/// return until every part has finished — `f` borrows the caller's stack.
pub trait IntraPool: Send + Sync {
    fn run(&self, parts: usize, f: &(dyn Fn(usize) + Sync)) -> bool;
}

thread_local! {
    static POOL: RefCell<Option<Arc<dyn IntraPool>>> = const { RefCell::new(None) };
}

/// Install (or clear) this thread's helper pool. The local executor calls
/// this at the top of each worker loop; threads without a pool fall back
/// to inline execution in [`parallel_for`].
pub fn install_pool(pool: Option<Arc<dyn IntraPool>>) {
    POOL.with(|p| *p.borrow_mut() = pool);
}

/// Run `run(p)` for every `p in 0..parts`, farming parts out through the
/// installed [`IntraPool`] when there is one. Returns true when a pool
/// actually helped; the inline fallback runs parts in ascending order.
/// Either way, all parts have completed when this returns.
pub fn parallel_for(parts: usize, run: &(dyn Fn(usize) + Sync)) -> bool {
    if parts > 1 {
        let pool = POOL.with(|p| p.borrow().clone());
        if let Some(pool) = pool {
            if pool.run(parts, run) {
                return true;
            }
        }
    }
    for p in 0..parts {
        run(p);
    }
    false
}

/// Raw-pointer wrapper that lets split closures write disjoint ranges of
/// one output buffer from helper threads. Safety contract: every part
/// touches a distinct range, and the originator blocks until all parts
/// finish (enforced by [`IntraPool::run`] / the inline fallback).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

// SAFETY: SendPtr is only used to hand disjoint sub-ranges of one buffer
// to scoped helpers that finish before the owning borrow ends.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        Self(p)
    }
    pub fn get(&self) -> *mut T {
        self.0
    }
}

// ---------------------------------------------------------------------------
// Split-aware slice helpers (the fused expression engine's entry points).
// ---------------------------------------------------------------------------

/// Elements below which a chunk is never worth a helper token.
const CHUNK_FLOOR: usize = 4096;

/// In-place unary over a slice, split into lane-aligned chunks when large.
pub fn unary_par(ker: &'static Kernels, op: UnaryKind, xs: &mut [f32]) {
    let n = xs.len();
    let parts = plan_parts(n, n / CHUNK_FLOOR);
    if parts <= 1 {
        return (ker.unary)(op, xs);
    }
    let chunk = chunk8(n, parts);
    let base = SendPtr::new(xs.as_mut_ptr());
    parallel_for(parts, &|p| {
        let lo = p * chunk;
        if lo >= n {
            return;
        }
        let hi = (lo + chunk).min(n);
        // SAFETY: chunks are disjoint and the borrow outlives all parts.
        let s = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        (ker.unary)(op, s);
    });
}

/// In-place binary over two slices, split into lane-aligned chunks.
pub fn binary_par(ker: &'static Kernels, op: BinaryKind, a: &mut [f32], b: &[f32]) {
    let n = a.len().min(b.len());
    let parts = plan_parts(n, n / CHUNK_FLOOR);
    if parts <= 1 {
        return (ker.binary)(op, a, b);
    }
    let chunk = chunk8(n, parts);
    let base = SendPtr::new(a.as_mut_ptr());
    parallel_for(parts, &|p| {
        let lo = p * chunk;
        if lo >= n {
            return;
        }
        let hi = (lo + chunk).min(n);
        // SAFETY: chunks are disjoint and the borrow outlives all parts.
        let s = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        (ker.binary)(op, s, &b[lo..hi]);
    });
}

/// Row-broadcast: `a[r][j] = op(a[r][j], row[j])` for every row of the
/// `rows×cols` buffer `a`, split on row boundaries when large.
pub fn bcast_par(ker: &'static Kernels, op: BinaryKind, a: &mut [f32], cols: usize, row: &[f32]) {
    if cols == 0 {
        return;
    }
    let rows = a.len() / cols;
    let parts = plan_parts(rows * cols, rows);
    if parts <= 1 {
        for r in 0..rows {
            (ker.binary)(op, &mut a[r * cols..(r + 1) * cols], row);
        }
        return;
    }
    let rchunk = rows.div_ceil(parts);
    let base = SendPtr::new(a.as_mut_ptr());
    parallel_for(parts, &|p| {
        let r0 = p * rchunk;
        if r0 >= rows {
            return;
        }
        let r1 = (r0 + rchunk).min(rows);
        for r in r0..r1 {
            // SAFETY: row ranges are disjoint per part.
            let s = unsafe { std::slice::from_raw_parts_mut(base.get().add(r * cols), cols) };
            (ker.binary)(op, s, row);
        }
    });
}

/// Chunk size covering `n` in `parts` pieces, rounded up to a multiple of
/// 8 so SIMD chunks stay lane-aligned (correctness never depends on this —
/// elementwise ops are element-independent — it only avoids split tails).
fn chunk8(n: usize, parts: usize) -> usize {
    (n.div_ceil(parts) + 7) & !7
}

/// Unit tests mutating the process-global split threshold serialize on
/// this guard (the test binary runs tests concurrently, and an unrelated
/// test observing a transiently-huge threshold would skip its split).
#[cfg(test)]
pub(crate) fn split_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..n).map(|i| (i as f32 - 7.5) * 0.37).collect();
        let b: Vec<f32> = (0..n).map(|i| ((i * 3) % 11) as f32 - 5.0).collect();
        (a, b)
    }

    #[test]
    fn detected_unary_bit_identical_to_scalar() {
        for op in [
            UnaryKind::AddScalar(1.5),
            UnaryKind::MulScalar(-0.25),
            UnaryKind::Pow(2.0),
            UnaryKind::Sqrt,
            UnaryKind::Abs,
            UnaryKind::Exp,
            UnaryKind::Neg,
            UnaryKind::Relu,
        ] {
            for n in [0usize, 1, 7, 8, 9, 64, 133] {
                let (base, _) = vecs(n);
                let mut s = base.clone();
                let mut v = base.clone();
                (scalar().unary)(op, &mut s);
                (detected().unary)(op, &mut v);
                let sb: Vec<u32> = s.iter().map(|x| x.to_bits()).collect();
                let vb: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
                assert_eq!(sb, vb, "{op:?} len {n}");
            }
        }
    }

    #[test]
    fn detected_binary_bit_identical_to_scalar() {
        use BinaryKind::*;
        for op in [Add, Sub, Mul, Div, DivOrZero] {
            for n in [0usize, 1, 8, 13, 100] {
                let (base, mut b) = vecs(n);
                if n > 4 {
                    b[2] = 0.0; // Div/DivOrZero divergence point
                    b[4] = f32::INFINITY;
                }
                let mut s = base.clone();
                let mut v = base.clone();
                (scalar().binary)(op, &mut s, &b);
                (detected().binary)(op, &mut v, &b);
                let sb: Vec<u32> = s.iter().map(|x| x.to_bits()).collect();
                let vb: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
                assert_eq!(sb, vb, "{op:?} len {n}");
            }
        }
    }

    #[test]
    fn detected_gemm_and_dist2_bit_identical_to_scalar() {
        for (m, k, n) in [(0, 3, 3), (1, 1, 1), (4, 8, 8), (5, 300, 9), (13, 17, 23)] {
            let a: Vec<f32> = (0..m * k).map(|i| ((i * 7) % 13) as f32 - 6.0).collect();
            let b: Vec<f32> = (0..k * n).map(|i| ((i * 5) % 9) as f32 * 0.5 - 2.0).collect();
            let mut cs = vec![0.25f32; m * n];
            let mut cv = cs.clone();
            (scalar().gemm_acc)(&mut cs, &a, &b, m, k, n);
            (detected().gemm_acc)(&mut cv, &a, &b, m, k, n);
            let sb: Vec<u32> = cs.iter().map(|x| x.to_bits()).collect();
            let vb: Vec<u32> = cv.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, vb, "gemm {m}x{k}x{n}");
        }
        for n in [0usize, 1, 8, 9, 65] {
            let (x, y) = vecs(n);
            let ds = (scalar().dist2)(&x, &y);
            let dv = (detected().dist2)(&x, &y);
            assert_eq!(ds.to_bits(), dv.to_bits(), "dist2 len {n}");
        }
    }

    #[test]
    fn relu_edge_cases_match_scalar_branch() {
        let xs = [f32::NAN, -0.0, 0.0, -3.5, 2.25, f32::INFINITY, f32::NEG_INFINITY, 1e-38];
        let mut s = xs;
        let mut v = xs;
        (scalar().unary)(UnaryKind::Relu, &mut s);
        (detected().unary)(UnaryKind::Relu, &mut v);
        let sb: Vec<u32> = s.iter().map(|x| x.to_bits()).collect();
        let vb: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
        assert_eq!(sb, vb);
        // NaN and -0.0 both land on +0.0 exactly.
        assert_eq!(s[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(s[1].to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn epilogue_bit_identical_to_sequential_unary_passes() {
        let chains: &[&[UnaryKind]] = &[
            &[],
            &[UnaryKind::Relu],
            &[UnaryKind::MulScalar(0.5), UnaryKind::AddScalar(-1.25)],
            &[
                UnaryKind::MulScalar(-2.0),
                UnaryKind::AddScalar(3.0),
                UnaryKind::Relu,
            ],
            &[UnaryKind::Abs, UnaryKind::Sqrt, UnaryKind::Neg],
            // Transcendental in the chain: both tables run the scalar fold.
            &[UnaryKind::MulScalar(0.1), UnaryKind::Exp, UnaryKind::Relu],
            &[UnaryKind::Abs, UnaryKind::Pow(1.5)],
        ];
        for ops in chains {
            for n in [0usize, 1, 7, 8, 9, 64, 133] {
                let (base, _) = vecs(n);
                // Oracle: the chain as sequential full passes of the scalar
                // unary kernel — what the unfused task stream computes.
                let mut seq = base.clone();
                for &op in *ops {
                    (scalar().unary)(op, &mut seq);
                }
                let mut s = base.clone();
                (scalar().epilogue)(&mut s, ops);
                let mut v = base.clone();
                (detected().epilogue)(&mut v, ops);
                let qb: Vec<u32> = seq.iter().map(|x| x.to_bits()).collect();
                let sb: Vec<u32> = s.iter().map(|x| x.to_bits()).collect();
                let vb: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
                assert_eq!(qb, sb, "{ops:?} len {n} (scalar fold vs passes)");
                assert_eq!(sb, vb, "{ops:?} len {n} (simd vs scalar)");
            }
        }
    }

    #[test]
    fn split_plan_respects_threshold_and_caps() {
        let _g = split_guard();
        let old = set_split_min(1000);
        assert_eq!(plan_parts(100, 64), 1, "below threshold");
        assert_eq!(plan_parts(1999, 64), 1, "below 2x threshold");
        assert_eq!(plan_parts(4000, 64), 4);
        assert_eq!(plan_parts(1_000_000, 64), MAX_PARTS, "hard cap");
        assert_eq!(plan_parts(4000, 3), 3, "structural cap");
        assert_eq!(plan_parts(4000, 1), 1);
        set_split_min(usize::MAX);
        assert_eq!(plan_parts(usize::MAX / 2, 64), 1, "disabled");
        set_split_min(old);
    }

    #[test]
    fn parallel_for_inline_covers_every_part_in_order() {
        // No pool installed on this thread: inline, ascending.
        let seen = std::sync::Mutex::new(Vec::new());
        let helped = parallel_for(5, &|p| seen.lock().unwrap().push(p));
        assert!(!helped);
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn split_helpers_match_unsplit_bitwise() {
        let _g = split_guard();
        let old = set_split_min(1024); // force splitting on ~64k elements
        let n = 70_000;
        let (base, b) = vecs(n);
        let ker = active();
        let mut whole = base.clone();
        (ker.unary)(UnaryKind::MulScalar(1.5), &mut whole);
        let mut split = base.clone();
        unary_par(ker, UnaryKind::MulScalar(1.5), &mut split);
        assert_eq!(whole, split);

        let mut whole = base.clone();
        (ker.binary)(BinaryKind::Add, &mut whole, &b);
        let mut split = base.clone();
        binary_par(ker, BinaryKind::Add, &mut split, &b);
        assert_eq!(whole, split);

        let cols = 100;
        let row: Vec<f32> = (0..cols).map(|j| j as f32 * 0.1).collect();
        let mut whole = base.clone();
        for r in 0..n / cols {
            (ker.binary)(BinaryKind::Sub, &mut whole[r * cols..(r + 1) * cols], &row);
        }
        let mut split = base.clone();
        bcast_par(ker, BinaryKind::Sub, &mut split, cols, &row);
        assert_eq!(whole, split);
        set_split_min(old);
    }
}
