//! Property-based testing harness (proptest is unavailable offline).
//!
//! A deliberately small core: a [`Gen`] wraps the repo PRNG with sizing
//! helpers, and [`check`] runs a property over many generated cases,
//! reporting the seed of the first failing case so it can be replayed. A
//! light "shrinking" pass retries the failing case with smaller size hints.
//!
//! Used by the coordinator-invariant property tests (DESIGN.md §8).

use crate::util::rng::Xoshiro256;

/// Test-case generator: PRNG + a size hint that [`check`] ramps up.
pub struct Gen {
    pub rng: Xoshiro256,
    /// Grows from 2 to `max_size` over the run; generators should scale
    /// their output with it so early cases are small.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            size,
        }
    }

    /// usize in [lo, hi] (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// usize in [1, size].
    pub fn sized(&mut self) -> usize {
        self.usize_in(1, self.size.max(1))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of f32 drawn from [-scale, scale].
    pub fn f32_vec(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(-scale, scale)).collect()
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub max_size: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            max_size: 24,
            seed: 0xD15_1B0A,
        }
    }
}

/// Run `prop` over `cfg.cases` generated cases. Panics with the failing
/// case's seed/size on the first failure (after trying smaller sizes to
/// produce a more readable counterexample).
pub fn check_with<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Ramp size 2..=max_size across the run.
        let size = 2 + case * cfg.max_size.saturating_sub(2) / cfg.cases.max(1);
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // Shrinking-lite: retry the same seed with smaller sizes and
            // report the smallest size that still fails.
            let mut min_fail = (size, msg);
            for s in (2..size).rev() {
                let mut g2 = Gen::new(seed, s);
                if let Err(m) = prop(&mut g2) {
                    min_fail = (s, m);
                } else {
                    break;
                }
            }
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}, size {}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// [`check_with`] under the default configuration.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_with(Config::default(), name, prop)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float equality for property bodies.
pub fn close(a: f32, b: f32, tol: f32) -> bool {
    let scale = 1.0_f32.max(a.abs()).max(b.abs());
    (a - b).abs() <= tol * scale
}

pub fn all_close(a: &[f32], b: &[f32], tol: f32) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| close(x, y, tol))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", |g| {
            n += 1;
            let x = g.sized();
            if x >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, Config::default().cases);
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |_g| Err("nope".into()));
    }

    #[test]
    fn close_handles_scale() {
        assert!(close(1000.0, 1000.1, 1e-3));
        assert!(!close(0.0, 0.1, 1e-3));
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0000001], 1e-5));
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1, 10);
        for _ in 0..100 {
            let v = g.usize_in(3, 5);
            assert!((3..=5).contains(&v));
            let f = g.f32_in(-2.0, 2.0);
            assert!((-2.0..=2.0).contains(&f));
            let s = g.sized();
            assert!((1..=10).contains(&s));
        }
    }
}
