//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §3).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed getters with defaults keep call sites terse.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (subcommand first by convention).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (binary name already removed).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.options.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options
            .get(name)
            .map(|v| v != "false")
            .unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--cores 48,96,192`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["bench", "--cores", "48", "--verbose", "--out=results.txt"]);
        assert_eq!(a.subcommand(), Some("bench"));
        assert_eq!(a.get_usize("cores", 0), 48);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("results.txt"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
        assert_eq!(a.get_str("missing", "d"), "d");
        assert!(!a.flag("missing"));
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--cores", "48,96, 192"]);
        assert_eq!(a.get_usize_list("cores", &[1]), vec![48, 96, 192]);
        assert_eq!(a.get_usize_list("other", &[1, 2]), vec![1, 2]);
    }
}
