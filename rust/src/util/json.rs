//! Minimal JSON parser — just enough for `artifacts/manifest.json`
//! (serde is unavailable offline; DESIGN.md §3). Supports objects, arrays,
//! strings (with basic escapes), numbers, booleans and null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// Member access for objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            // \uXXXX (basic multilingual plane only).
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = &self.bytes[self.pos..];
                    let ch_len = match s[0] {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += ch_len;
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
  "gemm_64": {
    "inputs": [[[64, 64], "float32"], [[64, 64], "float32"]],
    "outputs": [[[64, 64], "float32"]]
  }
}"#;
        let v = parse(text).unwrap();
        let entry = v.get("gemm_64").unwrap();
        let ins = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins.len(), 2);
        let dims = ins[0].as_arr().unwrap()[0].as_arr().unwrap();
        assert_eq!(dims[0].as_usize(), Some(64));
        assert_eq!(ins[0].as_arr().unwrap()[1].as_str(), Some("float32"));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(parse("3.5e2").unwrap().as_f64(), Some(350.0));
        assert_eq!(parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(
            parse(r#""a\n\"bA""#).unwrap().as_str(),
            Some("a\n\"bA")
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_and_empty() {
        let v = parse(r#"{"a": [], "b": {}, "c": [1, [2, 3]]}"#).unwrap();
        assert!(v.get("a").unwrap().as_arr().unwrap().is_empty());
        assert!(v.get("b").unwrap().as_obj().unwrap().is_empty());
        let c = v.get("c").unwrap().as_arr().unwrap();
        assert_eq!(c[1].as_arr().unwrap()[1].as_f64(), Some(3.0));
    }
}
