//! Minimal TOML-subset parser for the config system.
//!
//! `serde`/`toml` are unavailable offline (DESIGN.md §3), so this implements
//! the subset the repo's config files need: top-level keys, `[table]`
//! headers, string / integer / float / boolean scalars, homogeneous arrays
//! of those scalars, `#` comments, and basic escape sequences in strings.
//! Keys are exposed flattened as `table.key`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`bandwidth = 12` ≡ `12.0`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse TOML text into a flat `table.key -> Value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>, ParseError> {
    let mut out = BTreeMap::new();
    let mut prefix = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                line: lineno,
                msg: "unterminated table header".into(),
            })?;
            let name = name.trim();
            if name.is_empty() || name.starts_with('[') {
                return Err(ParseError {
                    line: lineno,
                    msg: "empty or array-of-tables header (unsupported)".into(),
                });
            }
            prefix = format!("{name}.");
            continue;
        }
        let eq = line.find('=').ok_or_else(|| ParseError {
            line: lineno,
            msg: format!("expected `key = value`, got `{line}`"),
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError {
                line: lineno,
                msg: "empty key".into(),
            });
        }
        let val = parse_value(line[eq + 1..].trim(), lineno)?;
        out.insert(format!("{prefix}{key}"), val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string literal.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, ParseError> {
    let err = |msg: String| ParseError { line: lineno, msg };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(Value::Str(unescape(body, lineno)?));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?
            .trim();
        if body.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_top_level(body) {
            items.push(parse_value(part.trim(), lineno)?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value `{s}`")))
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in body.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => escaped = false,
        }
    }
    parts.push(&body[start..]);
    parts
}

fn unescape(s: &str, lineno: usize) -> Result<String, ParseError> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => {
                return Err(ParseError {
                    line: lineno,
                    msg: format!("unsupported escape `\\{}`", other.unwrap_or(' ')),
                })
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let text = r#"
# top comment
name = "marenostrum"   # inline comment
cores = 1536
sched_overhead_s = 0.004
verbose = true

[cluster]
bandwidth = 11.6e9
nodes = 32
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["name"].as_str(), Some("marenostrum"));
        assert_eq!(m["cores"].as_i64(), Some(1536));
        assert_eq!(m["sched_overhead_s"].as_f64(), Some(0.004));
        assert_eq!(m["verbose"].as_bool(), Some(true));
        assert_eq!(m["cluster.bandwidth"].as_f64(), Some(11.6e9));
        assert_eq!(m["cluster.nodes"].as_i64(), Some(32));
    }

    #[test]
    fn parses_arrays() {
        let m = parse("cores = [48, 96, 192]\nnames = [\"a\", \"b,c\"]").unwrap();
        let cores: Vec<i64> = m["cores"]
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(cores, vec![48, 96, 192]);
        let names: Vec<&str> = m["names"]
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["a", "b,c"]);
    }

    #[test]
    fn int_with_underscores() {
        let m = parse("n = 100_480_507").unwrap();
        assert_eq!(m["n"].as_i64(), Some(100_480_507));
    }

    #[test]
    fn string_escapes_and_hash_in_string() {
        let m = parse(r#"s = "a#b\nc""#).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b\nc"));
    }

    #[test]
    fn int_usable_as_float() {
        let m = parse("x = 3").unwrap();
        assert_eq!(m["x"].as_f64(), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = \"unterminated").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn rejects_array_of_tables() {
        assert!(parse("[[points]]\nx = 1").is_err());
    }
}
