//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is unavailable offline, so this module provides the two
//! generators the library needs: [`SplitMix64`] for seeding / cheap streams
//! and [`Xoshiro256`] (xoshiro256**) as the general-purpose generator used by
//! array creation routines, the shuffle operator and the test harness.
//! Both are well-studied public-domain algorithms (Blackman & Vigna).

/// SplitMix64: tiny, fast, passes BigCrush; the canonical seeder for xoshiro.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the library's general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full f32 mantissa coverage.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; creation routines dominate on the uniform path anyway).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference vector for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct() {
        let mut r1 = Xoshiro256::seed_from_u64(42);
        let mut r2 = Xoshiro256::seed_from_u64(42);
        let mut r3 = Xoshiro256::seed_from_u64(43);
        let v1: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let v2: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        let v3: Vec<u64> = (0..16).map(|_| r3.next_u64()).collect();
        assert_eq!(v1, v2);
        assert_ne!(v1, v3);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "x={x}");
        }
    }

    #[test]
    fn next_below_unbiased_bounds() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
