//! Small self-contained substrates that the offline environment forces us to
//! implement from scratch (no `rand`, `serde`, `clap`, `proptest` crates are
//! available — see DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;
