//! Layered configuration: built-in defaults ← TOML file ← CLI overrides.
//!
//! The config governs the simulator's cluster cost model and the benchmark
//! sweeps; `configs/marenostrum.toml` holds the calibration used for the
//! paper-figure reproductions.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tasking::SimConfig;
use crate::util::cli::Args;
use crate::util::toml;

/// Which [`crate::tasking::Executor`] backend `Config::runtime` builds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// In-process thread pool (optionally with an out-of-core budget).
    #[default]
    Local,
    /// Discrete-event simulator (graphs recorded, never executed).
    Sim,
    /// Multi-process coordinator over TCP workers (`dsarray worker`).
    Cluster,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "local" => Ok(Backend::Local),
            "sim" => Ok(Backend::Sim),
            "cluster" => Ok(Backend::Cluster),
            other => bail!("unknown backend `{other}` (expected local|sim|cluster)"),
        }
    }
}

/// Top-level runtime configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Execution backend for `Config::runtime` (`--backend`).
    pub backend: Backend,
    /// Worker threads for real (local) execution; on the cluster backend
    /// this is the coordinator's executor-thread count.
    pub local_workers: usize,
    /// Worker processes the cluster backend spawns on loopback when no
    /// explicit addresses are given (`--cluster-workers`).
    pub cluster_workers: usize,
    /// Addresses of already-running `dsarray worker` processes
    /// (`--cluster-addr host:port,host:port`); empty means spawn.
    pub cluster_addrs: Vec<String>,
    /// Lineage-based recovery of dead cluster workers (on by default;
    /// `--no-recovery` restores the poison-on-death contract).
    pub recovery: bool,
    /// Copies of each block kept on distinct workers
    /// (`--replicate-blocks k`, default 1 = no replication).
    pub replicate_blocks: usize,
    /// Heartbeat interval for proactive cluster liveness probes, in
    /// milliseconds (`--heartbeat-ms`, default 0 = reactive detection
    /// only). A worker missing three consecutive beats is declared dead.
    pub heartbeat_ms: u64,
    /// Straggler speculation threshold: a task running longer than this
    /// factor times its task name's running-time estimate is re-executed
    /// speculatively on another worker (`--straggler-factor`, default
    /// 0 = off; 3 is a reasonable starting point).
    pub straggler_factor: f64,
    /// Out-of-core resident-set budget for local execution; `None` keeps
    /// every block in memory (see `Runtime::local_with_budget`).
    pub memory_budget_bytes: Option<u64>,
    /// Parent directory for the out-of-core block store's spill files
    /// (each runtime creates — and removes at teardown — its own
    /// uniquely-named subdirectory under it). Only used with a budget.
    pub spill_dir: Option<String>,
    /// Simulated core counts for scaling sweeps.
    pub sim_cores: Vec<usize>,
    /// Cost model template (worker count is substituted per sweep point).
    pub sim: SimConfig,
    /// Directory with compiled HLO artifacts.
    pub artifacts_dir: String,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Serving micro-batch deadline window in milliseconds
    /// (`--batch-window-ms`, 0 disables coalescing).
    pub serve_batch_window_ms: u64,
    /// Serving row cap per coalesced batch (`--max-batch-rows`).
    pub serve_max_batch_rows: usize,
    /// Serving admission-control cap on queued rows (`--max-pending-rows`;
    /// past it requests are shed with an explicit `Overloaded` response).
    pub serve_max_pending_rows: usize,
    /// Plan-layer optimization level (`--optimizer off|cse|full`). Defaults
    /// to [`crate::plan::Level::Off`] so config-driven runs reproduce the
    /// pre-planner task streams unless opted in; the fluent
    /// [`crate::tasking::Runtime::builder`] defaults to `Full`.
    pub optimizer: crate::plan::Level,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            backend: Backend::Local,
            local_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            cluster_workers: 2,
            cluster_addrs: Vec::new(),
            recovery: true,
            replicate_blocks: 1,
            heartbeat_ms: 0,
            straggler_factor: 0.0,
            memory_budget_bytes: None,
            spill_dir: None,
            sim_cores: vec![48, 96, 192, 384, 768],
            sim: SimConfig::marenostrum(48),
            artifacts_dir: "artifacts".to_string(),
            seed: 42,
            serve_batch_window_ms: 2,
            serve_max_batch_rows: 256,
            serve_max_pending_rows: 4096,
            optimizer: crate::plan::Level::Off,
        }
    }
}

impl Config {
    /// Load from a TOML file over the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let map = toml::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Config::default();

        if let Some(v) = map.get("local_workers").and_then(|v| v.as_i64()) {
            cfg.local_workers = v as usize;
        }
        if let Some(v) = map.get("backend").and_then(|v| v.as_str()) {
            cfg.backend = Backend::parse(v)?;
        }
        if let Some(v) = map.get("cluster_workers").and_then(|v| v.as_i64()) {
            cfg.cluster_workers = v as usize;
        }
        if let Some(v) = map.get("cluster_addr").and_then(|v| v.as_str()) {
            cfg.cluster_addrs = split_addrs(v);
        }
        if let Some(v) = map.get("recovery").and_then(|v| v.as_bool()) {
            cfg.recovery = v;
        }
        if let Some(v) = map.get("replicate_blocks").and_then(|v| v.as_i64()) {
            cfg.replicate_blocks = (v.max(1)) as usize;
        }
        if let Some(v) = map.get("heartbeat_ms").and_then(|v| v.as_i64()) {
            cfg.heartbeat_ms = v.max(0) as u64;
        }
        if let Some(v) = map.get("straggler_factor").and_then(|v| v.as_f64()) {
            cfg.straggler_factor = v.max(0.0);
        }
        if let Some(v) = map.get("seed").and_then(|v| v.as_i64()) {
            cfg.seed = v as u64;
        }
        if let Some(v) = map.get("artifacts_dir").and_then(|v| v.as_str()) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = map.get("memory_budget_bytes").and_then(|v| v.as_i64()) {
            cfg.memory_budget_bytes = (v > 0).then_some(v as u64);
        }
        if let Some(v) = map.get("spill_dir").and_then(|v| v.as_str()) {
            cfg.spill_dir = Some(v.to_string());
        }
        if let Some(v) = map.get("serve_batch_window_ms").and_then(|v| v.as_i64()) {
            cfg.serve_batch_window_ms = v.max(0) as u64;
        }
        if let Some(v) = map.get("serve_max_batch_rows").and_then(|v| v.as_i64()) {
            cfg.serve_max_batch_rows = v.max(1) as usize;
        }
        if let Some(v) = map.get("serve_max_pending_rows").and_then(|v| v.as_i64()) {
            cfg.serve_max_pending_rows = v.max(1) as usize;
        }
        if let Some(v) = map.get("optimizer").and_then(|v| v.as_str()) {
            cfg.optimizer = crate::plan::Level::parse(v)?;
        }
        if let Some(arr) = map.get("sim_cores").and_then(|v| v.as_array()) {
            cfg.sim_cores = arr
                .iter()
                .filter_map(|v| v.as_i64())
                .map(|v| v as usize)
                .collect();
        }
        let s = &mut cfg.sim;
        for (key, field) in [
            ("sim.sched_task_s", &mut s.sched_task_s as *mut f64),
            ("sim.core_scale", &mut s.core_scale as *mut f64),
            ("sim.sched_edge_s", &mut s.sched_edge_s as *mut f64),
            ("sim.task_overhead_s", &mut s.task_overhead_s as *mut f64),
            ("sim.per_input_s", &mut s.per_input_s as *mut f64),
            ("sim.transfer_latency_s", &mut s.transfer_latency_s as *mut f64),
            ("sim.bandwidth_bps", &mut s.bandwidth_bps as *mut f64),
            ("sim.flops_per_s", &mut s.flops_per_s as *mut f64),
            ("sim.mem_bps", &mut s.mem_bps as *mut f64),
        ] {
            if let Some(v) = map.get(key).and_then(|v| v.as_f64()) {
                // Safety: `field` points into `cfg.sim`, alive for the loop.
                unsafe { *field = v };
            }
        }
        Ok(cfg)
    }

    /// Apply CLI overrides on top (flags mirror the TOML keys). Errors on
    /// an unknown `--backend` value instead of silently running local.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("workers") {
            if let Ok(n) = v.parse() {
                self.local_workers = n;
            }
        }
        if let Some(v) = args.get("backend") {
            self.backend = Backend::parse(v)?;
        }
        if let Some(v) = args.get("cluster-workers") {
            if let Ok(n) = v.parse() {
                self.cluster_workers = n;
            }
        }
        if let Some(v) = args.get("cluster-addr") {
            self.cluster_addrs = split_addrs(v);
        }
        if args.flag("no-recovery") {
            self.recovery = false;
        }
        if let Some(v) = args.get("replicate-blocks") {
            if let Ok(k) = v.parse::<usize>() {
                self.replicate_blocks = k.max(1);
            }
        }
        if let Some(v) = args.get("heartbeat-ms") {
            if let Ok(ms) = v.parse::<u64>() {
                self.heartbeat_ms = ms;
            }
        }
        if let Some(v) = args.get("straggler-factor") {
            if let Ok(f) = v.parse::<f64>() {
                self.straggler_factor = f.max(0.0);
            }
        }
        if let Some(v) = args.get("seed") {
            if let Ok(n) = v.parse() {
                self.seed = n;
            }
        }
        if let Some(v) = args.get("artifacts-dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = args.get("memory-budget-mb") {
            if let Ok(mb) = v.parse::<u64>() {
                self.memory_budget_bytes = (mb > 0).then_some(mb * 1024 * 1024);
            }
        }
        if let Some(v) = args.get("spill-dir") {
            self.spill_dir = Some(v.to_string());
        }
        if let Some(v) = args.get("batch-window-ms") {
            if let Ok(ms) = v.parse::<u64>() {
                self.serve_batch_window_ms = ms;
            }
        }
        if let Some(v) = args.get("max-batch-rows") {
            if let Ok(n) = v.parse::<usize>() {
                self.serve_max_batch_rows = n.max(1);
            }
        }
        if let Some(v) = args.get("max-pending-rows") {
            if let Ok(n) = v.parse::<usize>() {
                self.serve_max_pending_rows = n.max(1);
            }
        }
        if let Some(v) = args.get("optimizer") {
            self.optimizer = crate::plan::Level::parse(v)?;
        }
        if args.get("cores").is_some() {
            self.sim_cores = args.get_usize_list("cores", &self.sim_cores);
        }
        self.sim.sched_task_s = args.get_f64("sched-task-s", self.sim.sched_task_s);
        self.sim.per_input_s = args.get_f64("per-input-s", self.sim.per_input_s);
        self.sim.flops_per_s = args.get_f64("flops-per-s", self.sim.flops_per_s);
        Ok(())
    }

    /// Build the configured local runtime: worker count plus the
    /// out-of-core budget / spill directory when set.
    #[deprecated(
        since = "0.11.0",
        note = "use `Runtime::builder().from_config(&cfg).backend(Backend::Local).build()`"
    )]
    pub fn local_runtime(&self) -> Result<crate::tasking::Runtime> {
        crate::tasking::Runtime::builder()
            .from_config(self)
            .backend(Backend::Local)
            .build()
    }

    /// Build the configured runtime for the selected [`Backend`].
    #[deprecated(
        since = "0.11.0",
        note = "use `Runtime::builder().from_config(&cfg).build()`"
    )]
    pub fn runtime(&self) -> Result<crate::tasking::Runtime> {
        crate::tasking::Runtime::builder().from_config(self).build()
    }

    /// Serving-tier options from the config: micro-batch window, batch row
    /// cap, and admission control — with the byte-denominated admission cap
    /// wired to the memory budget (an eighth of it) so an overloaded server
    /// sheds instead of queueing toward OOM.
    pub fn serve_options(&self) -> crate::serving::ServeOptions {
        crate::serving::ServeOptions::default()
            .with_batch_window_ms(self.serve_batch_window_ms)
            .with_max_batch_rows(self.serve_max_batch_rows)
            .with_max_pending_rows(self.serve_max_pending_rows)
            .with_max_pending_bytes(self.memory_budget_bytes.map(|b| (b / 8).max(1)))
    }

    /// Cost model at a specific simulated core count.
    pub fn sim_at(&self, cores: usize) -> SimConfig {
        let mut s = self.sim.clone();
        s.workers = cores;
        s
    }

    /// Defaults + optional `--config <file>` + CLI overrides.
    pub fn resolve(args: &Args) -> Result<Self> {
        let mut cfg = match args.get("config") {
            Some(path) => Config::from_file(Path::new(path))?,
            None => Config::default(),
        };
        cfg.apply_args(args)?;
        Ok(cfg)
    }
}

/// `host:port,host:port` → list (whitespace tolerated).
fn split_addrs(s: &str) -> Vec<String> {
    s.split(',')
        .map(|p| p.trim().to_string())
        .filter(|p| !p.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert!(c.local_workers >= 1);
        assert!(!c.sim_cores.is_empty());
        assert!(c.sim.sched_task_s > 0.0);
    }

    #[test]
    fn file_overrides_and_cli_overrides() {
        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_cfg_{}.toml", std::process::id()));
        std::fs::write(
            &p,
            "seed = 7\nsim_cores = [8, 16]\nmemory_budget_bytes = 1048576\n[sim]\nsched_task_s = 0.001\nflops_per_s = 1e9\n",
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.memory_budget_bytes, Some(1 << 20));
        assert_eq!(cfg.sim_cores, vec![8, 16]);
        assert_eq!(cfg.sim.sched_task_s, 0.001);
        assert_eq!(cfg.sim.flops_per_s, 1e9);

        let args = Args::parse(
            [
                "--seed",
                "9",
                "--cores",
                "4",
                "--sched-task-s",
                "0.002",
                "--memory-budget-mb",
                "2",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let mut cfg2 = cfg.clone();
        cfg2.apply_args(&args).unwrap();
        assert_eq!(cfg2.seed, 9);
        assert_eq!(cfg2.sim_cores, vec![4]);
        assert_eq!(cfg2.sim.sched_task_s, 0.002);
        assert_eq!(cfg2.memory_budget_bytes, Some(2 << 20));
        let rt = crate::tasking::Runtime::builder()
            .from_config(&cfg2)
            .backend(Backend::Local)
            .build()
            .unwrap();
        assert!(!rt.is_sim());

        let sim16 = cfg2.sim_at(16);
        assert_eq!(sim16.workers, 16);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn backend_and_cluster_flags_parse() {
        let c = Config::default();
        assert_eq!(c.backend, Backend::Local);
        assert_eq!(c.cluster_workers, 2);
        assert!(c.cluster_addrs.is_empty());

        let args = Args::parse(
            [
                "--backend",
                "cluster",
                "--cluster-workers",
                "3",
                "--cluster-addr",
                "127.0.0.1:7401, 127.0.0.1:7402",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.backend, Backend::Cluster);
        assert_eq!(c.cluster_workers, 3);
        assert_eq!(
            c.cluster_addrs,
            vec!["127.0.0.1:7401".to_string(), "127.0.0.1:7402".to_string()]
        );

        // Recovery defaults on; --no-recovery and --replicate-blocks flow
        // through to the cluster options.
        assert!(c.recovery);
        assert_eq!(c.replicate_blocks, 1);
        // Elasticity knobs default off (reactive detection, no speculation).
        assert_eq!(c.heartbeat_ms, 0);
        assert_eq!(c.straggler_factor, 0.0);
        let args = Args::parse(
            [
                "--no-recovery",
                "--replicate-blocks",
                "3",
                "--heartbeat-ms",
                "250",
                "--straggler-factor",
                "3.5",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert!(!c.recovery);
        assert_eq!(c.replicate_blocks, 3);
        assert_eq!(c.heartbeat_ms, 250);
        assert_eq!(c.straggler_factor, 3.5);
        // A negative factor clamps to off instead of erroring.
        let args = Args::parse(["--straggler-factor", "-1"].iter().map(|s| s.to_string()));
        c.apply_args(&args).unwrap();
        assert_eq!(c.straggler_factor, 0.0);

        // Serving knobs default sane and flow through, with the pending-byte
        // admission cap derived from the memory budget.
        let c = Config::default();
        assert_eq!(c.serve_batch_window_ms, 2);
        assert_eq!(c.serve_max_batch_rows, 256);
        assert_eq!(c.serve_max_pending_rows, 4096);
        assert_eq!(c.serve_options().max_pending_bytes, None);
        let args = Args::parse(
            [
                "--batch-window-ms",
                "5",
                "--max-batch-rows",
                "64",
                "--max-pending-rows",
                "128",
                "--memory-budget-mb",
                "8",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        let so = c.serve_options();
        assert_eq!(so.batch_window_ms, 5);
        assert_eq!(so.max_batch_rows, 64);
        assert_eq!(so.max_pending_rows, 128);
        assert_eq!(so.max_pending_bytes, Some(1 << 20));

        let bad = Args::parse(["--backend", "mpi"].iter().map(|s| s.to_string()));
        assert!(Config::default().apply_args(&bad).is_err());
        assert!(Backend::parse("sim").is_ok());

        // The sim backend builds a record-only runtime.
        let mut c = Config::default();
        c.backend = Backend::Sim;
        let rt = crate::tasking::Runtime::builder().from_config(&c).build().unwrap();
        assert!(rt.is_sim());
    }

    #[test]
    fn optimizer_level_parses_from_file_and_cli() {
        // Config-driven runs default to Off (pre-planner task streams).
        let c = Config::default();
        assert_eq!(c.optimizer, crate::plan::Level::Off);

        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_opt_{}.toml", std::process::id()));
        std::fs::write(&p, "optimizer = \"cse\"\n").unwrap();
        let mut cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.optimizer, crate::plan::Level::Cse);
        std::fs::remove_file(&p).ok();

        let args = Args::parse(["--optimizer", "full"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optimizer, crate::plan::Level::Full);
        let rt = crate::tasking::Runtime::builder().from_config(&cfg).build().unwrap();
        assert_eq!(rt.planner().level(), crate::plan::Level::Full);

        let bad = Args::parse(["--optimizer", "mega"].iter().map(|s| s.to_string()));
        assert!(Config::default().apply_args(&bad).is_err());
    }
}
