//! The legacy `Dataset`/`Subset` structure (paper §3.2.1) — the baseline
//! every experiment compares against.
//!
//! A Dataset is a collection of samples and labels divided in Subsets; each
//! Subset stores a row panel of samples (N×M) and optionally labels (N×1).
//! Partitioning is along the sample axis **only** — the root cause of the
//! limitations §4.1 catalogues: no cheap column access, `N²+N`-task
//! transpose, pre-collections shuffle, labels welded to samples.

pub mod ops;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{CostHint, Future, Runtime};
use crate::util::rng::Xoshiro256;

/// One partition: a block of samples and (optionally) a block of labels.
#[derive(Clone, Copy, Debug)]
pub struct Subset {
    pub samples: Future,
    pub labels: Option<Future>,
}

impl Subset {
    pub fn n_samples(&self) -> usize {
        self.samples.meta.rows
    }
}

/// The paper's baseline distributed structure (Fig 2).
#[derive(Clone)]
pub struct Dataset {
    pub(crate) rt: Runtime,
    pub(crate) subsets: Vec<Subset>,
    pub(crate) n_features: usize,
    pub(crate) sparse: bool,
}

impl Dataset {
    pub fn n_subsets(&self) -> usize {
        self.subsets.len()
    }

    pub fn n_samples(&self) -> usize {
        self.subsets.iter().map(|s| s.n_samples()).sum()
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn has_labels(&self) -> bool {
        self.subsets.iter().all(|s| s.labels.is_some())
    }

    pub fn subset(&self, i: usize) -> &Subset {
        &self.subsets[i]
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Subset size of partition `i` (the paper's `subset_size`).
    pub fn subset_size(&self, i: usize) -> usize {
        self.subsets[i].n_samples()
    }

    /// Append another Subset (the paper's `append`).
    pub fn append(&mut self, s: Subset) -> Result<()> {
        if s.samples.meta.cols != self.n_features {
            bail!(
                "appended subset has {} features, dataset has {}",
                s.samples.meta.cols,
                self.n_features
            );
        }
        self.subsets.push(s);
        Ok(())
    }

    /// Build from an in-memory matrix (+ optional labels), split into
    /// `n_subsets` row panels as evenly as possible.
    pub fn from_matrix(
        rt: &Runtime,
        samples: &DenseMatrix,
        labels: Option<&DenseMatrix>,
        n_subsets: usize,
    ) -> Result<Self> {
        if n_subsets == 0 || n_subsets > samples.rows() {
            bail!(
                "n_subsets {n_subsets} invalid for {} samples",
                samples.rows()
            );
        }
        if let Some(l) = labels {
            if l.rows() != samples.rows() || l.cols() != 1 {
                bail!("labels must be {}x1", samples.rows());
            }
        }
        let mut subsets = Vec::with_capacity(n_subsets);
        let base = samples.rows() / n_subsets;
        let extra = samples.rows() % n_subsets;
        let mut r0 = 0;
        for i in 0..n_subsets {
            let r = base + usize::from(i < extra);
            let s = rt.put_block(Block::Dense(samples.slice(r0, 0, r, samples.cols())?));
            let l = match labels {
                Some(l) => Some(rt.put_block(Block::Dense(l.slice(r0, 0, r, 1)?))),
                None => None,
            };
            subsets.push(Subset {
                samples: s,
                labels: l,
            });
            r0 += r;
        }
        Ok(Self {
            rt: rt.clone(),
            subsets,
            n_features: samples.cols(),
            sparse: false,
        })
    }

    /// Random dataset: one creation task per Subset (mirrors dislib's
    /// parallel loaders, works in sim mode through phantom blocks).
    pub fn random(
        rt: &Runtime,
        n_samples: usize,
        n_features: usize,
        n_subsets: usize,
        seed: u64,
    ) -> Result<Self> {
        if n_subsets == 0 || n_subsets > n_samples {
            bail!("n_subsets {n_subsets} invalid for {n_samples} samples");
        }
        let base = n_samples / n_subsets;
        let extra = n_samples % n_subsets;
        let mut subsets = Vec::with_capacity(n_subsets);
        for i in 0..n_subsets {
            let r = base + usize::from(i < extra);
            let meta = BlockMeta::dense(r, n_features);
            let sseed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let out = rt.submit(
                "dataset.create.random",
                &[],
                vec![meta],
                CostHint::default().with_bytes(meta.bytes() as f64),
                Arc::new(move |_| {
                    let mut rng = Xoshiro256::seed_from_u64(sseed);
                    let data: Vec<f32> = (0..r * n_features).map(|_| rng.next_f32()).collect();
                    Ok(vec![Block::Dense(DenseMatrix::from_vec(r, n_features, data)?)])
                }),
            );
            subsets.push(Subset {
                samples: out[0],
                labels: None,
            });
        }
        Ok(Self {
            rt: rt.clone(),
            subsets,
            n_features,
            sparse: false,
        })
    }

    /// Metadata-only Dataset for simulation (pre-loaded phantom Subsets,
    /// no creation tasks) — see `dsarray::creation::phantom`.
    pub fn phantom(
        rt: &Runtime,
        n_samples: usize,
        n_features: usize,
        n_subsets: usize,
        density: Option<f64>,
    ) -> Result<Self> {
        if n_subsets == 0 || n_subsets > n_samples {
            bail!("n_subsets {n_subsets} invalid for {n_samples} samples");
        }
        let base = n_samples / n_subsets;
        let extra = n_samples % n_subsets;
        let mut subsets = Vec::with_capacity(n_subsets);
        for i in 0..n_subsets {
            let r = base + usize::from(i < extra);
            let meta = match density {
                Some(d) => {
                    BlockMeta::sparse(r, n_features, ((r * n_features) as f64 * d).round() as usize)
                }
                None => BlockMeta::dense(r, n_features),
            };
            subsets.push(Subset {
                samples: rt.put_block(Block::Phantom(meta)),
                labels: None,
            });
        }
        Ok(Self {
            rt: rt.clone(),
            subsets,
            n_features,
            sparse: density.is_some(),
        })
    }

    /// Synchronize and stack all samples (the paper's `.samples` accessor —
    /// a full synchronization point).
    pub fn collect_samples(&self) -> Result<DenseMatrix> {
        let mut parts = Vec::with_capacity(self.subsets.len());
        for s in &self.subsets {
            parts.push(self.rt.wait(s.samples)?.to_dense()?);
        }
        let refs: Vec<&DenseMatrix> = parts.iter().collect();
        DenseMatrix::vstack(&refs)
    }

    /// Synchronize and stack all labels.
    pub fn collect_labels(&self) -> Result<DenseMatrix> {
        let mut parts = Vec::with_capacity(self.subsets.len());
        for s in &self.subsets {
            let l = s.labels.ok_or_else(|| anyhow::anyhow!("dataset has no labels"))?;
            parts.push(self.rt.wait(l)?.to_dense()?);
        }
        let refs: Vec<&DenseMatrix> = parts.iter().collect();
        DenseMatrix::vstack(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_matrix_round_trip_with_labels() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(10, 4, |i, j| (i * 4 + j) as f32);
        let l = DenseMatrix::from_fn(10, 1, |i, _| (i % 3) as f32);
        let ds = Dataset::from_matrix(&rt, &m, Some(&l), 3).unwrap();
        assert_eq!(ds.n_subsets(), 3);
        assert_eq!(ds.n_samples(), 10);
        // 10 = 4 + 3 + 3.
        assert_eq!(ds.subset_size(0), 4);
        assert_eq!(ds.subset_size(2), 3);
        assert!(ds.has_labels());
        assert_eq!(ds.collect_samples().unwrap(), m);
        assert_eq!(ds.collect_labels().unwrap(), l);
    }

    #[test]
    fn append_checks_features() {
        let rt = Runtime::local(1);
        let m = DenseMatrix::zeros(4, 3);
        let mut ds = Dataset::from_matrix(&rt, &m, None, 2).unwrap();
        let good = rt.put_block(Block::Dense(DenseMatrix::zeros(2, 3)));
        ds.append(Subset {
            samples: good,
            labels: None,
        })
        .unwrap();
        assert_eq!(ds.n_subsets(), 3);
        let bad = rt.put_block(Block::Dense(DenseMatrix::zeros(2, 4)));
        assert!(ds
            .append(Subset {
                samples: bad,
                labels: None
            })
            .is_err());
    }

    #[test]
    fn random_one_task_per_subset() {
        let rt = Runtime::local(2);
        let ds = Dataset::random(&rt, 100, 8, 5, 1).unwrap();
        assert_eq!(rt.metrics().tasks_for("dataset.create.random"), 5);
        let m = ds.collect_samples().unwrap();
        assert_eq!((m.rows(), m.cols()), (100, 8));
        assert!(m.data().iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn collect_labels_fails_without_labels() {
        let rt = Runtime::local(1);
        let ds = Dataset::random(&rt, 10, 2, 2, 0).unwrap();
        assert!(!ds.has_labels());
        assert!(ds.collect_labels().is_err());
    }
}
