//! Dataset operations with their paper-documented (inefficient) task
//! topologies:
//!
//! * **transpose** (§5.2): `N²` split tasks + `N` merge tasks. Each split
//!   task extracts and transposes one column chunk of one Subset; each
//!   merge hstacks the N chunks of a new Subset. The complexity "is caused
//!   by the need of maintaining data divided in Subsets".
//! * **shuffle** (§5.4): pseudo-shuffle with `N·min(N,S) + N` tasks — the
//!   pre-collections topology (bounded task arity forces per-pair splits).
//! * **max/min features** (§3.2.1): per-Subset partials + a reduction.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{CostHint, Future};
use crate::util::rng::Xoshiro256;

use super::{Dataset, Subset};

impl Dataset {
    /// Transpose the samples (labels are dropped — the Dataset API cannot
    /// express what transposed labels mean, §4.1). `N² + N` tasks.
    pub fn transpose(&self) -> Result<Dataset> {
        let n = self.subsets.len();
        let m = self.n_features;
        if m < n {
            bail!("transpose needs at least {n} features to split into {n} chunks");
        }
        // Column-chunk boundaries of the transposed Subsets.
        let base = m / n;
        let extra = m % n;
        let mut chunk_cols = Vec::with_capacity(n);
        let mut c0 = 0;
        for j in 0..n {
            let c = base + usize::from(j < extra);
            chunk_cols.push((c0, c));
            c0 += c;
        }

        // Phase 1: N² split tasks. part[j][i] = transposed chunk j of
        // subset i: (c_j x rows_i).
        let mut parts: Vec<Vec<Future>> = vec![Vec::with_capacity(n); n];
        for (_i, s) in self.subsets.iter().enumerate() {
            let rows = s.n_samples();
            for (j, &(c0, c)) in chunk_cols.iter().enumerate() {
                let meta = BlockMeta::dense(c, rows);
                let out = self.rt.submit(
                    "dataset.transpose.split",
                    &[s.samples],
                    vec![meta],
                    CostHint::default().with_bytes(2.0 * meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let d = ins[0].to_dense()?;
                        Ok(vec![Block::Dense(d.slice(0, c0, d.rows(), c)?.transpose())])
                    }),
                );
                parts[j].push(out[0]);
            }
        }

        // Phase 2: N merge tasks (hstack row-aligned chunks).
        let total_rows = self.n_samples();
        let mut subsets = Vec::with_capacity(n);
        for (j, &(_, c)) in chunk_cols.iter().enumerate() {
            let futs = parts[j].clone();
            let meta = BlockMeta::dense(c, total_rows);
            let out = self.rt.submit(
                "dataset.transpose.merge",
                &futs,
                vec![meta],
                CostHint::default().with_bytes(2.0 * meta.bytes() as f64),
                crate::tasking::ops::hstack_op(),
            );
            subsets.push(Subset {
                samples: out[0],
                labels: None,
            });
        }
        Ok(Dataset {
            rt: self.rt.clone(),
            subsets,
            n_features: total_rows,
            sparse: self.sparse,
        })
    }

    /// Pseudo-shuffle (paper §5.4): each Subset is split into
    /// `min(N, S)` random parts (one task per part — bounded arity, no
    /// collection outputs), and each new Subset merges the parts routed to
    /// it. Total tasks: `N·min(N,S) + N`.
    pub fn shuffle(&self, seed: u64) -> Result<Dataset> {
        let n = self.subsets.len();
        if n < 2 {
            bail!("shuffle needs at least 2 subsets");
        }
        let m = self.n_features;
        let mut rng = Xoshiro256::seed_from_u64(seed);

        // Master-side routing: subset i emits k_i = min(N, S_i) parts.
        // Destinations go round-robin over the global part sequence so every
        // new Subset receives at least one part (the paper's "in a way that
        // the final shuffled N Subsets are also of size S"); randomness
        // lives in the row-to-part assignment below.
        let mut incoming: Vec<Vec<(Future, usize)>> = vec![Vec::new(); n]; // dest -> (part, rows)
        let mut part_counter = 0usize;
        for (i, s) in self.subsets.iter().enumerate() {
            let rows = s.n_samples();
            let k = n.min(rows);
            let dests: Vec<usize> = (0..k).map(|g| (part_counter + g) % n).collect();
            part_counter += k;
            let _ = i;
            // Random local row assignment to the k parts.
            let mut local: Vec<usize> = (0..rows).collect();
            rng.shuffle(&mut local);
            let base = rows / k;
            let extra = rows % k;
            let mut off = 0;
            for (g, &d) in dests.iter().enumerate() {
                let take = base + usize::from(g < extra);
                let rows_g: Vec<usize> = local[off..off + take].to_vec();
                off += take;
                let meta = BlockMeta::dense(rows_g.len(), m);
                let out = self.rt.submit(
                    "dataset.shuffle.split",
                    &[s.samples],
                    vec![meta],
                    CostHint::default().with_bytes(2.0 * meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let d = ins[0].to_dense()?;
                        let mut part = DenseMatrix::zeros(rows_g.len(), d.cols());
                        for (t, &r) in rows_g.iter().enumerate() {
                            part.row_mut(t).copy_from_slice(d.row(r));
                        }
                        Ok(vec![Block::Dense(part)])
                    }),
                );
                incoming[d].push((out[0], take));
            }
        }

        // Merge phase: one task per new Subset.
        let mut subsets = Vec::with_capacity(n);
        for inc in incoming {
            let futs: Vec<Future> = inc.iter().map(|&(f, _)| f).collect();
            let rows: usize = inc.iter().map(|&(_, r)| r).sum();
            if futs.is_empty() {
                bail!("shuffle produced an empty subset (degenerate sizes)");
            }
            let meta = BlockMeta::dense(rows, m);
            let out = self.rt.submit(
                "dataset.shuffle.merge",
                &futs,
                vec![meta],
                CostHint::default().with_bytes(2.0 * meta.bytes() as f64),
                crate::tasking::ops::vstack_op(),
            );
            subsets.push(Subset {
                samples: out[0],
                labels: None,
            });
        }
        Ok(Dataset {
            rt: self.rt.clone(),
            subsets,
            n_features: m,
            sparse: self.sparse,
        })
    }

    /// Per-feature maximum (paper's `max_features`): one partial task per
    /// Subset + one reduction task.
    pub fn max_features(&self) -> Result<Future> {
        self.feature_fold("dataset.max_features", f32::NEG_INFINITY, |a, b| a.max(b))
    }

    /// Per-feature minimum (`min_features`).
    pub fn min_features(&self) -> Result<Future> {
        self.feature_fold("dataset.min_features", f32::INFINITY, |a, b| a.min(b))
    }

    fn feature_fold(
        &self,
        name: &'static str,
        init: f32,
        f: impl Fn(f32, f32) -> f32 + Send + Sync + Clone + 'static,
    ) -> Result<Future> {
        let m = self.n_features;
        let mut partials = Vec::with_capacity(self.subsets.len());
        for s in &self.subsets {
            let meta = BlockMeta::dense(1, m);
            let f = f.clone();
            let out = self.rt.submit(
                name,
                &[s.samples],
                vec![meta],
                CostHint::flops((s.n_samples() * m) as f64)
                    .with_bytes(s.samples.meta.bytes() as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let d = ins[0].to_dense()?;
                    Ok(vec![Block::Dense(d.fold_axis(0, init, &f))])
                }),
            );
            partials.push(out[0]);
        }
        let f2 = f;
        let out = self.rt.submit(
            "dataset.feature_reduce",
            &partials,
            vec![BlockMeta::dense(1, m)],
            CostHint::flops((self.subsets.len() * m) as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                let mut acc = DenseMatrix::full(1, m, init);
                for b in ins {
                    let d = b.to_dense()?;
                    for (a, &v) in acc.data_mut().iter_mut().zip(d.data()) {
                        *a = f2(*a, v);
                    }
                }
                Ok(vec![Block::Dense(acc)])
            }),
        );
        Ok(out[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasking::Runtime;

    fn setup(rows: usize, cols: usize, n: usize) -> (Runtime, DenseMatrix, Dataset) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(rows, cols, |i, j| (i * cols + j) as f32);
        let ds = Dataset::from_matrix(&rt, &m, None, n).unwrap();
        (rt, m, ds)
    }

    #[test]
    fn transpose_matches_reference() {
        let (_rt, m, ds) = setup(8, 10, 4);
        let t = ds.transpose().unwrap();
        assert_eq!(t.n_samples(), 10);
        assert_eq!(t.n_features(), 8);
        assert_eq!(t.collect_samples().unwrap(), m.transpose());
    }

    #[test]
    fn transpose_task_count_is_n_squared_plus_n() {
        let (rt, _m, ds) = setup(12, 12, 4);
        let before = rt.metrics();
        ds.transpose().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dataset.transpose.split"), 16); // N²
        assert_eq!(d.tasks_for("dataset.transpose.merge"), 4); // N
        assert_eq!(d.total_tasks(), 20);
    }

    #[test]
    fn shuffle_preserves_row_multiset_and_task_count() {
        let (rt, m, ds) = setup(20, 3, 4); // S=5 per subset, N=4, min=4
        let before = rt.metrics();
        let sh = ds.shuffle(11).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dataset.shuffle.split"), 16); // N*min(N,S)
        assert_eq!(d.tasks_for("dataset.shuffle.merge"), 4); // N
        let got = sh.collect_samples().unwrap();
        let mut a: Vec<Vec<u32>> = (0..got.rows())
            .map(|i| got.row(i).iter().map(|x| x.to_bits()).collect())
            .collect();
        let mut b: Vec<Vec<u32>> = (0..m.rows())
            .map(|i| m.row(i).iter().map(|x| x.to_bits()).collect())
            .collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn shuffle_small_subsets_uses_min_n_s() {
        // N=5 subsets of S=2 rows: min(N,S)=2 parts each -> 10 split tasks.
        let (rt, _m, ds) = setup(10, 2, 5);
        let before = rt.metrics();
        ds.shuffle(3).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dataset.shuffle.split"), 10);
        assert_eq!(d.tasks_for("dataset.shuffle.merge"), 5);
    }

    #[test]
    fn min_max_features() {
        let (rt, m, ds) = setup(9, 4, 3);
        let mx = ds.max_features().unwrap();
        let mx = rt.wait(mx).unwrap().to_dense().unwrap();
        assert_eq!(mx.data(), m.fold_axis(0, f32::NEG_INFINITY, f32::max).data());
        let mn = ds.min_features().unwrap();
        let mn = rt.wait(mn).unwrap().to_dense().unwrap();
        assert_eq!(mn.data(), m.fold_axis(0, f32::INFINITY, f32::min).data());
    }

    #[test]
    fn transpose_rejects_too_few_features() {
        let (_rt, _m, ds) = setup(8, 3, 4);
        assert!(ds.transpose().is_err());
    }
}
