//! # rustdslib — ds-array reproduction (Rust + JAX + Pallas)
//!
//! A production-shaped reproduction of *"ds-array: A Distributed Data
//! Structure for Large Scale Machine Learning"* (CS.DC 2021): a blocked
//! 2-D distributed array with a NumPy-like API on top of a from-scratch
//! PyCOMPSs-style task runtime, the legacy `Dataset`/`Subset` baseline it
//! is compared against, estimator implementations (K-means, ALS, …), a
//! PJRT runtime that executes AOT-compiled JAX/Pallas block kernels, and a
//! discrete-event cluster simulator that replays the real task graphs at
//! MareNostrum scale to regenerate every figure of the paper's evaluation.
//!
//! Indexing goes through a zero-copy **view layer**: slices and fancy
//! index selections share block futures with the parent and materialize
//! lazily (see [`dsarray::DsArray::force`] and `docs/API.md` for the full
//! NumPy ↔ ds-array mapping).
//!
//! Data sets larger than memory go through the **out-of-core layer**:
//! parallel partitioned loaders ([`dsarray::io`], one task per block-row —
//! the master never materializes the matrix) and a runtime memory budget
//! ([`tasking::Runtime::local_with_budget`]) that spills live blocks to a
//! [`storage::BlockStore`] and faults them back transparently. The
//! [`io_guide`] module embeds `docs/IO.md` with runnable examples.
//!
//! Multi-process execution goes through the **cluster backend**
//! ([`tasking::Runtime::cluster`]): block payloads live on `dsarray
//! worker` processes over TCP, tasks are placed on the worker holding the
//! most input bytes, and missing blocks move worker-to-worker. The
//! [`cluster_guide`] module embeds `docs/CLUSTER.md`.
//!
//! Worker death is absorbed by **lineage-based fault recovery**: the
//! single-assignment task graph doubles as a lineage log, so the
//! coordinator replays a dead worker's lost sub-graph on survivors (roots
//! re-load from an on-disk journal) and results stay bit-identical to a
//! fault-free run; opt-in k-way replication
//! ([`tasking::cluster::ClusterOptions::with_replication`]) trades put
//! traffic for near-zero recovery time, and a deterministic seeded
//! fault-injection harness ([`tasking::FaultPlan`]) makes every chaos
//! scenario reproducible. The [`fault_tolerance_guide`] module embeds
//! `docs/FAULT_TOLERANCE.md`.
//!
//! Per-block compute goes through the **kernel layer** ([`kernels`]):
//! packed SIMD micro-kernels behind a vtable selected once per process by
//! runtime CPU feature detection (portable scalar fallback, bit-identical
//! results), plus size-gated intra-block sub-task splitting so one fat
//! block can occupy every worker. The [`kernels_guide`] module embeds
//! `docs/KERNELS.md`.
//!
//! Fitted models answer live traffic through the **serving tier**
//! ([`serving`], `dsarray serve`): estimators persist as DSBK-format
//! artifacts, parameters live as pinned replicated runtime blocks, and
//! concurrent `Predict` requests coalesce through an adaptive
//! micro-batcher with admission control — answers bit-identical to batch
//! `predict`. The [`serving_guide`] module embeds `docs/SERVING.md`.
//!
//! Whole-plan optimization goes through the **plan layer** ([`plan`]):
//! common-subexpression elimination over pending subgraphs, elementwise
//! epilogues grafted into gemm tiles while they are cache-hot, and
//! dead-block pre-release — behind the one fluent construction front door,
//! [`tasking::Runtime::builder`], which carries the optimizer
//! [`plan::Level`]. The [`planner_guide`] module embeds `docs/PLANNER.md`.
//!
//! ```
//! use rustdslib::{dsarray::creation, tasking::Runtime};
//!
//! let rt = Runtime::local(2);
//! let w = creation::random(&rt, (60, 40), (10, 10), 42).unwrap();
//! // Chain like NumPy; everything before collect() runs as async tasks.
//! let expr = w.transpose().unwrap().norm_axis(1).unwrap();
//! let vals = expr.collect().unwrap();
//! assert_eq!(vals.rows(), 40);
//! // Block-aligned slicing is pure metadata — zero tasks.
//! let top = w.slice_rows(0, 30).unwrap();
//! assert!(!top.is_view());
//! ```
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.

pub mod bench;
pub mod config;
pub mod dataset;
pub mod dsarray;
pub mod estimators;
pub mod kernels;
pub mod plan;
pub mod runtime;
pub mod serving;
pub mod storage;
pub mod tasking;
pub mod util;

/// Guide: partitioned file I/O and the out-of-core block store
/// (`docs/IO.md`, embedded so its examples run under `cargo test --doc`
/// and its intra-doc links are checked by `cargo doc -D warnings`).
#[doc = include_str!("../../docs/IO.md")]
pub mod io_guide {}

/// Guide: the multi-process cluster backend — wire protocol, locality
/// placement, failure semantics (`docs/CLUSTER.md`, embedded so its
/// examples run under `cargo test --doc`).
#[doc = include_str!("../../docs/CLUSTER.md")]
pub mod cluster_guide {}

/// Guide: lineage-based fault recovery in the cluster backend — the
/// recovery walk, the root journal, k-way replication, what is and isn't
/// survivable, and the deterministic fault-injection harness
/// (`docs/FAULT_TOLERANCE.md`, embedded so its worker-killing example
/// runs under `cargo test --doc`).
#[doc = include_str!("../../docs/FAULT_TOLERANCE.md")]
pub mod fault_tolerance_guide {}

/// Guide: the SIMD kernel layer and intra-block parallelism — vtable
/// dispatch, bit-identicality contract, sub-task splitting
/// (`docs/KERNELS.md`, embedded so its examples run under
/// `cargo test --doc`).
#[doc = include_str!("../../docs/KERNELS.md")]
pub mod kernels_guide {}

/// Guide: the online serving tier — model artifacts, the micro-batching
/// window, admission control, fault behavior under replication
/// (`docs/SERVING.md`, embedded so its end-to-end serve/predict example
/// runs under `cargo test --doc`).
#[doc = include_str!("../../docs/SERVING.md")]
pub mod serving_guide {}

/// Guide: the plan layer — CSE epoch semantics, gemm epilogue grafting,
/// dead-block pre-release, `RuntimeBuilder`, and the `explain()` output
/// format (`docs/PLANNER.md`, embedded so its examples run under
/// `cargo test --doc`).
#[doc = include_str!("../../docs/PLANNER.md")]
pub mod planner_guide {}

pub use storage::{Block, BlockMeta, CsrMatrix, DenseMatrix};
pub use tasking::{Future, Runtime, SimConfig, SimReport};
