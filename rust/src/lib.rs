//! # rustdslib — ds-array reproduction (Rust + JAX + Pallas)
//!
//! A production-shaped reproduction of *"ds-array: A Distributed Data
//! Structure for Large Scale Machine Learning"* (CS.DC 2021): a blocked
//! 2-D distributed array with a NumPy-like API on top of a from-scratch
//! PyCOMPSs-style task runtime, the legacy `Dataset`/`Subset` baseline it
//! is compared against, estimator implementations (K-means, ALS, …), a
//! PJRT runtime that executes AOT-compiled JAX/Pallas block kernels, and a
//! discrete-event cluster simulator that replays the real task graphs at
//! MareNostrum scale to regenerate every figure of the paper's evaluation.
//!
//! Indexing goes through a zero-copy **view layer**: slices and fancy
//! index selections share block futures with the parent and materialize
//! lazily (see [`dsarray::DsArray::force`] and `docs/API.md` for the full
//! NumPy ↔ ds-array mapping).
//!
//! ```
//! use rustdslib::{dsarray::creation, tasking::Runtime};
//!
//! let rt = Runtime::local(2);
//! let w = creation::random(&rt, (60, 40), (10, 10), 42).unwrap();
//! // Chain like NumPy; everything before collect() runs as async tasks.
//! let expr = w.transpose().unwrap().norm_axis(1).unwrap();
//! let vals = expr.collect().unwrap();
//! assert_eq!(vals.rows(), 40);
//! // Block-aligned slicing is pure metadata — zero tasks.
//! let top = w.slice_rows(0, 30).unwrap();
//! assert!(!top.is_view());
//! ```
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.

pub mod bench;
pub mod config;
pub mod dataset;
pub mod dsarray;
pub mod estimators;
pub mod runtime;
pub mod storage;
pub mod tasking;
pub mod util;

pub use storage::{Block, BlockMeta, CsrMatrix, DenseMatrix};
pub use tasking::{Future, Runtime, SimConfig, SimReport};
