//! # rustdslib — ds-array reproduction (Rust + JAX + Pallas)
//!
//! A production-shaped reproduction of *"ds-array: A Distributed Data
//! Structure for Large Scale Machine Learning"* (CS.DC 2021): a blocked
//! 2-D distributed array with a NumPy-like API on top of a from-scratch
//! PyCOMPSs-style task runtime, the legacy `Dataset`/`Subset` baseline it
//! is compared against, estimator implementations (K-means, ALS, …), a
//! PJRT runtime that executes AOT-compiled JAX/Pallas block kernels, and a
//! discrete-event cluster simulator that replays the real task graphs at
//! MareNostrum scale to regenerate every figure of the paper's evaluation.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.

pub mod bench;
pub mod config;
pub mod dataset;
pub mod dsarray;
pub mod estimators;
pub mod runtime;
pub mod storage;
pub mod tasking;
pub mod util;

pub use storage::{Block, BlockMeta, CsrMatrix, DenseMatrix};
pub use tasking::{Future, Runtime, SimConfig, SimReport};
