//! Axis reductions (paper §4.3, Fig 5): because ds-arrays are blocked along
//! *both* axes, a column-wise reduction is one task per block-column (each
//! reading that column's blocks as a collection) — the operation that
//! Datasets could only do by loading everything into memory.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future};

use super::DsArray;

/// Which elementwise accumulation a reduction task applies.
#[derive(Clone, Copy, Debug)]
enum Kind {
    Sum,
    Min,
    Max,
    /// Sum of squares (for norms, fused — no intermediate `A**2` array).
    SumSq,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Sum => "dsarray.reduce.sum",
            Kind::Min => "dsarray.reduce.min",
            Kind::Max => "dsarray.reduce.max",
            Kind::SumSq => "dsarray.reduce.sumsq",
        }
    }

    fn init(self) -> f32 {
        match self {
            Kind::Sum | Kind::SumSq => 0.0,
            Kind::Min => f32::INFINITY,
            Kind::Max => f32::NEG_INFINITY,
        }
    }

    fn fold(self, acc: f32, x: f32) -> f32 {
        match self {
            Kind::Sum => acc + x,
            Kind::SumSq => acc + x * x,
            Kind::Min => acc.min(x),
            Kind::Max => acc.max(x),
        }
    }

    /// Merge two partial results (partials of SumSq are already squared).
    fn combine(self, a: f32, b: f32) -> f32 {
        match self {
            Kind::Sum | Kind::SumSq => a + b,
            Kind::Min => a.min(b),
            Kind::Max => a.max(b),
        }
    }
}

impl DsArray {
    /// Reduce along `axis` (0 = down columns → 1×cols; 1 = across rows →
    /// rows×1). One task per block-column (axis 0) / block-row (axis 1).
    fn reduce_axis(&self, kind: Kind, axis: usize) -> Result<DsArray> {
        if axis > 1 {
            bail!("axis must be 0 or 1, got {axis}");
        }
        if self.is_lazy() {
            return self.force()?.reduce_axis(kind, axis);
        }
        // One task per block-line, submitted as one batch.
        let mut batch = Vec::new();
        if axis == 0 {
            for j in 0..self.grid.1 {
                let futs = self.block_col(j);
                let c = self.block_cols_at(j);
                let meta = BlockMeta::dense(1, c);
                let flops: f64 = futs.iter().map(|f| (f.meta.rows * f.meta.cols) as f64).sum();
                let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
                batch.push(BatchTask::new(
                    kind.name(),
                    futs,
                    vec![meta],
                    CostHint::flops(flops).with_bytes(bytes),
                    reduce_fn(kind, axis),
                ));
            }
            let blocks: Vec<Future> =
                self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
            DsArray::from_parts(
                self.rt.clone(),
                (1, self.shape.1),
                (1, self.block_shape.1),
                blocks,
                false,
            )
        } else {
            for i in 0..self.grid.0 {
                let futs = self.block_row(i);
                let r = self.block_rows_at(i);
                let meta = BlockMeta::dense(r, 1);
                let flops: f64 = futs.iter().map(|f| (f.meta.rows * f.meta.cols) as f64).sum();
                let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
                batch.push(BatchTask::new(
                    kind.name(),
                    futs,
                    vec![meta],
                    CostHint::flops(flops).with_bytes(bytes),
                    reduce_fn(kind, axis),
                ));
            }
            let blocks: Vec<Future> =
                self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
            DsArray::from_parts(
                self.rt.clone(),
                (self.shape.0, 1),
                (self.block_shape.0, 1),
                blocks,
                false,
            )
        }
    }

    /// Full reduction to a single future scalar (1×1 block): per-axis pass
    /// then a final merge task over the partials.
    fn reduce_all(&self, kind: Kind) -> Result<Future> {
        // reduce_axis forces lazy views, so no explicit force is needed.
        let partial = self.reduce_axis(kind, 0)?; // 1 x cols in gc blocks
        let futs: Vec<Future> = partial.blocks.clone();
        let meta = BlockMeta::dense(1, 1);
        let out = self.rt.submit(
            "dsarray.reduce.final",
            &futs,
            vec![meta],
            CostHint::flops(self.shape.1 as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                let mut acc = kind.init();
                for b in ins {
                    for &v in b.to_dense()?.data() {
                        acc = kind.combine(acc, v);
                    }
                }
                Ok(vec![Block::Dense(DenseMatrix::full(1, 1, acc))])
            }),
        );
        Ok(out[0])
    }

    pub fn sum_axis(&self, axis: usize) -> Result<DsArray> {
        self.reduce_axis(Kind::Sum, axis)
    }

    pub fn min_axis(&self, axis: usize) -> Result<DsArray> {
        self.reduce_axis(Kind::Min, axis)
    }

    pub fn max_axis(&self, axis: usize) -> Result<DsArray> {
        self.reduce_axis(Kind::Max, axis)
    }

    /// Mean along an axis (sum then scale).
    pub fn mean_axis(&self, axis: usize) -> Result<DsArray> {
        let n = if axis == 0 { self.shape.0 } else { self.shape.1 };
        self.sum_axis(axis)?.mul_scalar(1.0 / n as f32)
    }

    /// L2 norm along an axis — fused sum-of-squares then sqrt, the paper's
    /// `w.transpose().norm(axis=1)` building block.
    pub fn norm_axis(&self, axis: usize) -> Result<DsArray> {
        self.reduce_axis(Kind::SumSq, axis)?.sqrt()
    }

    /// Total sum as a synchronized scalar (local mode).
    pub fn sum(&self) -> Result<f32> {
        let f = self.reduce_all(Kind::Sum)?;
        Ok(self.rt.wait(f)?.to_dense()?.get(0, 0))
    }

    pub fn min(&self) -> Result<f32> {
        let f = self.reduce_all(Kind::Min)?;
        Ok(self.rt.wait(f)?.to_dense()?.get(0, 0))
    }

    pub fn max(&self) -> Result<f32> {
        let f = self.reduce_all(Kind::Max)?;
        Ok(self.rt.wait(f)?.to_dense()?.get(0, 0))
    }

    pub fn mean(&self) -> Result<f32> {
        Ok(self.sum()? / (self.shape.0 * self.shape.1) as f32)
    }

    /// Frobenius norm as a synchronized scalar.
    pub fn norm(&self) -> Result<f32> {
        let f = self.reduce_all(Kind::SumSq)?;
        Ok(self.rt.wait(f)?.to_dense()?.get(0, 0).sqrt())
    }
}

fn reduce_fn(kind: Kind, axis: usize) -> crate::tasking::TaskFn {
    Arc::new(move |ins: &[Arc<Block>]| {
        let first = ins[0].to_dense()?;
        let mut acc = match axis {
            0 => DenseMatrix::full(1, first.cols(), kind.init()),
            _ => DenseMatrix::full(first.rows(), 1, kind.init()),
        };
        for b in ins {
            let d = b.to_dense()?;
            if axis == 0 {
                for i in 0..d.rows() {
                    for (a, &v) in acc.data_mut().iter_mut().zip(d.row(i)) {
                        *a = kind.fold(*a, v);
                    }
                }
            } else {
                for i in 0..d.rows() {
                    let folded = d.row(i).iter().fold(acc.get(i, 0), |a, &v| kind.fold(a, v));
                    acc.set(i, 0, folded);
                }
            }
        }
        Ok(vec![Block::Dense(acc)])
    })
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;
    use crate::util::prop::all_close;

    fn setup() -> (Runtime, DenseMatrix, super::DsArray) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(6, 8, |i, j| ((i * 8 + j) % 11) as f32 - 5.0);
        let a = creation::from_matrix(&rt, &m, (2, 3)).unwrap();
        (rt, m, a)
    }

    #[test]
    fn axis_sums_match_reference() {
        let (_rt, m, a) = setup();
        let s0 = a.sum_axis(0).unwrap().collect().unwrap();
        assert!(all_close(s0.data(), m.sum_axis(0).data(), 1e-5));
        let s1 = a.sum_axis(1).unwrap().collect().unwrap();
        assert!(all_close(s1.data(), m.sum_axis(1).data(), 1e-5));
        assert!(a.sum_axis(2).is_err());
    }

    #[test]
    fn min_max_mean() {
        let (_rt, m, a) = setup();
        let mn = a.min_axis(0).unwrap().collect().unwrap();
        assert_eq!(mn.data(), m.fold_axis(0, f32::INFINITY, f32::min).data());
        let mx = a.max_axis(1).unwrap().collect().unwrap();
        assert_eq!(mx.data(), m.fold_axis(1, f32::NEG_INFINITY, f32::max).data());
        let mean = a.mean_axis(0).unwrap().collect().unwrap();
        let want = m.sum_axis(0).map(|x| x / 6.0);
        assert!(all_close(mean.data(), want.data(), 1e-5));
    }

    #[test]
    fn scalar_reductions() {
        let (_rt, m, a) = setup();
        assert!((a.sum().unwrap() - m.sum()).abs() < 1e-4);
        assert_eq!(a.min().unwrap(), -5.0);
        assert_eq!(a.max().unwrap(), 5.0);
        assert!((a.norm().unwrap() - m.norm()).abs() < 1e-4);
        assert!((a.mean().unwrap() - m.sum() / 48.0).abs() < 1e-5);
    }

    #[test]
    fn norm_axis_fused_matches_two_step() {
        let (_rt, m, a) = setup();
        let fused = a.norm_axis(1).unwrap().collect().unwrap();
        let want: Vec<f32> = (0..m.rows())
            .map(|i| m.row(i).iter().map(|&x| x * x).sum::<f32>().sqrt())
            .collect();
        assert!(all_close(fused.data(), &want, 1e-5));
    }

    #[test]
    fn task_counts_one_per_block_line() {
        // Fig 5: column-of-blocks per task.
        let (rt, _m, a) = setup();
        let before = rt.metrics();
        a.sum_axis(0).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.total_tasks(), a.grid().1 as u64);
        let before = rt.metrics();
        a.sum_axis(1).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.total_tasks(), a.grid().0 as u64);
    }

    #[test]
    fn paper_expression_sqrt_norm_sq() {
        // sqrt(||w^T||_2^2) per the paper's §4.2.3 chaining example.
        let (_rt, m, a) = setup();
        let expr = a
            .transpose()
            .unwrap()
            .norm_axis(1)
            .unwrap()
            .pow(2.0)
            .unwrap()
            .sqrt()
            .unwrap();
        let got = expr.collect().unwrap();
        let want: Vec<f32> = (0..m.cols())
            .map(|j| {
                (0..m.rows())
                    .map(|i| m.get(i, j) * m.get(i, j))
                    .sum::<f32>()
                    .sqrt()
            })
            .collect();
        assert!(all_close(got.data(), &want, 1e-4));
    }
}
