//! Array creation routines (paper §4.2.2).
//!
//! `random_array`-style routines spawn **one task per block**; file loaders
//! spawn **one task per row of blocks** (files are parsed line by line).
//! Block size is caller-chosen — the flexibility Datasets lack.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, CsrMatrix, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future, Runtime};
use crate::util::rng::Xoshiro256;

use super::DsArray;

fn validate(shape: (usize, usize), block_shape: (usize, usize)) -> Result<()> {
    if shape.0 == 0 || shape.1 == 0 {
        bail!("empty shape {shape:?}");
    }
    if block_shape.0 == 0 || block_shape.1 == 0 {
        bail!("empty block shape {block_shape:?}");
    }
    Ok(())
}

/// Shared scaffold: one task per block, each generating its block. The
/// whole grid is submitted as one batch (one scheduler-lock round-trip).
fn per_block(
    rt: &Runtime,
    shape: (usize, usize),
    block_shape: (usize, usize),
    name: &'static str,
    sparse_nnz: Option<f64>, // density for sparse, None for dense
    make: impl Fn(usize, usize, usize, usize) -> crate::tasking::TaskFn,
) -> Result<DsArray> {
    validate(shape, block_shape)?;
    let grid = (
        DsArray::grid_dim(shape.0, block_shape.0),
        DsArray::grid_dim(shape.1, block_shape.1),
    );
    let mut batch = Vec::with_capacity(grid.0 * grid.1);
    for i in 0..grid.0 {
        let r = (shape.0 - i * block_shape.0).min(block_shape.0);
        for j in 0..grid.1 {
            let c = (shape.1 - j * block_shape.1).min(block_shape.1);
            let meta = match sparse_nnz {
                Some(d) => BlockMeta::sparse(r, c, ((r * c) as f64 * d).round() as usize),
                None => BlockMeta::dense(r, c),
            };
            let hint = CostHint::default().with_bytes(meta.bytes() as f64);
            batch.push(BatchTask::new(name, Vec::new(), vec![meta], hint, make(i, j, r, c)));
        }
    }
    let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
    DsArray::from_parts(
        rt.clone(),
        shape,
        block_shape,
        blocks,
        sparse_nnz.is_some(),
    )
}

/// Uniform [0,1) random ds-array (dense). One task per block.
pub fn random(
    rt: &Runtime,
    shape: (usize, usize),
    block_shape: (usize, usize),
    seed: u64,
) -> Result<DsArray> {
    per_block(rt, shape, block_shape, "dsarray.create.random", None, |i, j, r, c| {
        let block_seed = seed ^ ((i as u64) << 32) ^ j as u64;
        Arc::new(move |_| {
            let mut rng = Xoshiro256::seed_from_u64(block_seed);
            let data: Vec<f32> = (0..r * c).map(|_| rng.next_f32()).collect();
            Ok(vec![Block::Dense(DenseMatrix::from_vec(r, c, data)?)])
        })
    })
}

/// Standard-normal random ds-array (dense). One task per block.
pub fn random_normal(
    rt: &Runtime,
    shape: (usize, usize),
    block_shape: (usize, usize),
    seed: u64,
) -> Result<DsArray> {
    per_block(rt, shape, block_shape, "dsarray.create.randn", None, |i, j, r, c| {
        let block_seed = seed ^ ((i as u64) << 32) ^ j as u64;
        Arc::new(move |_| {
            let mut rng = Xoshiro256::seed_from_u64(block_seed);
            let data: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
            Ok(vec![Block::Dense(DenseMatrix::from_vec(r, c, data)?)])
        })
    })
}

/// Random sparse ds-array with the given density (CSR blocks).
pub fn random_sparse(
    rt: &Runtime,
    shape: (usize, usize),
    block_shape: (usize, usize),
    density: f64,
    seed: u64,
) -> Result<DsArray> {
    if !(0.0..=1.0).contains(&density) {
        bail!("density {density} outside [0,1]");
    }
    per_block(
        rt,
        shape,
        block_shape,
        "dsarray.create.sparse",
        Some(density),
        |i, j, r, c| {
            let block_seed = seed ^ ((i as u64) << 32) ^ j as u64;
            Arc::new(move |_| {
                let mut rng = Xoshiro256::seed_from_u64(block_seed);
                let nnz = ((r * c) as f64 * density).round() as usize;
                let trips: Vec<(usize, usize, f32)> = (0..nnz)
                    .map(|_| {
                        (
                            rng.next_below(r as u64) as usize,
                            rng.next_below(c as u64) as usize,
                            rng.next_f32(),
                        )
                    })
                    .collect();
                Ok(vec![Block::Csr(CsrMatrix::from_triplets(r, c, &trips)?)])
            })
        },
    )
}

pub fn full(
    rt: &Runtime,
    shape: (usize, usize),
    block_shape: (usize, usize),
    value: f32,
) -> Result<DsArray> {
    per_block(rt, shape, block_shape, "dsarray.create.full", None, |_, _, r, c| {
        Arc::new(move |_| Ok(vec![Block::Dense(DenseMatrix::full(r, c, value))]))
    })
}

pub fn zeros(rt: &Runtime, shape: (usize, usize), block_shape: (usize, usize)) -> Result<DsArray> {
    full(rt, shape, block_shape, 0.0)
}

pub fn ones(rt: &Runtime, shape: (usize, usize), block_shape: (usize, usize)) -> Result<DsArray> {
    full(rt, shape, block_shape, 1.0)
}

/// Identity matrix of size n (dense blocks).
pub fn identity(rt: &Runtime, n: usize, block_shape: (usize, usize)) -> Result<DsArray> {
    per_block(rt, (n, n), block_shape, "dsarray.create.identity", None, |i, j, r, c| {
        let (r0, c0) = (i * block_shape.0, j * block_shape.1);
        Arc::new(move |_| {
            let m = DenseMatrix::from_fn(r, c, |bi, bj| {
                if r0 + bi == c0 + bj {
                    1.0
                } else {
                    0.0
                }
            });
            Ok(vec![Block::Dense(m)])
        })
    })
}

/// Metadata-only ds-array for simulation: blocks are registered as
/// pre-existing phantom data (no creation tasks), mirroring the paper's
/// benchmarks, which measure operations on already-loaded data. `density`
/// of `Some(d)` makes CSR-metadata blocks.
pub fn phantom(
    rt: &Runtime,
    shape: (usize, usize),
    block_shape: (usize, usize),
    density: Option<f64>,
) -> Result<DsArray> {
    validate(shape, block_shape)?;
    let grid = (
        DsArray::grid_dim(shape.0, block_shape.0),
        DsArray::grid_dim(shape.1, block_shape.1),
    );
    let mut blocks = Vec::with_capacity(grid.0 * grid.1);
    for i in 0..grid.0 {
        let r = (shape.0 - i * block_shape.0).min(block_shape.0);
        for j in 0..grid.1 {
            let c = (shape.1 - j * block_shape.1).min(block_shape.1);
            let meta = match density {
                Some(d) => BlockMeta::sparse(r, c, ((r * c) as f64 * d).round() as usize),
                None => BlockMeta::dense(r, c),
            };
            blocks.push(rt.put_block(Block::Phantom(meta)));
        }
    }
    DsArray::from_parts(rt.clone(), shape, block_shape, blocks, density.is_some())
}

/// Distribute an in-memory matrix (local mode; the test/example entry).
pub fn from_matrix(rt: &Runtime, m: &DenseMatrix, block_shape: (usize, usize)) -> Result<DsArray> {
    let shape = (m.rows(), m.cols());
    validate(shape, block_shape)?;
    let grid = (
        DsArray::grid_dim(shape.0, block_shape.0),
        DsArray::grid_dim(shape.1, block_shape.1),
    );
    let mut blocks = Vec::with_capacity(grid.0 * grid.1);
    for i in 0..grid.0 {
        let r0 = i * block_shape.0;
        let r = (shape.0 - r0).min(block_shape.0);
        for j in 0..grid.1 {
            let c0 = j * block_shape.1;
            let c = (shape.1 - c0).min(block_shape.1);
            blocks.push(rt.put_block(Block::Dense(m.slice(r0, c0, r, c)?)));
        }
    }
    DsArray::from_parts(rt.clone(), shape, block_shape, blocks, false)
}

/// Distribute an in-memory CSR matrix as a sparse ds-array.
pub fn from_csr(rt: &Runtime, m: &CsrMatrix, block_shape: (usize, usize)) -> Result<DsArray> {
    let shape = (m.rows(), m.cols());
    validate(shape, block_shape)?;
    let grid = (
        DsArray::grid_dim(shape.0, block_shape.0),
        DsArray::grid_dim(shape.1, block_shape.1),
    );
    let mut blocks = Vec::with_capacity(grid.0 * grid.1);
    for i in 0..grid.0 {
        let r0 = i * block_shape.0;
        let r = (shape.0 - r0).min(block_shape.0);
        for j in 0..grid.1 {
            let c0 = j * block_shape.1;
            let c = (shape.1 - c0).min(block_shape.1);
            blocks.push(rt.put_block(Block::Csr(m.slice(r0, c0, r, c)?)));
        }
    }
    DsArray::from_parts(rt.clone(), shape, block_shape, blocks, true)
}

/// Load a CSV file into a ds-array with a declared shape: one parse task
/// per **row of blocks** (paper §4.2.2). This is a shape-checking wrapper
/// over the parallel partitioned loader [`crate::dsarray::io::load_csv`] —
/// each task parses only its own byte range, so the master never
/// materializes the matrix. Prefer the `io` entry point when the shape
/// should be inferred from the file.
pub fn load_csv(
    rt: &Runtime,
    path: &Path,
    shape: (usize, usize),
    block_shape: (usize, usize),
    delimiter: char,
) -> Result<DsArray> {
    validate(shape, block_shape)?;
    let arr = crate::dsarray::io::load_csv(rt, path, block_shape, delimiter)?;
    if arr.shape() != shape {
        bail!(
            "{}: file holds a {}x{} matrix, caller declared {}x{}",
            path.display(),
            arr.rows(),
            arr.cols(),
            shape.0,
            shape.1
        );
    }
    Ok(arr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasking::SimConfig;

    #[test]
    fn random_is_deterministic_and_uniform() {
        let rt = Runtime::local(2);
        let a = random(&rt, (8, 8), (4, 4), 7).unwrap();
        let b = random(&rt, (8, 8), (4, 4), 7).unwrap();
        let c = random(&rt, (8, 8), (4, 4), 8).unwrap();
        let (ma, mb, mc) = (a.collect().unwrap(), b.collect().unwrap(), c.collect().unwrap());
        assert_eq!(ma, mb);
        assert_ne!(ma, mc);
        assert!(ma.data().iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn creation_task_counts_match_paper() {
        // random: one task per block; load: one task per row of blocks.
        let rt = Runtime::local(1);
        random(&rt, (8, 8), (2, 2), 0).unwrap();
        assert_eq!(rt.metrics().tasks_for("dsarray.create.random"), 16);
    }

    #[test]
    fn identity_collects_to_eye() {
        let rt = Runtime::local(2);
        let a = identity(&rt, 5, (2, 2)).unwrap();
        let m = a.collect().unwrap();
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(m.get(i, j), if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn sparse_round_trip() {
        let rt = Runtime::local(2);
        let csr = CsrMatrix::from_triplets(
            6,
            5,
            &[(0, 0, 1.0), (2, 3, 2.0), (5, 4, 3.0), (3, 1, -1.0)],
        )
        .unwrap();
        let a = from_csr(&rt, &csr, (2, 2)).unwrap();
        assert!(a.is_sparse());
        assert_eq!(a.collect_csr().unwrap().to_dense(), csr.to_dense());
        assert_eq!(a.collect().unwrap(), csr.to_dense());
    }

    #[test]
    fn random_sparse_density() {
        let rt = Runtime::local(2);
        let a = random_sparse(&rt, (40, 40), (10, 10), 0.1, 3).unwrap();
        let csr = a.collect_csr().unwrap();
        // Duplicate positions collapse, so nnz <= target.
        assert!(csr.nnz() <= 160 && csr.nnz() > 100, "nnz {}", csr.nnz());
    }

    #[test]
    fn load_csv_round_trip() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(7, 5, |i, j| (i * 5 + j) as f32);
        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_dsarr_{}.csv", std::process::id()));
        crate::storage::io::write_csv(&p, &m, ',').unwrap();
        let a = load_csv(&rt, &p, (7, 5), (3, 2), ',').unwrap();
        assert_eq!(a.collect().unwrap(), m);
        assert_eq!(rt.metrics().tasks_for("dsarray.io.load_csv"), 3);
        // A wrong declared shape is a clear error, not silent truncation.
        assert!(load_csv(&rt, &p, (8, 5), (3, 2), ',').is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn sim_mode_builds_same_graph_shape() {
        let sim = Runtime::sim(SimConfig::with_workers(4));
        let a = random(&sim, (100, 100), (10, 10), 0).unwrap();
        assert_eq!(a.n_blocks(), 100);
        assert_eq!(sim.metrics().tasks_for("dsarray.create.random"), 100);
        let report = sim.run_sim().unwrap();
        assert_eq!(report.tasks_executed, 100);
    }

    #[test]
    fn rejects_empty_shapes() {
        let rt = Runtime::local(1);
        assert!(zeros(&rt, (0, 5), (1, 1)).is_err());
        assert!(zeros(&rt, (5, 5), (0, 1)).is_err());
    }
}
