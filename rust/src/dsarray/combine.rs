//! Combining ds-arrays: vertical/horizontal concatenation and saving to
//! disk — the remaining data-management surface of the NumPy-like API.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, DenseMatrix};
use crate::tasking::Future;

use super::DsArray;

/// Stack ds-arrays vertically (same cols + block shape). Block grids are
/// concatenated directly when every non-final array's rows divide the
/// block height; otherwise the data is re-blocked through `rechunk`.
pub fn vstack(parts: &[&DsArray]) -> Result<DsArray> {
    if parts.is_empty() {
        bail!("vstack of zero arrays");
    }
    // Materialize lazy views and deferred expressions: stacking addresses
    // canonical block grids.
    if parts.iter().any(|p| p.is_lazy()) {
        let forced: Vec<DsArray> = parts.iter().map(|p| p.force()).collect::<Result<_>>()?;
        let refs: Vec<&DsArray> = forced.iter().collect();
        return vstack(&refs);
    }
    let first = parts[0];
    let bs = first.block_shape;
    for p in parts {
        if p.cols() != first.cols() {
            bail!("vstack col mismatch: {} vs {}", p.cols(), first.cols());
        }
        if p.block_shape != bs {
            bail!("vstack block-shape mismatch (rechunk first)");
        }
    }
    let rows: usize = parts.iter().map(|p| p.rows()).sum();
    // Fast path: block grids concatenate exactly.
    let aligned = parts[..parts.len() - 1]
        .iter()
        .all(|p| p.rows() % bs.0 == 0);
    if aligned {
        let mut blocks: Vec<Future> = Vec::new();
        for p in parts {
            blocks.extend(p.blocks.iter().copied());
        }
        return DsArray::from_parts(
            first.rt.clone(),
            (rows, first.cols()),
            bs,
            blocks,
            parts.iter().all(|p| p.sparse),
        );
    }
    // Misaligned: go through a gather-based re-block of the concatenation.
    // (One task per output block; same pattern as rechunk.)
    let stacked = concat_rows_unaligned(parts, rows)?;
    Ok(stacked)
}

fn concat_rows_unaligned(parts: &[&DsArray], rows: usize) -> Result<DsArray> {
    let first = parts[0];
    let bs = first.block_shape;
    let cols = first.cols();
    let rt = first.rt.clone();
    // Row offset of each part.
    let mut offsets = Vec::with_capacity(parts.len());
    let mut acc = 0;
    for p in parts {
        offsets.push(acc);
        acc += p.rows();
    }
    let out_grid0 = DsArray::grid_dim(rows, bs.0);
    let mut blocks = Vec::new();
    for oi in 0..out_grid0 {
        let or0 = oi * bs.0;
        let orn = (rows - or0).min(bs.0);
        for oj in 0..DsArray::grid_dim(cols, bs.1) {
            let oc0 = oj * bs.1;
            let ocn = (cols - oc0).min(bs.1);
            // Collect contributing (part, block, placement) tuples.
            let mut futs = Vec::new();
            let mut places: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
            for (pi, p) in parts.iter().enumerate() {
                let p0 = offsets[pi];
                let p1 = p0 + p.rows();
                let lo = or0.max(p0);
                let hi = (or0 + orn).min(p1);
                if lo >= hi {
                    continue;
                }
                // Blocks of p overlapping local rows [lo-p0, hi-p0).
                let bi0 = (lo - p0) / p.block_shape.0;
                let bi1 = (hi - 1 - p0) / p.block_shape.0;
                for bi in bi0..=bi1 {
                    let br0 = p0 + bi * p.block_shape.0;
                    let brn = p.block_rows_at(bi);
                    let s_lo = lo.max(br0);
                    let s_hi = hi.min(br0 + brn);
                    futs.push(p.block(bi, oj));
                    // (src row offset in block, rows, dst row offset, …)
                    places.push((s_lo - br0, s_hi - s_lo, s_lo - or0, 0, ocn));
                }
            }
            let meta = crate::storage::BlockMeta::dense(orn, ocn);
            let places_c = places.clone();
            let out = rt.submit(
                "dsarray.vstack.gather",
                &futs,
                vec![meta],
                crate::tasking::CostHint::default().with_bytes(2.0 * meta.bytes() as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let mut out = DenseMatrix::zeros(orn, ocn);
                    for (b, &(sr, nr, dr, sc, nc)) in ins.iter().zip(&places_c) {
                        let d = b.to_dense()?;
                        let part = d.slice(sr, sc, nr, nc)?;
                        out.paste(dr, 0, &part)?;
                    }
                    Ok(vec![Block::Dense(out)])
                }),
            );
            blocks.push(out[0]);
        }
    }
    DsArray::from_parts(rt, (rows, cols), bs, blocks, false)
}

/// Stack ds-arrays horizontally (same rows + block shape, aligned widths).
pub fn hstack(parts: &[&DsArray]) -> Result<DsArray> {
    if parts.is_empty() {
        bail!("hstack of zero arrays");
    }
    if parts.iter().any(|p| p.is_lazy()) {
        let forced: Vec<DsArray> = parts.iter().map(|p| p.force()).collect::<Result<_>>()?;
        let refs: Vec<&DsArray> = forced.iter().collect();
        return hstack(&refs);
    }
    let first = parts[0];
    let bs = first.block_shape;
    for p in parts {
        if p.rows() != first.rows() {
            bail!("hstack row mismatch: {} vs {}", p.rows(), first.rows());
        }
        if p.block_shape != bs {
            bail!("hstack block-shape mismatch (rechunk first)");
        }
    }
    for p in &parts[..parts.len() - 1] {
        if p.cols() % bs.1 != 0 {
            bail!("hstack needs non-final arrays' cols divisible by the block width");
        }
    }
    let cols: usize = parts.iter().map(|p| p.cols()).sum();
    let gr = first.grid.0;
    let mut blocks = Vec::new();
    for i in 0..gr {
        for p in parts {
            blocks.extend(p.block_row(i));
        }
    }
    DsArray::from_parts(
        first.rt.clone(),
        (first.rows(), cols),
        bs,
        blocks,
        parts.iter().all(|p| p.sparse),
    )
}

impl DsArray {
    /// Synchronize and write the array as ONE CSV file (collect-based: the
    /// master materializes the full matrix — fine for small outputs). For
    /// arrays near or beyond memory, use the parallel partitioned writer
    /// [`crate::dsarray::io::save_csv_parts`], which writes one file per
    /// block-row from worker tasks and keeps the master empty-handed.
    pub fn save_csv(&self, path: &Path, delimiter: char) -> Result<()> {
        let m = self.collect()?;
        crate::storage::io::write_csv(path, &m, delimiter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::tasking::Runtime;

    #[test]
    fn vstack_aligned_fast_path() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(6, 4, |i, j| (i * 4 + j) as f32);
        let b = DenseMatrix::from_fn(4, 4, |i, j| 100.0 + (i * 4 + j) as f32);
        let da = creation::from_matrix(&rt, &a, (2, 2)).unwrap();
        let db = creation::from_matrix(&rt, &b, (2, 2)).unwrap();
        let before = rt.metrics().total_tasks();
        let v = vstack(&[&da, &db]).unwrap();
        assert_eq!(rt.metrics().total_tasks(), before, "fast path: no tasks");
        assert_eq!(v.shape(), (10, 4));
        assert_eq!(v.collect().unwrap(), DenseMatrix::vstack(&[&a, &b]).unwrap());
    }

    #[test]
    fn stacking_deferred_expressions_materializes_first() {
        // Regression: a deferred elementwise array's `blocks` hold the raw
        // UN-evaluated base operands; stacking must force the chain, not
        // splice those blocks in.
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let b = DenseMatrix::from_fn(4, 4, |i, j| (i + j) as f32);
        let da = creation::from_matrix(&rt, &a, (2, 2)).unwrap();
        let db = creation::from_matrix(&rt, &b, (2, 2)).unwrap();
        let lazy = da.add_scalar(10.0).unwrap();
        assert!(lazy.is_deferred());
        let v = vstack(&[&lazy, &db]).unwrap();
        let want = DenseMatrix::vstack(&[&a.map(|x| x + 10.0), &b]).unwrap();
        assert_eq!(v.collect().unwrap(), want);
        let h = hstack(&[&db, &lazy]).unwrap();
        let want = DenseMatrix::hstack(&[&b, &a.map(|x| x + 10.0)]).unwrap();
        assert_eq!(h.collect().unwrap(), want);
    }

    #[test]
    fn vstack_unaligned_reblocks() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(5, 4, |i, j| (i * 4 + j) as f32);
        let b = DenseMatrix::from_fn(4, 4, |i, j| 100.0 + (i * 4 + j) as f32);
        let da = creation::from_matrix(&rt, &a, (2, 2)).unwrap(); // 5 % 2 != 0
        let db = creation::from_matrix(&rt, &b, (2, 2)).unwrap();
        let v = vstack(&[&da, &db]).unwrap();
        assert_eq!(v.shape(), (9, 4));
        assert_eq!(v.collect().unwrap(), DenseMatrix::vstack(&[&a, &b]).unwrap());
    }

    #[test]
    fn hstack_and_mismatches() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let b = DenseMatrix::from_fn(4, 2, |i, j| -((i * 2 + j) as f32));
        let da = creation::from_matrix(&rt, &a, (2, 2)).unwrap();
        let db = creation::from_matrix(&rt, &b, (2, 2)).unwrap();
        let h = hstack(&[&da, &db]).unwrap();
        assert_eq!(h.shape(), (4, 6));
        assert_eq!(h.collect().unwrap(), DenseMatrix::hstack(&[&a, &b]).unwrap());
        // Row mismatch.
        let dc = creation::zeros(&rt, (6, 2), (2, 2)).unwrap();
        assert!(hstack(&[&da, &dc]).is_err());
        // Block mismatch for vstack.
        let dd = creation::zeros(&rt, (4, 4), (4, 4)).unwrap();
        assert!(vstack(&[&da, &dd]).is_err());
    }

    #[test]
    fn save_csv_round_trip() {
        let rt = Runtime::local(1);
        let a = creation::random(&rt, (6, 3), (2, 2), 5).unwrap();
        let p = std::env::temp_dir().join(format!("dsarr_save_{}.csv", std::process::id()));
        a.save_csv(&p, ',').unwrap();
        let back = creation::load_csv(&rt, &p, (6, 3), (2, 2), ',').unwrap();
        assert_eq!(back.collect().unwrap(), a.collect().unwrap());
        std::fs::remove_file(&p).ok();
    }
}
