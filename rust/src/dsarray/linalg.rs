//! Linear algebra: transpose (paper §5.2 — N tasks, one per row of blocks),
//! blocked matmul, and the Gram matrix `AᵀA` (computed without an explicit
//! transposed copy — the ALS enabler, §5.3).
//!
//! Both multiply flavors route through the plan layer ([`crate::plan`]):
//! the operand grids are captured as a [`GemmSpec`], which at optimizer
//! `Level::Full` stays *deferred* on the result array — later elementwise
//! maps graft into the gemm tiles as an epilogue, structurally identical
//! plans dedupe through the CSE memo, and the operands pre-release inside
//! the submission critical section. At `Level::Off` the spec lowers
//! immediately into the exact historical eager task stream.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::kernels::UnaryKind;
use crate::plan::{GemmKind, GemmSpec};
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{ops, BatchTask, CostHint, Future, Runtime};

use super::DsArray;

/// Densify and horizontally stack a row panel of blocks into one
/// contiguous matrix (single-block panels just densify).
fn hstack_panel(blocks: &[Arc<Block>]) -> Result<DenseMatrix> {
    if blocks.len() == 1 {
        return blocks[0].to_dense();
    }
    let dense: Vec<DenseMatrix> = blocks.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
    let refs: Vec<&DenseMatrix> = dense.iter().collect();
    DenseMatrix::hstack(&refs)
}

impl DsArray {
    /// Transpose: one task per **row of blocks** (collection-in /
    /// collection-out), then a master-side rearrangement of the grid so
    /// block (i,j) becomes block (j,i). For an N×M grid this is N tasks —
    /// versus N²+N for the Dataset baseline (paper §5.2) — submitted as ONE
    /// batch (one scheduler-lock round-trip for the whole operation).
    pub fn transpose(&self) -> Result<DsArray> {
        if self.is_lazy() {
            return self.force()?.transpose();
        }
        let (gr, gc) = self.grid;
        // Collected outputs: task i yields the transposed blocks of row i.
        let mut batch = Vec::with_capacity(gr);
        for i in 0..gr {
            let futs = self.block_row(i);
            let metas: Vec<BlockMeta> = futs.iter().map(|f| f.meta.transposed()).collect();
            let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
            batch.push(BatchTask::new(
                "dsarray.transpose.rowblocks",
                futs,
                metas,
                CostHint::default().with_bytes(2.0 * bytes),
                Arc::new(|ins: &[Arc<Block>]| Ok(ins.iter().map(|b| b.transpose()).collect())),
            ));
        }
        let row_outputs: Vec<Vec<Future>> = self.rt.submit_batch(batch);
        // Grid rearrangement happens on the master: no tasks.
        let mut blocks = Vec::with_capacity(gr * gc);
        for j in 0..gc {
            for i in 0..gr {
                blocks.push(row_outputs[i][j]);
            }
        }
        DsArray::from_parts(
            self.rt.clone(),
            (self.shape.1, self.shape.0),
            (self.block_shape.1, self.block_shape.0),
            blocks,
            self.sparse,
        )
    }

    /// Blocked matrix multiply: one task per output block, reading a row of
    /// blocks of `self` and a column of blocks of `other` (collections).
    pub fn matmul(&self, other: &DsArray) -> Result<DsArray> {
        if self.shape.1 != other.shape.0 {
            bail!(
                "matmul shape mismatch: {:?} @ {:?}",
                self.shape,
                other.shape
            );
        }
        if self.block_shape.1 != other.block_shape.0 {
            bail!(
                "matmul block mismatch: inner block {} vs {} (rechunk first)",
                self.block_shape.1,
                other.block_shape.0
            );
        }
        // Validated; now lazy views may pay their materialization tasks.
        if self.is_lazy() || other.is_lazy() {
            return self.force()?.matmul(&other.force()?);
        }
        self.plan_gemm(GemmSpec {
            kind: GemmKind::Nn,
            a: self.blocks.clone(),
            a_grid: self.grid,
            b: other.blocks.clone(),
            b_grid: other.grid,
            k_total: self.shape.1,
            out_shape: (self.shape.0, other.shape.1),
            out_block_shape: (self.block_shape.0, other.block_shape.1),
            epilogue: Vec::new(),
            state: Arc::default(),
        })
    }

    /// Route a blocked multiply through the plan layer: at optimizer
    /// `Level::Full` the gemm stays *deferred* (the spec rides on the
    /// result array, operand references retained) so later elementwise ops
    /// graft into its tiles and structurally identical plans dedupe;
    /// otherwise it lowers immediately — with CSE at `Level::Cse`, and as
    /// the exact historical eager task stream at `Level::Off`.
    fn plan_gemm(&self, spec: GemmSpec) -> Result<DsArray> {
        if self.rt.planner().fuse_enabled() {
            self.rt.retain(&spec.a);
            self.rt.retain(&spec.b);
            return Ok(DsArray::from_gemm(self.rt.clone(), spec));
        }
        lower_gemm(&self.rt, &spec)
    }

    /// Wrap a pending gemm plan as a deferred array. The caller has already
    /// retained the spec's operand references; `blocks` stays empty until
    /// [`DsArray::force`] lowers the plan.
    pub(crate) fn from_gemm(rt: Runtime, spec: GemmSpec) -> DsArray {
        let grid = spec.out_grid();
        DsArray {
            rt,
            shape: spec.out_shape,
            block_shape: spec.out_block_shape,
            grid,
            blocks: Vec::new(),
            sparse: false,
            view: None,
            expr: None,
            gemm: Some(spec),
        }
    }

    /// Lower a deferred gemm plan. A structurally identical plan forced in
    /// a recent epoch returns its memoized blocks with **zero tasks** (CSE);
    /// otherwise one task per output tile runs the accumulate loop plus any
    /// grafted elementwise epilogue while the tile is cache-hot. Either way
    /// the spec's operand references are released as soon as the tasks'
    /// reads are registered (dead-block pre-release, atomic with the
    /// submission) and the result is memoized in the spec's shared state —
    /// repeated consumers of one plan lower it once.
    pub(crate) fn force_gemm(&self) -> Result<DsArray> {
        let spec = self.gemm.as_ref().expect("force_gemm on deferred gemm arrays only");
        // Hold the state lock across the whole lowering (mirrors
        // `force_expr`): concurrent forces serialize, and grafting/cloning
        // observe either "pending with live operand refs" or "forced".
        let mut st = spec.state.lock().unwrap();
        if let Some(f) = &st.forced {
            return Ok(f.clone());
        }
        if let Some(blocks) = self.rt.cse_lookup(spec.key(), spec.n_tasks() as u64) {
            let out = DsArray::from_parts(
                self.rt.clone(),
                spec.out_shape,
                spec.out_block_shape,
                blocks,
                false,
            )?;
            // The plan never runs: drop its operand references now and arm
            // the credit so exactly one future Drop skips its release.
            self.rt.release(&spec.a);
            self.rt.release(&spec.b);
            st.release_credit = true;
            st.forced = Some(out.clone());
            return Ok(out);
        }
        let batch = build_gemm_batch(&self.rt, spec);
        let mut release = spec.a.clone();
        release.extend_from_slice(&spec.b);
        let blocks: Vec<Future> = self
            .rt
            .submit_batch_releasing(batch, &release)
            .into_iter()
            .map(|v| v[0])
            .collect();
        self.rt.planner().note_prereleased(release.len() as u64);
        // Credit is armed as soon as the handles are gone, so a failure
        // below can never lead Drop to double-release.
        st.release_credit = true;
        let out = DsArray::from_parts(
            self.rt.clone(),
            spec.out_shape,
            spec.out_block_shape,
            blocks,
            false,
        )?;
        self.rt.cse_record(spec.key(), &out.blocks);
        st.forced = Some(out.clone());
        Ok(out)
    }

    /// Kronecker product `self ⊗ other` (part of dislib's ds-array API):
    /// one task per block of self (each reading all of other's blocks);
    /// the result grid mirrors self's grid. Output block size is
    /// `(bs_a.0 * other.rows, bs_a.1 * other.cols)` so the grid layout
    /// follows self's grid directly.
    pub fn kron(&self, other: &DsArray) -> Result<DsArray> {
        if self.is_lazy() || other.is_lazy() {
            return self.force()?.kron(&other.force()?);
        }
        let (ar, ac) = self.shape;
        let (br, bc) = other.shape;
        // Each output "super-block" is (a_block ⊗ other) — computed as one
        // task reading one block of self + every block of other.
        let other_blocks: Vec<Future> = other.blocks.clone();
        let (obs0, obs1) = other.block_shape;
        let (ogr, ogc) = other.grid;
        let mut batch = Vec::with_capacity(self.blocks.len());
        for i in 0..self.grid.0 {
            let rows_a = self.block_rows_at(i);
            for j in 0..self.grid.1 {
                let cols_a = self.block_cols_at(j);
                let mut reads = vec![self.block(i, j)];
                reads.extend_from_slice(&other_blocks);
                let meta = BlockMeta::dense(rows_a * br, cols_a * bc);
                let flops = (rows_a * cols_a * br * bc) as f64;
                batch.push(BatchTask::new(
                    "dsarray.kron.block",
                    reads,
                    vec![meta],
                    CostHint::flops(flops).with_bytes(meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let a = ins[0].to_dense()?;
                        // Assemble other from its blocks.
                        let mut b = DenseMatrix::zeros(br, bc);
                        for (t, blk) in ins[1..].iter().enumerate() {
                            let (bi, bj) = (t / ogc, t % ogc);
                            let _ = ogr;
                            b.paste(bi * obs0, bj * obs1, &blk.to_dense()?)?;
                        }
                        let mut out = DenseMatrix::zeros(a.rows() * br, a.cols() * bc);
                        for r in 0..a.rows() {
                            for c in 0..a.cols() {
                                let scale = a.get(r, c);
                                if scale == 0.0 {
                                    continue;
                                }
                                let scaled = b.map(|x| x * scale);
                                out.paste(r * br, c * bc, &scaled)?;
                            }
                        }
                        Ok(vec![Block::Dense(out)])
                    }),
                ));
            }
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(
            self.rt.clone(),
            (ar * br, ac * bc),
            (self.block_shape.0 * br, self.block_shape.1 * bc),
            blocks,
            false,
        )
    }

    /// Pairwise squared Euclidean distances between rows:
    /// `D[i,j] = ‖selfᵢ − otherⱼ‖²`, the inner product of the KNN and
    /// K-means estimators, exposed as a first-class blocked operation. One
    /// task per output block — a block-row of `self` against a block-row of
    /// `other` (collections). Multi-column grids hstack their row panels
    /// inside the task; single-column grids go straight to
    /// [`ops::pairwise_dist2_op`], the kernel-layer distance micro-kernel.
    pub fn pairwise_dist2(&self, other: &DsArray) -> Result<DsArray> {
        if self.shape.1 != other.shape.1 {
            bail!(
                "pairwise_dist2 feature mismatch: {:?} vs {:?}",
                self.shape,
                other.shape
            );
        }
        if self.is_lazy() || other.is_lazy() {
            return self.force()?.pairwise_dist2(&other.force()?);
        }
        let feats = self.shape.1;
        let (gx, gy) = (self.grid.0, other.grid.0);
        let xc = self.grid.1;
        let one_panel = xc == 1 && other.grid.1 == 1;
        let mut batch = Vec::with_capacity(gx * gy);
        for i in 0..gx {
            let mx = self.block_rows_at(i);
            let x_row = self.block_row(i);
            for j in 0..gy {
                let my = other.block_rows_at(j);
                let mut futs = x_row.clone();
                futs.extend_from_slice(&other.block_row(j));
                let meta = BlockMeta::dense(mx, my);
                let flops = 3.0 * mx as f64 * my as f64 * feats as f64;
                let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
                let body = if one_panel {
                    ops::pairwise_dist2_op()
                } else {
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let (xs, ys) = ins.split_at(xc);
                        let x = hstack_panel(xs)?;
                        let y = hstack_panel(ys)?;
                        Ok(vec![Block::Dense(x.pairwise_dist2(&y)?)])
                    })
                };
                batch.push(BatchTask::new(
                    "dsarray.pairwise_dist2",
                    futs,
                    vec![meta],
                    CostHint::flops(flops).with_bytes(bytes),
                    body,
                ));
            }
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(
            self.rt.clone(),
            (self.shape.0, other.shape.0),
            (self.block_shape.0, other.block_shape.0),
            blocks,
            false,
        )
    }

    /// Gram matrix `AᵀA` computed directly from block columns — no
    /// transposed copy of `A` is ever materialized (ds-arrays give cheap
    /// column access; this is what the Dataset-based ALS could not do).
    pub fn gram(&self) -> Result<DsArray> {
        // Force once so a lazy view is not materialized twice for the two
        // tn_matmul operands.
        let a = self.force()?;
        a.tn_matmul(&a)
    }

    /// `selfᵀ @ other` without materializing the transpose: one task per
    /// output block, reading a block-column of each operand. Operands must
    /// share the row blocking.
    pub fn tn_matmul(&self, other: &DsArray) -> Result<DsArray> {
        if self.shape.0 != other.shape.0 || self.block_shape.0 != other.block_shape.0 {
            bail!(
                "tn_matmul row structure mismatch: {:?}/{:?} vs {:?}/{:?}",
                self.shape,
                self.block_shape,
                other.shape,
                other.block_shape
            );
        }
        if self.is_lazy() || other.is_lazy() {
            return self.force()?.tn_matmul(&other.force()?);
        }
        self.plan_gemm(GemmSpec {
            kind: GemmKind::Tn,
            a: self.blocks.clone(),
            a_grid: self.grid,
            b: other.blocks.clone(),
            b_grid: other.grid,
            k_total: self.shape.0,
            out_shape: (self.shape.1, other.shape.1),
            out_block_shape: (self.block_shape.1, other.block_shape.1),
            epilogue: Vec::new(),
            state: Arc::default(),
        })
    }
}

/// Lower a gemm plan eagerly (optimizer `Off`/`Cse`): exactly the
/// historical eager matmul/tn_matmul task stream. At `Level::Cse` a
/// memoized structurally identical plan short-circuits to zero tasks.
fn lower_gemm(rt: &Runtime, spec: &GemmSpec) -> Result<DsArray> {
    if let Some(blocks) = rt.cse_lookup(spec.key(), spec.n_tasks() as u64) {
        return DsArray::from_parts(
            rt.clone(),
            spec.out_shape,
            spec.out_block_shape,
            blocks,
            false,
        );
    }
    let batch = build_gemm_batch(rt, spec);
    let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
    let out = DsArray::from_parts(
        rt.clone(),
        spec.out_shape,
        spec.out_block_shape,
        blocks,
        false,
    )?;
    rt.cse_record(spec.key(), &out.blocks);
    Ok(out)
}

/// Materialize the task batch of one gemm plan: one task per output tile,
/// reading a row (Nn) or column (Tn) of blocks per operand, accumulating
/// every k-step straight into the output block (tiled gemm_acc / SpMM-acc
/// kernels), then running any grafted elementwise epilogue over the hot
/// tile through the runtime's SIMD vtable. With an empty epilogue the tasks
/// are bit- and metric-identical to the historical eager stream (same
/// names, cost hints, and bodies).
fn build_gemm_batch(rt: &Runtime, spec: &GemmSpec) -> Vec<BatchTask> {
    let ker = rt.kernels();
    let name = spec.task_name();
    let eps: Arc<[UnaryKind]> = spec.epilogue.clone().into();
    let n_ops = 1 + spec.epilogue.len() as u32;
    let (gr, gc) = spec.out_grid();
    let k_total = spec.k_total;
    let ep_flops = spec.epilogue.len() as f64;
    let mut batch = Vec::with_capacity(gr * gc);
    match spec.kind {
        GemmKind::Nn => {
            let kb = spec.a_grid.1;
            for i in 0..gr {
                let m = spec.a[i * kb].meta.rows;
                let a_row: Vec<Future> = (0..kb).map(|k| spec.a[i * kb + k]).collect();
                for j in 0..gc {
                    let n = spec.b[j].meta.cols;
                    let mut futs = a_row.clone();
                    futs.extend((0..kb).map(|k| spec.b[k * gc + j]));
                    let meta = BlockMeta::dense(m, n);
                    let flops =
                        2.0 * m as f64 * k_total as f64 * n as f64 + ep_flops * (m * n) as f64;
                    let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
                    let eps = Arc::clone(&eps);
                    batch.push(
                        BatchTask::new(
                            name,
                            futs,
                            vec![meta],
                            CostHint::flops(flops).with_bytes(bytes),
                            Arc::new(move |ins: &[Arc<Block>]| {
                                let (a_blocks, b_blocks) = ins.split_at(kb);
                                let mut acc = DenseMatrix::zeros(m, n);
                                for (a, b) in a_blocks.iter().zip(b_blocks) {
                                    match (&**a, &**b) {
                                        (Block::Csr(s), Block::Dense(d)) => {
                                            s.matmul_dense_acc(d, &mut acc)?
                                        }
                                        (x, y) => acc.gemm_acc(&x.to_dense()?, &y.to_dense()?)?,
                                    }
                                }
                                if !eps.is_empty() {
                                    (ker.epilogue)(acc.data_mut(), &eps);
                                }
                                Ok(vec![Block::Dense(acc)])
                            }),
                        )
                        .with_fused_ops(n_ops),
                    );
                }
            }
        }
        GemmKind::Tn => {
            let kb = spec.a_grid.0;
            for i in 0..gr {
                let ci = spec.a[i].meta.cols;
                let col_i: Vec<Future> =
                    (0..kb).map(|r| spec.a[r * spec.a_grid.1 + i]).collect();
                for j in 0..gc {
                    let cj = spec.b[j].meta.cols;
                    let mut futs = col_i.clone();
                    futs.extend((0..kb).map(|r| spec.b[r * spec.b_grid.1 + j]));
                    let meta = BlockMeta::dense(ci, cj);
                    let flops =
                        2.0 * ci as f64 * k_total as f64 * cj as f64 + ep_flops * (ci * cj) as f64;
                    let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
                    let eps = Arc::clone(&eps);
                    batch.push(
                        BatchTask::new(
                            name,
                            futs,
                            vec![meta],
                            CostHint::flops(flops).with_bytes(bytes),
                            Arc::new(move |ins: &[Arc<Block>]| {
                                let (a_blocks, b_blocks) = ins.split_at(kb);
                                let mut acc = DenseMatrix::zeros(ci, cj);
                                for (a, b) in a_blocks.iter().zip(b_blocks) {
                                    let at = a.to_dense()?.transpose();
                                    match &**b {
                                        Block::Csr(s) => acc.gemm_acc(&at, &s.to_dense())?,
                                        y => acc.gemm_acc(&at, &y.to_dense()?)?,
                                    }
                                }
                                if !eps.is_empty() {
                                    (ker.epilogue)(acc.data_mut(), &eps);
                                }
                                Ok(vec![Block::Dense(acc)])
                            }),
                        )
                        .with_fused_ops(n_ops),
                    );
                }
            }
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;

    #[test]
    fn transpose_matches_reference_and_task_count() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(6, 9, |i, j| (i * 9 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (2, 3)).unwrap();
        let before = rt.metrics();
        let t = a.transpose().unwrap();
        let d = rt.metrics().since(&before);
        // Paper: N tasks for an N×M grid (N = 3 block rows here).
        assert_eq!(d.tasks_for("dsarray.transpose.rowblocks"), 3);
        assert_eq!(t.shape(), (9, 6));
        assert_eq!(t.block_shape(), (3, 2));
        assert_eq!(t.collect().unwrap(), m.transpose());
    }

    #[test]
    fn transpose_involution() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(5, 4, |i, j| (i + 2 * j) as f32);
        let a = creation::from_matrix(&rt, &m, (2, 3)).unwrap();
        let tt = a.transpose().unwrap().transpose().unwrap();
        assert_eq!(tt.collect().unwrap(), m);
    }

    #[test]
    fn sparse_transpose_stays_sparse() {
        let rt = Runtime::local(2);
        let csr =
            crate::storage::CsrMatrix::from_triplets(4, 6, &[(0, 5, 1.0), (3, 2, 2.0)]).unwrap();
        let a = creation::from_csr(&rt, &csr, (2, 3)).unwrap();
        let t = a.transpose().unwrap();
        assert!(t.is_sparse());
        assert_eq!(t.collect_csr().unwrap().to_dense(), csr.to_dense().transpose());
    }

    #[test]
    fn matmul_matches_reference() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(5, 6, |i, j| ((i * 6 + j) % 7) as f32 - 3.0);
        let b = DenseMatrix::from_fn(6, 4, |i, j| ((i * 4 + j) % 5) as f32 * 0.5);
        let da = creation::from_matrix(&rt, &a, (2, 3)).unwrap();
        let db = creation::from_matrix(&rt, &b, (3, 2)).unwrap();
        let before = rt.metrics();
        let dc = da.matmul(&db).unwrap();
        let d = rt.metrics().since(&before);
        // One task per output block: ceil(5/2) x ceil(4/2) = 3x2 = 6.
        assert_eq!(d.tasks_for("dsarray.matmul.block"), 6);
        let got = dc.collect().unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_shape_checks() {
        let rt = Runtime::local(1);
        let a = creation::zeros(&rt, (4, 4), (2, 2)).unwrap();
        let b = creation::zeros(&rt, (5, 4), (2, 2)).unwrap();
        assert!(a.matmul(&b).is_err());
        let c = creation::zeros(&rt, (4, 4), (3, 3)).unwrap();
        assert!(a.matmul(&c).is_err());
    }

    #[test]
    fn gram_without_transpose_copy() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(7, 5, |i, j| ((i * 5 + j) % 4) as f32 - 1.5);
        let da = creation::from_matrix(&rt, &a, (3, 2)).unwrap();
        let g = da.gram().unwrap();
        assert_eq!(g.shape(), (5, 5));
        let want = a.transpose().matmul(&a).unwrap();
        assert!(g.collect().unwrap().max_abs_diff(&want) < 1e-4);
        // No transpose tasks were needed.
        assert_eq!(rt.metrics().tasks_for("dsarray.transpose.rowblocks"), 0);
        assert_eq!(rt.metrics().tasks_for("dsarray.tn_matmul.block"), 9);
    }

    #[test]
    fn tn_matmul_rectangular() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(6, 4, |i, j| (i * 4 + j) as f32 * 0.25);
        let b = DenseMatrix::from_fn(6, 3, |i, j| ((i + j) % 3) as f32 - 1.0);
        let da = creation::from_matrix(&rt, &a, (2, 2)).unwrap();
        let db = creation::from_matrix(&rt, &b, (2, 2)).unwrap();
        let got = da.tn_matmul(&db).unwrap();
        assert_eq!(got.shape(), (4, 3));
        let want = a.transpose().matmul(&b).unwrap();
        assert!(got.collect().unwrap().max_abs_diff(&want) < 1e-4);
        // Row-structure mismatch rejected.
        let dc = creation::from_matrix(&rt, &b, (3, 2)).unwrap();
        assert!(da.tn_matmul(&dc).is_err());
    }

    #[test]
    fn kron_matches_reference() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(3, 2, |i, j| (i * 2 + j) as f32 - 2.0);
        let b = DenseMatrix::from_fn(2, 3, |i, j| (i + j) as f32 * 0.5 + 1.0);
        let da = creation::from_matrix(&rt, &a, (2, 1)).unwrap();
        let db = creation::from_matrix(&rt, &b, (1, 2)).unwrap();
        let k = da.kron(&db).unwrap();
        assert_eq!(k.shape(), (6, 6));
        let got = k.collect().unwrap();
        for i in 0..6 {
            for j in 0..6 {
                let want = a.get(i / 2, j / 3) * b.get(i % 2, j % 3);
                assert!((got.get(i, j) - want).abs() < 1e-6, "({i},{j})");
            }
        }
        // kron with identity reproduces a block-diagonal embedding.
        let eye = creation::identity(&rt, 2, (2, 2)).unwrap();
        let ke = db.kron(&eye).unwrap();
        let got = ke.collect().unwrap();
        assert_eq!(got.get(0, 0), b.get(0, 0));
        assert_eq!(got.get(1, 1), b.get(0, 0));
        assert_eq!(got.get(0, 1), 0.0);
    }

    #[test]
    fn pairwise_dist2_matches_oracle_across_grids() {
        let rt = Runtime::local(2);
        let x = DenseMatrix::from_fn(7, 5, |i, j| ((i * 5 + j) % 9) as f32 * 0.3 - 1.0);
        let y = DenseMatrix::from_fn(4, 5, |i, j| ((i + 2 * j) % 7) as f32 * 0.5);
        // Multi-column grid on x (panels hstacked in-task), single-column
        // grid on y.
        let dx = creation::from_matrix(&rt, &x, (3, 2)).unwrap();
        let dy = creation::from_matrix(&rt, &y, (2, 5)).unwrap();
        let before = rt.metrics();
        let d = dx.pairwise_dist2(&dy).unwrap();
        let delta = rt.metrics().since(&before);
        // One task per (block-row of x) × (block-row of y): 3 × 2.
        assert_eq!(delta.tasks_for("dsarray.pairwise_dist2"), 6);
        assert_eq!(d.shape(), (7, 4));
        let got = d.collect().unwrap();
        for i in 0..7 {
            for j in 0..4 {
                let want: f32 = (0..5)
                    .map(|k| {
                        let dk = x.get(i, k) - y.get(j, k);
                        dk * dk
                    })
                    .sum();
                assert!((got.get(i, j) - want).abs() <= 1e-4 * want.max(1.0), "({i},{j})");
            }
        }
        // Single-panel fast path (both grids one block wide) agrees.
        let dx1 = creation::from_matrix(&rt, &x, (4, 5)).unwrap();
        let d1 = dx1.pairwise_dist2(&dy).unwrap().collect().unwrap();
        assert_eq!(d1, got);
        // Feature-dimension mismatch rejected.
        let bad = creation::zeros(&rt, (3, 4), (2, 2)).unwrap();
        assert!(dx.pairwise_dist2(&bad).is_err());
    }

    #[test]
    fn full_level_grafts_epilogue_bit_identical_with_fewer_tasks() {
        let m_a = DenseMatrix::from_fn(8, 6, |i, j| ((i * 6 + j) % 7) as f32 - 3.0);
        let m_b = DenseMatrix::from_fn(6, 4, |i, j| ((i * 4 + j) % 5) as f32 * 0.5);

        let off = Runtime::local(2);
        let a = creation::from_matrix(&off, &m_a, (4, 3)).unwrap();
        let b = creation::from_matrix(&off, &m_b, (3, 2)).unwrap();
        let want = a
            .matmul(&b)
            .unwrap()
            .mul_scalar(0.5)
            .unwrap()
            .abs()
            .unwrap()
            .collect()
            .unwrap();

        let full = Runtime::local(2).with_optimizer(crate::plan::Level::Full);
        let a = creation::from_matrix(&full, &m_a, (4, 3)).unwrap();
        let b = creation::from_matrix(&full, &m_b, (3, 2)).unwrap();
        let before = full.metrics();
        let c = a.matmul(&b).unwrap().mul_scalar(0.5).unwrap().abs().unwrap();
        assert_eq!(
            full.metrics().total_tasks(),
            before.total_tasks(),
            "gemm + epilogue stays pending until force"
        );
        let plan = c.explain();
        assert!(plan.contains("optimizer: full"), "{plan}");
        assert!(plan.contains("epilogue"), "{plan}");
        let got = c.collect().unwrap();
        assert_eq!(got.max_abs_diff(&want), 0.0, "bit-identical across levels");

        let d = full.metrics().since(&before);
        // 2x2 output tiles, gemm + both unary ops in one task each.
        assert_eq!(d.tasks_for("dsarray.matmul.fused"), 4);
        assert_eq!(d.tasks_for("dsarray.matmul.block"), 0);
        assert_eq!(d.tasks_for("dsarray.ew.fused"), 0);
        assert!(
            full.metrics().total_tasks() < off.metrics().total_tasks(),
            "optimizer must strictly shrink the task stream"
        );
        assert!(full.metrics().blocks_prereleased > 0, "operands pre-released");
        // Forcing again reuses the memoized gemm result.
        assert!(c.explain().contains("already forced"));
    }

    #[test]
    fn cse_dedupes_repeated_gram_across_collect_epochs() {
        let rt = Runtime::local(2).with_optimizer(crate::plan::Level::Cse);
        let m = DenseMatrix::from_fn(7, 5, |i, j| ((i * 5 + j) % 4) as f32 - 1.5);
        let x = creation::from_matrix(&rt, &m, (3, 2)).unwrap();

        let g1 = x.gram().unwrap();
        let first = rt.metrics().tasks_for("dsarray.tn_matmul.block");
        assert_eq!(first, 9);
        let r1 = g1.collect().unwrap(); // bumps the collect epoch

        // Structurally identical subgraph: memo hit, zero new gemm tasks.
        let g2 = x.gram().unwrap();
        assert_eq!(rt.metrics().tasks_for("dsarray.tn_matmul.block"), first);
        assert!(rt.metrics().tasks_deduped >= 9);
        assert_eq!(g2.collect().unwrap(), r1);

        // A different subgraph (other operand ids) still lowers fresh.
        let y = creation::from_matrix(&rt, &m, (3, 2)).unwrap();
        let _ = y.gram().unwrap();
        assert_eq!(rt.metrics().tasks_for("dsarray.tn_matmul.block"), first + 9);
    }

    #[test]
    fn sparse_dense_matmul() {
        let rt = Runtime::local(2);
        let csr = crate::storage::CsrMatrix::from_triplets(
            4,
            6,
            &[(0, 0, 2.0), (1, 3, 1.0), (3, 5, -1.0)],
        )
        .unwrap();
        let a = creation::from_csr(&rt, &csr, (2, 3)).unwrap();
        let b = DenseMatrix::from_fn(6, 3, |i, j| (i + j) as f32);
        let db = creation::from_matrix(&rt, &b, (3, 2)).unwrap();
        let got = a.matmul(&db).unwrap().collect().unwrap();
        let want = csr.to_dense().matmul(&b).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-5);
    }
}
