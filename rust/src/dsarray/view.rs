//! The zero-copy view layer (paper §4.2.3).
//!
//! The paper's complexity argument is that indexing a ds-array is a
//! *metadata* operation, not a data movement: a slice only needs to know
//! which blocks it overlaps and at what offset. This module makes that
//! claim executable. A [`ViewSpec`] is a slice descriptor carried alongside
//! the block grid: a row/col offset plus extent, optionally replaced by an
//! arbitrary index map per axis (fancy indexing). Slicing constructs a view
//! that *shares* the parent's block futures — zero tasks submitted, handle
//! references retained through the refcount-reclamation machinery — and the
//! data is only copied when something actually needs canonical blocks:
//!
//! * block-aligned slices whose extent ends on a block boundary (or the
//!   array edge) are detected at construction time and returned as fully
//!   canonical arrays — they are *never* materialized;
//! * every other slice and every fancy-indexed selection stays lazy until
//!   [`DsArray::force`] runs, which a downstream operation (matmul,
//!   reductions, rechunk, shuffle, estimator fits, …) triggers implicitly;
//! * `collect` and `get` never force: they synchronize the backing blocks
//!   and apply the mapping master-side.
//!
//! Materialization preserves the sparse backend: per-block extraction goes
//! through [`Block::slice`]/[`Block::take_rows`]/[`Block::take_cols`] and
//! cross-block gathers assemble CSR regions with CSR stacking, so slicing a
//! sparse ds-array no longer silently densifies it.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, CsrMatrix, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future, Runtime};

use super::DsArray;

/// Slice descriptor attached to a lazy [`DsArray`] view. Logical element
/// `(i, j)` of the view lives at stored element `(map_row(i), map_col(j))`
/// of the backing sub-grid (`DsArray::blocks`).
#[derive(Clone, Debug, Default)]
pub(crate) struct ViewSpec {
    /// Stored row of logical row 0 (ignored when `row_index` is set).
    pub row_off: usize,
    /// Stored column of logical column 0 (ignored when `col_index` is set).
    pub col_off: usize,
    /// Fancy row indexing: logical row `k` is stored row `row_index[k]`.
    /// Arbitrary order and duplicates are allowed.
    pub row_index: Option<Arc<Vec<usize>>>,
    /// Fancy column indexing, same contract as `row_index`.
    pub col_index: Option<Arc<Vec<usize>>>,
}

impl ViewSpec {
    /// Stored row backing logical row `k`.
    pub fn map_row(&self, k: usize) -> usize {
        match &self.row_index {
            Some(m) => m[k],
            None => self.row_off + k,
        }
    }

    /// Stored column backing logical column `k`.
    pub fn map_col(&self, k: usize) -> usize {
        match &self.col_index {
            Some(m) => m[k],
            None => self.col_off + k,
        }
    }

    /// Stored-coordinate selection of the logical row range `[lo, lo+len)`.
    pub fn row_sel(&self, lo: usize, len: usize) -> Sel {
        match &self.row_index {
            Some(m) => Sel::Idx(m[lo..lo + len].to_vec()),
            None => Sel::Range {
                start: self.row_off + lo,
                len,
            },
        }
    }

    /// Stored-coordinate selection of the logical column range `[lo, lo+len)`.
    pub fn col_sel(&self, lo: usize, len: usize) -> Sel {
        match &self.col_index {
            Some(m) => Sel::Idx(m[lo..lo + len].to_vec()),
            None => Sel::Range {
                start: self.col_off + lo,
                len,
            },
        }
    }
}

/// One axis of one materialization task: which stored coordinates feed the
/// output, in output order.
#[derive(Clone, Debug)]
pub(crate) enum Sel {
    /// Contiguous stored range `[start, start + len)`.
    Range { start: usize, len: usize },
    /// Arbitrary stored indices.
    Idx(Vec<usize>),
}

impl Sel {
    fn count(&self) -> usize {
        match self {
            Sel::Range { len, .. } => *len,
            Sel::Idx(v) => v.len(),
        }
    }

    /// Stored block-lines this selection reads (sorted, deduplicated).
    fn needed_lines(&self, bs: usize) -> Vec<usize> {
        match self {
            Sel::Range { start, len } => ((start / bs)..=((start + len - 1) / bs)).collect(),
            Sel::Idx(v) => {
                let mut lines: Vec<usize> = v.iter().map(|&s| s / bs).collect();
                lines.sort_unstable();
                lines.dedup();
                lines
            }
        }
    }

    /// Rebase stored coordinates onto a region stacked from `lines` (whose
    /// cumulative start offsets are `offs`).
    fn localize(&self, bs: usize, lines: &[usize], offs: &[usize]) -> Sel {
        let to_local = |s: usize| {
            let line = s / bs;
            let pos = lines.binary_search(&line).expect("needed line present");
            offs[pos] + (s - line * bs)
        };
        match self {
            // A contiguous stored range stays contiguous: its needed lines
            // are consecutive and each is stacked in full.
            Sel::Range { start, len } => Sel::Range {
                start: to_local(*start),
                len: *len,
            },
            Sel::Idx(v) => Sel::Idx(v.iter().map(|&s| to_local(s)).collect()),
        }
    }
}

/// Compact a stored-coordinate index list onto the sub-grid of its touched
/// block-lines: returns (kept lines, sorted/deduplicated, and the indices
/// rebased onto that compacted grid). Keeping only touched lines is what
/// stops a small fancy-index view from pinning the whole backing grid
/// resident (refcount reclamation keeps working for untouched blocks).
fn compact_index(idx: &[usize], bs: usize) -> (Vec<usize>, Vec<usize>) {
    let mut lines: Vec<usize> = idx.iter().map(|&s| s / bs).collect();
    lines.sort_unstable();
    lines.dedup();
    // All kept lines except the last are full (`bs`-sized): any non-final
    // parent line is full, and the parent's final line sorts last. So the
    // compacted coordinate of a row is `position_of_line * bs + local`.
    let remapped = idx
        .iter()
        .map(|&s| {
            let pos = lines.binary_search(&(s / bs)).expect("own line present");
            pos * bs + (s % bs)
        })
        .collect();
    (lines, remapped)
}

/// Stack the input blocks of a gather task (row-major, `ncl` blocks per
/// band) into one region block, staying CSR when every input is CSR.
/// Single-input tasks bypass this (extraction reads the input directly).
fn stack_region(ins: &[Arc<Block>], ncl: usize) -> Result<Block> {
    if ins.iter().all(|b| matches!(&**b, Block::Csr(_))) {
        let mut bands: Vec<CsrMatrix> = Vec::with_capacity(ins.len() / ncl);
        for band in ins.chunks(ncl) {
            let parts: Vec<&CsrMatrix> = band.iter().map(|b| b.as_csr().unwrap()).collect();
            bands.push(CsrMatrix::hstack(&parts)?);
        }
        let refs: Vec<&CsrMatrix> = bands.iter().collect();
        Ok(Block::Csr(CsrMatrix::vstack(&refs)?))
    } else {
        let dense: Vec<DenseMatrix> = ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
        let mut bands: Vec<DenseMatrix> = Vec::with_capacity(dense.len() / ncl);
        for band in dense.chunks(ncl) {
            let refs: Vec<&DenseMatrix> = band.iter().collect();
            bands.push(DenseMatrix::hstack(&refs)?);
        }
        let refs: Vec<&DenseMatrix> = bands.iter().collect();
        Ok(Block::Dense(DenseMatrix::vstack(&refs)?))
    }
}

/// Extract the selected sub-matrix from a region block, preserving backend.
fn extract(region: &Block, rows: &Sel, cols: &Sel) -> Result<Block> {
    let picked = match rows {
        Sel::Range { start, len } => region.slice(*start, 0, *len, region.cols())?,
        Sel::Idx(v) => region.take_rows(v)?,
    };
    match cols {
        Sel::Range { start, len } => picked.slice(0, *start, picked.rows(), *len),
        Sel::Idx(v) => picked.take_cols(v),
    }
}

impl DsArray {
    /// Whether this array is a lazy view over another array's blocks
    /// (shared futures plus a slice descriptor — see [`DsArray::force`]).
    /// Canonical arrays return `false`.
    pub fn is_view(&self) -> bool {
        self.view.is_some()
    }

    /// Shape of the stored backing grid (equals [`DsArray::shape`] for
    /// canonical arrays; for views it is the region the shared blocks
    /// cover, of which the view exposes a subset).
    pub(crate) fn stored_shape(&self) -> (usize, usize) {
        if self.view.is_none() {
            return self.shape;
        }
        let rows = (0..self.grid.0)
            .map(|i| self.blocks[i * self.grid.1].meta.rows)
            .sum();
        let cols = (0..self.grid.1).map(|j| self.blocks[j].meta.cols).sum();
        (rows, cols)
    }

    /// Declared output metadata for an `r × c` selection of this array:
    /// dense, or a proportional-nnz CSR estimate when sparse.
    pub(crate) fn sel_out_meta(&self, r: usize, c: usize) -> BlockMeta {
        if !self.sparse {
            return BlockMeta::dense(r, c);
        }
        let total_nnz: usize = self.blocks.iter().map(|b| b.meta.nnz).sum();
        let (sr, sc) = self.stored_shape();
        let frac = (r * c) as f64 / (sr * sc).max(1) as f64;
        BlockMeta::sparse(r, c, (total_nnz as f64 * frac).round() as usize)
    }

    /// Assemble a lazy view over an explicit backing sub-grid. Retains one
    /// handle reference per block (released on drop), validates that the
    /// mapping stays inside the stored region, and never submits tasks.
    pub(crate) fn from_view(
        rt: Runtime,
        shape: (usize, usize),
        block_shape: (usize, usize),
        stored_grid: (usize, usize),
        blocks: Vec<Future>,
        sparse: bool,
        view: ViewSpec,
    ) -> Result<Self> {
        if blocks.len() != stored_grid.0 * stored_grid.1 {
            bail!(
                "view block count {} != backing grid {}x{}",
                blocks.len(),
                stored_grid.0,
                stored_grid.1
            );
        }
        rt.retain(&blocks);
        let arr = Self {
            rt,
            shape,
            block_shape,
            grid: stored_grid,
            blocks,
            sparse,
            view: Some(view),
            expr: None,
            gemm: None,
        };
        // Non-terminal stored lines must be full blocks: the view's
        // `coordinate / block_size` arithmetic depends on it. Sub-grids of a
        // regular parent grid satisfy this by construction.
        for i in 0..arr.grid.0.saturating_sub(1) {
            debug_assert_eq!(arr.blocks[i * arr.grid.1].meta.rows, arr.block_shape.0);
        }
        for j in 0..arr.grid.1.saturating_sub(1) {
            debug_assert_eq!(arr.blocks[j].meta.cols, arr.block_shape.1);
        }
        let (sr, sc) = arr.stored_shape();
        let v = arr.view.as_ref().expect("just set");
        let max_r = match &v.row_index {
            Some(m) => m.iter().copied().max().unwrap_or(0),
            None => v.row_off + shape.0 - 1,
        };
        let max_c = match &v.col_index {
            Some(m) => m.iter().copied().max().unwrap_or(0),
            None => v.col_off + shape.1 - 1,
        };
        if max_r >= sr || max_c >= sc {
            bail!("view mapping reaches ({max_r},{max_c}), backing region is {sr}x{sc}");
        }
        Ok(arr)
    }

    /// Wrap a backing sub-grid as either a canonical array (when the view
    /// descriptor is trivial and the blocks exactly cover `shape` — the
    /// block-aligned fast path, pure metadata forever) or a lazy view.
    pub(crate) fn wrap_view(
        rt: Runtime,
        shape: (usize, usize),
        block_shape: (usize, usize),
        stored_grid: (usize, usize),
        blocks: Vec<Future>,
        sparse: bool,
        view: ViewSpec,
    ) -> Result<Self> {
        let trivial = view.row_index.is_none()
            && view.col_index.is_none()
            && view.row_off == 0
            && view.col_off == 0;
        if trivial {
            let stored_rows: usize = (0..stored_grid.0)
                .map(|i| blocks[i * stored_grid.1].meta.rows)
                .sum();
            let stored_cols: usize = (0..stored_grid.1).map(|j| blocks[j].meta.cols).sum();
            if (stored_rows, stored_cols) == shape {
                return DsArray::from_parts(rt, shape, block_shape, blocks, sparse);
            }
        }
        DsArray::from_view(rt, shape, block_shape, stored_grid, blocks, sparse, view)
    }

    /// Materialize a lazy view or a deferred elementwise expression into a
    /// canonical blocked array.
    ///
    /// Canonical arrays (including block-aligned slices) return a cheap
    /// clone that shares blocks — zero tasks. Lazy views submit one copy
    /// task per output block (`dsarray.index.slice` when the output lives
    /// inside a single backing block, `dsarray.index.gather` otherwise) and
    /// preserve the sparse backend throughout. Deferred elementwise chains
    /// (`dsarray::expr`) collapse to one fused `dsarray.ew.fused` task per
    /// block, executed in place when the executor holds the sole reference
    /// to an input block; their materialization is **memoized**, so
    /// repeated consumers of one chain execute it once. Operations that
    /// need canonical blocks (linalg, reductions, rechunk, shuffle, the
    /// estimators) call this implicitly; for views, call it yourself before
    /// chaining several such operations off one view, so the copy happens
    /// once instead of per operation.
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, tasking::Runtime};
    /// let rt = Runtime::local(2);
    /// let a = creation::random(&rt, (8, 8), (4, 4), 1).unwrap();
    /// let lazy = a.slice(1, 6, 2, 7).unwrap();
    /// assert!(lazy.is_view());
    /// let owned = lazy.force().unwrap();
    /// assert!(!owned.is_view());
    /// assert_eq!(owned.collect().unwrap(), lazy.collect().unwrap());
    /// ```
    pub fn force(&self) -> Result<DsArray> {
        if self.gemm.is_some() {
            return self.force_gemm();
        }
        if self.expr.is_some() {
            return self.force_expr();
        }
        let Some(view) = self.view.clone() else {
            return Ok(self.clone());
        };
        let (nr, nc) = self.shape;
        let (bs0, bs1) = self.block_shape;
        let out_grid = (Self::grid_dim(nr, bs0), Self::grid_dim(nc, bs1));
        let mut batch = Vec::with_capacity(out_grid.0 * out_grid.1);
        for oi in 0..out_grid.0 {
            let r_lo = oi * bs0;
            let rsel = view.row_sel(r_lo, (nr - r_lo).min(bs0));
            for oj in 0..out_grid.1 {
                let c_lo = oj * bs1;
                let csel = view.col_sel(c_lo, (nc - c_lo).min(bs1));
                batch.push(self.gather_task(rsel.clone(), csel));
            }
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(
            self.rt.clone(),
            self.shape,
            self.block_shape,
            blocks,
            self.sparse,
        )
    }

    /// Build the copy task materializing one output block of a view.
    fn gather_task(&self, rsel: Sel, csel: Sel) -> BatchTask {
        let (bs0, bs1) = self.block_shape;
        let rlines = rsel.needed_lines(bs0);
        let clines = csel.needed_lines(bs1);
        let mut futs = Vec::with_capacity(rlines.len() * clines.len());
        for &bi in &rlines {
            for &bj in &clines {
                futs.push(self.block(bi, bj));
            }
        }
        // Start offset of each needed line within the stacked region.
        let mut roffs = Vec::with_capacity(rlines.len());
        let mut acc = 0;
        for &bi in &rlines {
            roffs.push(acc);
            acc += self.blocks[bi * self.grid.1].meta.rows;
        }
        let mut coffs = Vec::with_capacity(clines.len());
        let mut acc = 0;
        for &bj in &clines {
            coffs.push(acc);
            acc += self.blocks[bj].meta.cols;
        }
        let r_local = rsel.localize(bs0, &rlines, &roffs);
        let c_local = csel.localize(bs1, &clines, &coffs);
        let out_meta = self.sel_out_meta(rsel.count(), csel.count());
        let bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
        let name = if futs.len() == 1 {
            "dsarray.index.slice"
        } else {
            "dsarray.index.gather"
        };
        let ncl = clines.len();
        BatchTask::new(
            name,
            futs,
            vec![out_meta],
            CostHint::default().with_bytes(bytes + out_meta.bytes() as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                // Single input: extract straight from the resolved block —
                // no region copy.
                if ins.len() == 1 {
                    return Ok(vec![extract(&ins[0], &r_local, &c_local)?]);
                }
                let region = stack_region(ins, ncl)?;
                Ok(vec![extract(&region, &r_local, &c_local)?])
            }),
        )
    }

    /// Build a zero-task view (or canonical array) selecting `rsel × csel`
    /// of this array's backing grid, in stored coordinates. Each axis keeps
    /// only the block-lines it touches: contiguous selections rebase their
    /// offset onto the restricted range, fancy selections are compacted via
    /// [`compact_index`] — so small views never pin unrelated blocks.
    pub(crate) fn select_stored(&self, rsel: Sel, csel: Sel) -> Result<DsArray> {
        let (bs0, bs1) = self.block_shape;
        let shape = (rsel.count(), csel.count());
        let (rlines, row_off, row_index) = match rsel {
            Sel::Range { start, len } => {
                let lines: Vec<usize> = ((start / bs0)..=((start + len - 1) / bs0)).collect();
                let off = start - lines[0] * bs0;
                (lines, off, None)
            }
            Sel::Idx(idx) => {
                let (lines, remapped) = compact_index(&idx, bs0);
                (lines, 0, Some(Arc::new(remapped)))
            }
        };
        let (clines, col_off, col_index) = match csel {
            Sel::Range { start, len } => {
                let lines: Vec<usize> = ((start / bs1)..=((start + len - 1) / bs1)).collect();
                let off = start - lines[0] * bs1;
                (lines, off, None)
            }
            Sel::Idx(idx) => {
                let (lines, remapped) = compact_index(&idx, bs1);
                (lines, 0, Some(Arc::new(remapped)))
            }
        };
        let mut blocks = Vec::with_capacity(rlines.len() * clines.len());
        for &bi in &rlines {
            for &bj in &clines {
                blocks.push(self.block(bi, bj));
            }
        }
        DsArray::wrap_view(
            self.rt.clone(),
            shape,
            self.block_shape,
            (rlines.len(), clines.len()),
            blocks,
            self.sparse,
            ViewSpec {
                row_off,
                col_off,
                row_index,
                col_index,
            },
        )
    }

    /// The stored block-lines a view touches per axis (canonical arrays
    /// touch everything). Used by the master-side `collect`/`get` paths.
    pub(crate) fn touched_lines(&self) -> (Vec<usize>, Vec<usize>) {
        match &self.view {
            None => ((0..self.grid.0).collect(), (0..self.grid.1).collect()),
            Some(v) => (
                v.row_sel(0, self.shape.0).needed_lines(self.block_shape.0),
                v.col_sel(0, self.shape.1).needed_lines(self.block_shape.1),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use super::*;
    use crate::tasking::Runtime;

    #[test]
    fn sel_geometry() {
        let r = Sel::Range { start: 5, len: 7 };
        assert_eq!(r.count(), 7);
        assert_eq!(r.needed_lines(4), vec![1, 2]);
        let i = Sel::Idx(vec![9, 0, 9, 2]);
        assert_eq!(i.count(), 4);
        assert_eq!(i.needed_lines(4), vec![0, 2]);
        // Localize onto a region stacked from lines [0, 2] of size 4 each.
        let loc = i.localize(4, &[0, 2], &[0, 4]);
        match loc {
            Sel::Idx(v) => assert_eq!(v, vec![5, 0, 5, 2]),
            _ => panic!("expected Idx"),
        }
        let loc = r.localize(4, &[1, 2], &[0, 4]);
        match loc {
            Sel::Range { start, len } => assert_eq!((start, len), (1, 7)),
            _ => panic!("expected Range"),
        }
    }

    #[test]
    fn compact_index_keeps_only_touched_lines() {
        let (lines, remapped) = compact_index(&[7, 1, 7, 2], 3);
        assert_eq!(lines, vec![0, 2]);
        // Line 2 stacks right after line 0: stored 7 → 3 + 1 = 4.
        assert_eq!(remapped, vec![4, 1, 4, 2]);
        // Identity when every line is touched.
        let (lines, remapped) = compact_index(&[5, 0, 3], 3);
        assert_eq!(lines, vec![0, 1]);
        assert_eq!(remapped, vec![5, 0, 3]);
    }

    #[test]
    fn fancy_views_pin_only_touched_lines() {
        // take_rows of a few rows must not retain the whole backing grid:
        // untouched block-rows stay reclaimable by the refcount machinery.
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(9, 4, |i, j| (i * 4 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (3, 4)).unwrap(); // 3x1 grid
        let v = a.take_rows(&[1, 0]).unwrap(); // touches block-row 0 only
        assert_eq!(v.grid(), (1, 1));
        let untouched = a.block(2, 0);
        let consumed = a.add_scalar(1.0).unwrap(); // reads every block
        drop(a);
        consumed.collect().unwrap();
        rt.barrier().unwrap();
        // The untouched line was evicted once its reader finished; the
        // view's shared line survives.
        assert!(rt.wait(untouched).is_err());
        let got = v.collect().unwrap();
        assert_eq!(got.row(0), m.row(1));
        assert_eq!(got.row(1), m.row(0));
    }

    #[test]
    fn force_on_canonical_is_free() {
        let rt = Runtime::local(1);
        let a = creation::zeros(&rt, (6, 6), (2, 2)).unwrap();
        let before = rt.metrics().total_tasks();
        let f = a.force().unwrap();
        assert_eq!(rt.metrics().total_tasks(), before);
        assert!(!f.is_view());
        assert_eq!(f.block(1, 1), a.block(1, 1));
    }

    #[test]
    fn forcing_a_view_copies_once_per_output_block() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(9, 9, |i, j| (i * 9 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (3, 3)).unwrap();
        let v = a.slice(1, 8, 1, 8).unwrap();
        assert!(v.is_view());
        let before = rt.metrics();
        let f = v.force().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_with_prefix("dsarray.index."), f.n_blocks() as u64);
        assert_eq!(f.collect().unwrap(), m.slice(1, 1, 7, 7).unwrap());
    }
}
