//! Element-wise operators (paper §4.2.3): scalar ops (`A ** 2`, `A + 1`),
//! array∘array ops, math maps (`sqrt`, `abs`, `exp`) and row broadcasts.
//! All of them are **deferred** on dense arrays: they attach a fused
//! expression (`dsarray::expr`) and submit zero tasks, so a chain like
//! `(x − μ) / σ` costs exactly one task and at most one allocation per
//! block when it materializes. Expressions chain like NumPy:
//! `(w.transpose().norm(1) ** 2).sqrt()`.
//!
//! Sparse arrays keep the eager one-task-per-op path, which preserves the
//! CSR backend (and its zero-preserving-map check) block by block.

use anyhow::{bail, Result};

use crate::kernels::{BinaryKind, UnaryKind};
use crate::storage::BlockMeta;
use crate::tasking::{ops, BatchTask, CostHint, Future};

use super::DsArray;

impl DsArray {
    /// Eager unary elementwise map (one task per block, submitted as one
    /// batch): the sparse-array path, preserving the CSR backend. Dense
    /// arrays defer through `map_lazy` instead.
    pub(crate) fn map_blocks_eager(
        &self,
        name: &'static str,
        f: impl Fn(f32) -> f32 + Send + Sync + Clone + 'static,
    ) -> Result<DsArray> {
        if self.is_lazy() {
            return self.force()?.map_blocks_eager(name, f);
        }
        let mut batch = Vec::with_capacity(self.blocks.len());
        for i in 0..self.grid.0 {
            for j in 0..self.grid.1 {
                let fut = self.block(i, j);
                let meta = fut.meta;
                let hint = CostHint::flops((meta.rows * meta.cols) as f64)
                    .with_bytes(meta.bytes() as f64);
                batch.push(BatchTask::new(name, vec![fut], vec![meta], hint, ops::map_op(f.clone())));
            }
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(self.rt.clone(), self.shape, self.block_shape, blocks, self.sparse)
    }

    /// Generic binary elementwise op; shapes and block shapes must match.
    /// Dense pairs defer into one fused expression; pairs involving a
    /// sparse operand run eagerly (zip densifies either way).
    fn zip_blocks(&self, other: &DsArray, name: &'static str, op: BinaryKind) -> Result<DsArray> {
        if self.shape != other.shape {
            bail!("shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        }
        if self.block_shape != other.block_shape {
            bail!(
                "block shape mismatch: {:?} vs {:?} (rechunk first)",
                self.block_shape,
                other.block_shape
            );
        }
        if self.sparse || other.sparse {
            return self.zip_blocks_eager(other, name, move |a, b| op.apply(a, b));
        }
        let a = if self.view.is_some() { self.force()? } else { self.clone() };
        let b = if other.view.is_some() { other.force()? } else { other.clone() };
        a.zip_lazy(&b, op)
    }

    fn zip_blocks_eager(
        &self,
        other: &DsArray,
        name: &'static str,
        f: impl Fn(f32, f32) -> f32 + Send + Sync + Clone + 'static,
    ) -> Result<DsArray> {
        if self.is_lazy() || other.is_lazy() {
            return self.force()?.zip_blocks_eager(&other.force()?, name, f);
        }
        let mut batch = Vec::with_capacity(self.blocks.len());
        for i in 0..self.grid.0 {
            for j in 0..self.grid.1 {
                let a = self.block(i, j);
                let b = other.block(i, j);
                let meta = BlockMeta::dense(a.meta.rows, a.meta.cols);
                let hint = CostHint::flops((meta.rows * meta.cols) as f64)
                    .with_bytes(2.0 * meta.bytes() as f64);
                batch.push(BatchTask::new(name, vec![a, b], vec![meta], hint, ops::zip_op(f.clone())));
            }
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        // zip densifies (mixed backends fold to dense).
        DsArray::from_parts(self.rt.clone(), self.shape, self.block_shape, blocks, false)
    }

    pub fn add_scalar(&self, s: f32) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.add_scalar", UnaryKind::AddScalar(s))
    }

    pub fn mul_scalar(&self, s: f32) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.mul_scalar", UnaryKind::MulScalar(s))
    }

    /// Element-wise power — the paper's `A ** 2`.
    pub fn pow(&self, e: f32) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.pow", UnaryKind::Pow(e))
    }

    pub fn sqrt(&self) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.sqrt", UnaryKind::Sqrt)
    }

    pub fn abs(&self) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.abs", UnaryKind::Abs)
    }

    pub fn exp(&self) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.exp", UnaryKind::Exp)
    }

    pub fn neg(&self) -> Result<DsArray> {
        self.map_lazy("dsarray.ew.neg", UnaryKind::Neg)
    }

    pub fn add(&self, other: &DsArray) -> Result<DsArray> {
        self.zip_blocks(other, "dsarray.ew.add", BinaryKind::Add)
    }

    pub fn sub(&self, other: &DsArray) -> Result<DsArray> {
        self.zip_blocks(other, "dsarray.ew.sub", BinaryKind::Sub)
    }

    pub fn mul(&self, other: &DsArray) -> Result<DsArray> {
        self.zip_blocks(other, "dsarray.ew.mul", BinaryKind::Mul)
    }

    pub fn div(&self, other: &DsArray) -> Result<DsArray> {
        self.zip_blocks(other, "dsarray.ew.div", BinaryKind::Div)
    }

    /// dislib's `apply_along_axis` over axis 1: run an arbitrary
    /// row→scalar function (one task per block-row, full-width panels)
    /// producing a rows×1 ds-array. The closure must be pure — it runs on
    /// worker threads.
    pub fn apply_along_rows(
        &self,
        f: impl Fn(&[f32]) -> f32 + Send + Sync + Clone + 'static,
    ) -> Result<DsArray> {
        if self.is_lazy() {
            return self.force()?.apply_along_rows(f);
        }
        let mut batch = Vec::with_capacity(self.grid.0);
        for i in 0..self.grid.0 {
            let reads = self.block_row(i);
            let rows = self.block_rows_at(i);
            let bytes: f64 = reads.iter().map(|r| r.meta.bytes() as f64).sum();
            let f = f.clone();
            batch.push(BatchTask::new(
                "dsarray.apply_along_rows",
                reads,
                vec![BlockMeta::dense(rows, 1)],
                CostHint::flops((rows * self.shape.1) as f64).with_bytes(bytes),
                std::sync::Arc::new(move |ins: &[std::sync::Arc<crate::storage::Block>]| {
                    let dense: Vec<crate::storage::DenseMatrix> =
                        ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
                    let refs: Vec<&crate::storage::DenseMatrix> = dense.iter().collect();
                    let panel = crate::storage::DenseMatrix::hstack(&refs)?;
                    let mut out = crate::storage::DenseMatrix::zeros(panel.rows(), 1);
                    for r in 0..panel.rows() {
                        out.set(r, 0, f(panel.row(r)));
                    }
                    Ok(vec![crate::storage::Block::Dense(out)])
                }),
            ));
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(
            self.rt.clone(),
            (self.shape.0, 1),
            (self.block_shape.0, 1),
            blocks,
            false,
        )
    }

    /// Broadcast a 1×cols row array across all rows: `self - row` (used by
    /// the scaler / normalization pipelines).
    pub fn sub_row_broadcast(&self, row: &DsArray) -> Result<DsArray> {
        self.row_broadcast(row, BinaryKind::Sub)
    }

    /// Broadcast divide by a 1×cols row array (zero divisors yield 0).
    pub fn div_row_broadcast(&self, row: &DsArray) -> Result<DsArray> {
        self.row_broadcast(row, BinaryKind::DivOrZero)
    }

    /// Broadcast multiply by a 1×cols row array — with
    /// [`DsArray::sub_row_broadcast`] this is the fused standardize chain
    /// `(x − μ) · σ⁻¹`.
    pub fn mul_row_broadcast(&self, row: &DsArray) -> Result<DsArray> {
        self.row_broadcast(row, BinaryKind::Mul)
    }

    fn row_broadcast(&self, row: &DsArray, op: BinaryKind) -> Result<DsArray> {
        if row.shape.0 != 1 || row.shape.1 != self.shape.1 {
            bail!(
                "broadcast row must be 1x{}, got {:?}",
                self.shape.1,
                row.shape
            );
        }
        if row.block_shape.1 != self.block_shape.1 {
            bail!("broadcast row block width mismatch");
        }
        // Sparse operands are fine here: the fused evaluator densifies per
        // block, and broadcast output was always dense.
        let a = if self.view.is_some() { self.force()? } else { self.clone() };
        let r = if row.view.is_some() { row.force()? } else { row.clone() };
        a.bcast_lazy(&r, op)
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;

    fn setup() -> (Runtime, DenseMatrix, super::DsArray) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(5, 7, |i, j| (i as f32 - 2.0) * 0.5 + j as f32);
        let a = creation::from_matrix(&rt, &m, (2, 3)).unwrap();
        (rt, m, a)
    }

    #[test]
    fn scalar_ops_match_reference() {
        let (_rt, m, a) = setup();
        assert_eq!(a.add_scalar(2.5).unwrap().collect().unwrap(), m.map(|x| x + 2.5));
        assert_eq!(a.mul_scalar(-2.0).unwrap().collect().unwrap(), m.map(|x| x * -2.0));
        assert_eq!(a.pow(2.0).unwrap().collect().unwrap(), m.map(|x| x * x));
        assert_eq!(a.abs().unwrap().collect().unwrap(), m.map(|x| x.abs()));
        assert_eq!(a.neg().unwrap().collect().unwrap(), m.map(|x| -x));
    }

    #[test]
    fn chained_expression_like_paper() {
        // sqrt(A**2) == |A| — exercising NumPy-style chaining.
        let (_rt, m, a) = setup();
        let got = a.pow(2.0).unwrap().sqrt().unwrap().collect().unwrap();
        assert!(got.max_abs_diff(&m.map(|x| x.abs())) < 1e-5);
    }

    #[test]
    fn array_array_ops() {
        let (rt, m, a) = setup();
        let n = DenseMatrix::from_fn(5, 7, |i, j| (i + j) as f32 + 1.0);
        let b = creation::from_matrix(&rt, &n, (2, 3)).unwrap();
        assert_eq!(
            a.add(&b).unwrap().collect().unwrap(),
            m.zip_map(&n, |x, y| x + y).unwrap()
        );
        assert_eq!(
            a.mul(&b).unwrap().collect().unwrap(),
            m.zip_map(&n, |x, y| x * y).unwrap()
        );
        assert_eq!(
            a.sub(&b).unwrap().collect().unwrap(),
            m.zip_map(&n, |x, y| x - y).unwrap()
        );
        // Mismatched shapes rejected.
        let c = creation::zeros(&rt, (5, 6), (2, 3)).unwrap();
        assert!(a.add(&c).is_err());
        // Mismatched block shapes rejected.
        let d = creation::zeros(&rt, (5, 7), (3, 3)).unwrap();
        assert!(a.add(&d).is_err());
    }

    #[test]
    fn row_broadcast() {
        let (rt, m, a) = setup();
        let row = DenseMatrix::from_fn(1, 7, |_, j| j as f32);
        let r = creation::from_matrix(&rt, &row, (1, 3)).unwrap();
        let got = a.sub_row_broadcast(&r).unwrap().collect().unwrap();
        let want = DenseMatrix::from_fn(5, 7, |i, j| m.get(i, j) - row.get(0, j));
        assert_eq!(got, want);
        assert!(a.sub_row_broadcast(&a).is_err());
        // Multiply-broadcast (the standardize second stage).
        let got = a.mul_row_broadcast(&r).unwrap().collect().unwrap();
        let want = DenseMatrix::from_fn(5, 7, |i, j| m.get(i, j) * row.get(0, j));
        assert_eq!(got, want);
    }

    #[test]
    fn apply_along_rows_matches_reference() {
        let (rt, m, a) = setup();
        let norms = a
            .apply_along_rows(|row| row.iter().map(|&x| x * x).sum::<f32>().sqrt())
            .unwrap();
        assert_eq!(norms.shape(), (5, 1));
        let got = norms.collect().unwrap();
        for i in 0..5 {
            let want = m.row(i).iter().map(|&x| x * x).sum::<f32>().sqrt();
            assert!((got.get(i, 0) - want).abs() < 1e-4, "row {i}");
        }
        // One task per block-row.
        let before = rt.metrics();
        a.apply_along_rows(|row| row[0]).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dsarray.apply_along_rows"), a.grid().0 as u64);
    }

    #[test]
    fn lazy_chain_is_one_task_per_block() {
        // The acceptance criterion: a 3-op elementwise chain submits zero
        // tasks while deferred and exactly one fused task per block when
        // consumed.
        let (rt, m, a) = setup();
        let before = rt.metrics();
        let chain = a
            .add_scalar(1.0)
            .unwrap()
            .mul_scalar(2.0)
            .unwrap()
            .add_scalar(-0.5)
            .unwrap();
        assert!(chain.is_deferred());
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        let got = chain.collect().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.total_tasks(), a.n_blocks() as u64);
        assert_eq!(d.tasks_for("dsarray.ew.fused"), a.n_blocks() as u64);
        assert_eq!(got, m.map(|x| (x + 1.0) * 2.0 - 0.5));
    }

    #[test]
    fn sparse_maps_stay_eager_and_csr() {
        let rt = Runtime::local(2);
        let csr =
            crate::storage::CsrMatrix::from_triplets(4, 6, &[(0, 5, 2.0), (3, 2, -4.0)]).unwrap();
        let a = creation::from_csr(&rt, &csr, (2, 3)).unwrap();
        let before = rt.metrics();
        let doubled = a.mul_scalar(2.0).unwrap();
        // Eager: one task per block, CSR preserved.
        assert!(!doubled.is_deferred());
        assert!(doubled.is_sparse());
        assert_eq!(
            rt.metrics().since(&before).tasks_for("dsarray.ew.mul_scalar"),
            a.n_blocks() as u64
        );
        assert_eq!(
            doubled.collect_csr().unwrap().to_dense(),
            csr.to_dense().map(|x| x * 2.0)
        );
        // Non-zero-preserving maps on CSR are still rejected at run time.
        let bad = a.add_scalar(1.0).unwrap();
        assert!(bad.collect().is_err() || bad.runtime().barrier().is_err());
    }
}
