//! The distributed array (`ds-array`) — the paper's contribution (§4).
//!
//! A 2-D array divided in `P×Q` blocks stored behind runtime futures. The
//! grid is a row-major list of block handles (the paper's "list of lists of
//! blocks"); blocks are dense or CSR depending on the data. All operations
//! submit tasks and return new ds-arrays immediately (asynchronous
//! scheduling); `collect` synchronizes.
//!
//! Submodules implement the NumPy-like API surface:
//! [`creation`], [`indexing`], [`elementwise`], [`reductions`], [`linalg`]
//! (transpose/matmul), [`shuffle`], [`rechunk`], and [`io`] (parallel
//! partitioned file loaders/savers — one task per block-row, so arrays
//! larger than any single memory can be ingested) — see `docs/API.md` for
//! the full NumPy ↔ ds-array mapping table and `docs/IO.md` for the
//! out-of-core I/O model.
//!
//! Slicing and fancy indexing go through the zero-copy **view layer**:
//! `slice*`/`take_rows`/`take_cols` share block futures with the parent
//! instead of copying, and lazy views materialize via [`DsArray::force`]
//! only when an operation needs canonical blocks.

pub mod combine;
pub mod creation;
pub mod decomposition;
pub mod elementwise;
mod expr;
pub mod indexing;
pub mod io;
pub mod linalg;
pub mod operators;
pub mod rechunk;
pub mod reductions;
pub mod shuffle;
mod view;

use anyhow::{bail, Result};

use crate::storage::{CsrMatrix, DenseMatrix};
use crate::tasking::{Future, Runtime};

pub(crate) use expr::ExprSpec;
pub(crate) use view::{Sel, ViewSpec};

/// Distributed 2-D array divided in blocks (paper Fig 4).
///
/// A `DsArray` *owns* a handle reference on every block it holds:
/// construction and [`Clone`] retain, [`Drop`] releases. When the last
/// owner of a block is gone and every submitted reader has completed, the
/// runtime evicts the block's value (refcount reclamation — see the
/// `tasking` module docs), so pipelines that rebind intermediates keep a
/// bounded resident set.
pub struct DsArray {
    pub(crate) rt: Runtime,
    /// Logical shape (rows, cols).
    pub(crate) shape: (usize, usize),
    /// Regular block shape; edge blocks are smaller when the shape does not
    /// divide evenly (paper §4.2.2).
    pub(crate) block_shape: (usize, usize),
    /// Grid dimensions (block rows, block cols).
    pub(crate) grid: (usize, usize),
    /// Row-major grid of block futures. For lazy views this is the shared
    /// *backing* sub-grid; the `view` descriptor maps logical coordinates
    /// onto it.
    pub(crate) blocks: Vec<Future>,
    /// Whether blocks are CSR.
    pub(crate) sparse: bool,
    /// Lazy-view slice descriptor; `None` for canonical arrays (the view
    /// layer, `dsarray::view`).
    pub(crate) view: Option<ViewSpec>,
    /// Deferred elementwise expression; `None` for canonical arrays and
    /// views (the fusion engine, `dsarray::expr`). For expression arrays
    /// `blocks` is the base operand's grid; further operands live in the
    /// spec. `view` and `expr` are never both set.
    pub(crate) expr: Option<ExprSpec>,
    /// Pending deferred gemm with grafted epilogue (the plan layer,
    /// [`crate::plan::GemmSpec`] — only set at `Level::Full`). For deferred
    /// gemm arrays `blocks` is empty (the operand grids live in the spec)
    /// until [`DsArray::force`] lowers the plan. Mutually exclusive with
    /// `view` and `expr`.
    pub(crate) gemm: Option<crate::plan::GemmSpec>,
}

impl Clone for DsArray {
    fn clone(&self) -> Self {
        self.rt.retain(&self.blocks);
        if let Some(expr) = &self.expr {
            for op in &expr.extra {
                self.rt.retain(&op.blocks);
            }
        }
        if let Some(g) = &self.gemm {
            self.rt.retain(&g.a);
            self.rt.retain(&g.b);
        }
        Self {
            rt: self.rt.clone(),
            shape: self.shape,
            block_shape: self.block_shape,
            grid: self.grid,
            blocks: self.blocks.clone(),
            sparse: self.sparse,
            view: self.view.clone(),
            expr: self.expr.clone(),
            gemm: self.gemm.clone(),
        }
    }
}

impl Drop for DsArray {
    fn drop(&mut self) {
        if let Some(expr) = &self.expr {
            {
                let mut st = expr.state.lock().unwrap();
                if st.release_credit {
                    // force() already released one owner's references
                    // early; this drop consumes the credit.
                    st.release_credit = false;
                    return;
                }
            }
            for op in &expr.extra {
                self.rt.release(&op.blocks);
            }
        }
        if let Some(g) = &self.gemm {
            {
                let mut st = g.state.lock().unwrap();
                if st.release_credit {
                    // force() pre-released one owner's operand references
                    // inside the submission critical section; this drop
                    // consumes the credit (`blocks` is empty for deferred
                    // gemm arrays, so returning skips nothing else).
                    st.release_credit = false;
                    return;
                }
            }
            self.rt.release(&g.a);
            self.rt.release(&g.b);
        }
        self.rt.release(&self.blocks);
    }
}

impl DsArray {
    /// Logical shape `(rows, cols)` — for views, the shape of the selected
    /// region, not of the backing blocks.
    pub fn shape(&self) -> (usize, usize) {
        self.shape
    }
    pub fn rows(&self) -> usize {
        self.shape.0
    }
    pub fn cols(&self) -> usize {
        self.shape.1
    }
    /// Regular block shape; edge blocks are smaller when the shape does not
    /// divide evenly.
    pub fn block_shape(&self) -> (usize, usize) {
        self.block_shape
    }
    /// (block rows, block cols) of the grid. For lazy views this is the
    /// *backing* grid the view maps into; [`DsArray::force`] yields the
    /// canonical grid of the selected region.
    pub fn grid(&self) -> (usize, usize) {
        self.grid
    }
    pub fn n_blocks(&self) -> usize {
        self.grid.0 * self.grid.1
    }
    pub fn is_sparse(&self) -> bool {
        self.sparse
    }
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Pin every block of this array: exempt from refcount reclamation even
    /// after all owners drop (e.g. source data re-read via bare futures).
    /// On a lazy view or deferred expression this pins the *backing/base*
    /// blocks (which also disables in-place execution over them); force
    /// first to pin the materialized result.
    pub fn pin(&self) {
        for &b in &self.blocks {
            self.rt.pin(b);
        }
    }

    /// Grid size for a logical size and block size.
    pub(crate) fn grid_dim(total: usize, block: usize) -> usize {
        total.div_ceil(block)
    }

    /// Logical row count of block-row `i` (edge rows are smaller). On views
    /// this describes the *materialized* grid [`DsArray::force`] would
    /// produce — which can be smaller than the backing [`DsArray::grid`];
    /// backing lines beyond it hold no materialized rows and return 0.
    pub fn block_rows_at(&self, i: usize) -> usize {
        debug_assert!(i < self.grid.0.max(Self::grid_dim(self.shape.0, self.block_shape.0)));
        self.shape
            .0
            .saturating_sub(i * self.block_shape.0)
            .min(self.block_shape.0)
    }

    /// Logical col count of block-col `j` (see [`DsArray::block_rows_at`]).
    pub fn block_cols_at(&self, j: usize) -> usize {
        debug_assert!(j < self.grid.1.max(Self::grid_dim(self.shape.1, self.block_shape.1)));
        self.shape
            .1
            .saturating_sub(j * self.block_shape.1)
            .min(self.block_shape.1)
    }

    /// Future of the block at grid position (i, j). For lazy views this
    /// addresses the shared *backing* grid (the view's mapping is not
    /// applied), and for deferred elementwise expressions it addresses the
    /// raw **un-evaluated base operand**; force first when canonical
    /// (computed) blocks are needed. Internal consumers and the estimators
    /// all force at entry.
    pub fn block(&self, i: usize, j: usize) -> Future {
        debug_assert!(i < self.grid.0 && j < self.grid.1);
        self.blocks[i * self.grid.1 + j]
    }

    /// All futures of block-row `i`, left to right.
    pub fn block_row(&self, i: usize) -> Vec<Future> {
        (0..self.grid.1).map(|j| self.block(i, j)).collect()
    }

    /// All futures of block-col `j`, top to bottom.
    pub fn block_col(&self, j: usize) -> Vec<Future> {
        (0..self.grid.0).map(|i| self.block(i, j)).collect()
    }

    /// Render the plan that forcing/collecting this array would execute,
    /// as seen by the query optimizer — the `EXPLAIN` of the plan layer.
    ///
    /// One line of array geometry, the active optimizer [`crate::plan::Level`],
    /// then the pending work: a deferred gemm (with any grafted elementwise
    /// epilogue), a deferred elementwise chain, a lazy view gather, or
    /// "materialized" when no tasks are pending. Purely diagnostic: calling
    /// it never submits tasks or changes the plan.
    pub fn explain(&self) -> String {
        let mut s = format!(
            "DsArray {}x{} · blocks {}x{} · grid {}x{}{}\n",
            self.shape.0,
            self.shape.1,
            self.block_shape.0,
            self.block_shape.1,
            self.grid.0,
            self.grid.1,
            if self.sparse { " · sparse" } else { "" },
        );
        s.push_str(&format!(
            "optimizer: {}\n",
            self.rt.planner().level().as_str()
        ));
        if let Some(g) = &self.gemm {
            let forced = g
                .state
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .forced
                .is_some();
            s.push_str(&format!("plan: {}\n", g.describe()));
            if forced {
                s.push_str("  already forced — memoized result is reused\n");
            } else {
                s.push_str(&format!(
                    "  lowers to {} `{}` task(s); operand blocks pre-release at force\n",
                    g.n_tasks(),
                    g.task_name(),
                ));
            }
        } else if let Some(expr) = &self.expr {
            s.push_str(&format!(
                "plan: deferred elementwise chain, {} op(s) over {} operand grid(s)\n",
                expr.n_ops,
                1 + expr.extra.len(),
            ));
            s.push_str(&format!(
                "  lowers to {} fused `dsarray.ew.fused` task(s) (one per block)\n",
                self.grid.0 * self.grid.1,
            ));
        } else if self.view.is_some() {
            // A view's `grid` is the shared backing sub-grid; force()
            // gathers into the logical output grid.
            let out_grid = Self::grid_dim(self.shape.0, self.block_shape.0)
                * Self::grid_dim(self.shape.1, self.block_shape.1);
            s.push_str(&format!(
                "plan: lazy view over shared backing blocks\n  lowers to {out_grid} gather task(s) if forced; collect() copies master-side with zero tasks\n",
            ));
        } else {
            s.push_str("plan: materialized — no pending tasks\n");
        }
        s
    }

    /// Assemble a ds-array from an explicit grid of futures. Validates that
    /// every block's metadata matches its grid slot.
    pub(crate) fn from_parts(
        rt: Runtime,
        shape: (usize, usize),
        block_shape: (usize, usize),
        blocks: Vec<Future>,
        sparse: bool,
    ) -> Result<Self> {
        let grid = (
            Self::grid_dim(shape.0, block_shape.0),
            Self::grid_dim(shape.1, block_shape.1),
        );
        if blocks.len() != grid.0 * grid.1 {
            bail!(
                "block count {} != grid {}x{}",
                blocks.len(),
                grid.0,
                grid.1
            );
        }
        // Take ownership of a handle reference per block. If validation
        // below bails, `arr` is dropped and releases them — balanced.
        rt.retain(&blocks);
        let arr = Self {
            rt,
            shape,
            block_shape,
            grid,
            blocks,
            sparse,
            view: None,
            expr: None,
            gemm: None,
        };
        for i in 0..grid.0 {
            for j in 0..grid.1 {
                let m = arr.block(i, j).meta;
                let (er, ec) = (arr.block_rows_at(i), arr.block_cols_at(j));
                if (m.rows, m.cols) != (er, ec) {
                    bail!(
                        "block ({i},{j}) meta {}x{} != expected {er}x{ec}",
                        m.rows,
                        m.cols
                    );
                }
            }
        }
        Ok(arr)
    }

    /// Synchronize every block and assemble the full dense matrix — the
    /// paper's `collect` (local mode only).
    ///
    /// Lazy views collect **without submitting tasks**: only the backing
    /// blocks the view touches are synchronized, and the slice mapping is
    /// applied while copying master-side. Deferred elementwise expressions
    /// materialize first (one fused task per block, memoized — see
    /// [`DsArray::force`]).
    pub fn collect(&self) -> Result<DenseMatrix> {
        // A collect delimits an optimizer epoch: stale CSE memo entries
        // from distant epochs are swept (no-op at `Level::Off`).
        self.rt.plan_epoch_tick();
        if self.expr.is_some() || self.gemm.is_some() {
            return self.force()?.collect();
        }
        let Some(view) = &self.view else {
            let mut out = DenseMatrix::zeros(self.shape.0, self.shape.1);
            for i in 0..self.grid.0 {
                for j in 0..self.grid.1 {
                    let b = self.rt.wait(self.block(i, j))?;
                    let d = b.to_dense()?;
                    out.paste(i * self.block_shape.0, j * self.block_shape.1, &d)?;
                }
            }
            return Ok(out);
        };
        let (nr, nc) = self.shape;
        let (bs0, bs1) = self.block_shape;
        // Synchronize only the touched backing blocks, densified up front.
        let (rlines, clines) = self.touched_lines();
        let mut dense: Vec<Option<DenseMatrix>> = self.blocks.iter().map(|_| None).collect();
        for &bi in &rlines {
            for &bj in &clines {
                let b = self.rt.wait(self.block(bi, bj))?;
                dense[bi * self.grid.1 + bj] = Some(b.to_dense()?);
            }
        }
        let mut out = DenseMatrix::zeros(nr, nc);
        for k in 0..nr {
            let sr = view.map_row(k);
            let (bi, lr) = (sr / bs0, sr % bs0);
            match &view.col_index {
                // Contiguous column window: copy row segments per block-col.
                None => {
                    let mut written = 0;
                    while written < nc {
                        let sc = view.col_off + written;
                        let (bj, lc) = (sc / bs1, sc % bs1);
                        let d = dense[bi * self.grid.1 + bj]
                            .as_ref()
                            .expect("touched backing block fetched");
                        let take = (d.cols() - lc).min(nc - written);
                        out.row_mut(k)[written..written + take]
                            .copy_from_slice(&d.row(lr)[lc..lc + take]);
                        written += take;
                    }
                }
                // Fancy columns: per-element copy through the index map.
                Some(cidx) => {
                    for (jj, &sc) in cidx.iter().enumerate() {
                        let (bj, lc) = (sc / bs1, sc % bs1);
                        let d = dense[bi * self.grid.1 + bj]
                            .as_ref()
                            .expect("touched backing block fetched");
                        out.set(k, jj, d.get(lr, lc));
                    }
                }
            }
        }
        Ok(out)
    }

    /// Synchronize and assemble as CSR (errors if the array is dense-backed).
    /// Lazy views are materialized first (this submits the view's copy
    /// tasks); `collect` stays task-free if dense output is acceptable.
    pub fn collect_csr(&self) -> Result<CsrMatrix> {
        if self.is_lazy() {
            return self.force()?.collect_csr();
        }
        if !self.sparse {
            bail!("collect_csr on a dense-backed ds-array");
        }
        let mut row_panels: Vec<CsrMatrix> = Vec::with_capacity(self.grid.0);
        for i in 0..self.grid.0 {
            let mut row_parts: Vec<CsrMatrix> = Vec::with_capacity(self.grid.1);
            for j in 0..self.grid.1 {
                let b = self.rt.wait(self.block(i, j))?;
                row_parts.push(b.as_csr()?.clone());
            }
            let refs: Vec<&CsrMatrix> = row_parts.iter().collect();
            row_panels.push(CsrMatrix::hstack(&refs)?);
        }
        let refs: Vec<&CsrMatrix> = row_panels.iter().collect();
        CsrMatrix::vstack(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::BlockMeta;
    use crate::tasking::Runtime;

    #[test]
    fn grid_geometry_with_edge_blocks() {
        let rt = Runtime::local(2);
        let a = creation::zeros(&rt, (10, 7), (4, 3)).unwrap();
        assert_eq!(a.grid(), (3, 3));
        assert_eq!(a.block_rows_at(0), 4);
        assert_eq!(a.block_rows_at(2), 2); // 10 = 4+4+2
        assert_eq!(a.block_cols_at(2), 1); // 7 = 3+3+1
        assert_eq!(a.block(2, 2).meta, BlockMeta::dense(2, 1));
    }

    #[test]
    fn collect_assembles_blocks_in_order() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(5, 6, |i, j| (i * 6 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (2, 4)).unwrap();
        assert_eq!(a.grid(), (3, 2));
        assert_eq!(a.collect().unwrap(), m);
    }

    #[test]
    fn from_parts_validates_geometry() {
        let rt = Runtime::local(1);
        let a = creation::zeros(&rt, (4, 4), (2, 2)).unwrap();
        // Wrong number of blocks.
        let r = DsArray::from_parts(rt.clone(), (4, 4), (2, 2), a.blocks[..3].to_vec(), false);
        assert!(r.is_err());
        // Blocks in the wrong slots (transposed grid of a non-square array).
        let b = creation::zeros(&rt, (4, 2), (2, 1)).unwrap();
        let r = DsArray::from_parts(rt, (2, 4), (1, 2), b.blocks.clone(), false);
        assert!(r.is_err());
    }

    #[test]
    fn consumed_intermediates_are_reclaimed() {
        // A rebinding pipeline: with the fused expression engine, the six
        // chained ops never materialize intermediate generations at all —
        // one fused task per block reads the (dead) source generation,
        // which is granted in place and reclaimed.
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(32, 32, |i, j| (i + j) as f32);
        let mut cur = creation::from_matrix(&rt, &m, (8, 8)).unwrap();
        for _ in 0..6 {
            cur = cur.add_scalar(1.0).unwrap();
        }
        let got = cur.collect().unwrap();
        assert_eq!(got, m.map(|x| x + 6.0));
        rt.barrier().unwrap();
        let met = rt.metrics();
        // One fused task per block, 5 per-block submissions fused away.
        assert_eq!(met.tasks_for("dsarray.ew.fused"), 16);
        assert_eq!(met.tasks_fused, 5 * 16);
        // The dead source generation executes in place: all 16 blocks
        // granted and reclaimed, and no fresh output bytes allocated.
        assert_eq!(met.inplace_hits, 16, "source generation granted in place");
        assert!(met.blocks_evicted >= 16, "evicted {}", met.blocks_evicted);
        assert_eq!(met.bytes_allocated, 0);
        // Where the eager pipeline produced 7 generations, the fused one
        // keeps at most ~one generation resident.
        let gen_bytes = 32 * 32 * 4; // 16 blocks x 8x8 f32
        assert!(
            met.peak_resident_bytes <= 2 * gen_bytes as u64,
            "peak {} not bounded",
            met.peak_resident_bytes
        );
        assert!(met.peak_resident_bytes >= gen_bytes as u64);
    }

    #[test]
    fn pinned_blocks_survive_owner_drop() {
        let rt = Runtime::local(1);
        let m = DenseMatrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (2, 2)).unwrap();
        let keep = a.block(0, 0);
        rt.pin(keep);
        let lost = a.block(1, 1);
        let b = a.add_scalar(1.0).unwrap();
        drop(a);
        b.collect().unwrap();
        rt.barrier().unwrap();
        // The pinned block survived its owner; the unpinned one was
        // reclaimed once its reader completed.
        assert!(rt.wait(keep).is_ok());
        assert!(rt.wait(lost).is_err());
        assert!(rt.metrics().blocks_evicted >= 1);
    }
}
