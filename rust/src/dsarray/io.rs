//! Parallel partitioned file I/O for ds-arrays — out-of-core ingestion and
//! write-back (paper §4.2.2, "files are read in parallel by the workers").
//!
//! Every loader here submits **one `dsarray.io.load_*` task per block-row**
//! through the executor; the master's only work is a streaming byte scan
//! ([`crate::storage::io::partition_lines`]) or an NPY header read — it
//! never materializes the matrix, so master-side peak residency during a
//! load stays below one block-row regardless of file size. Combined with a
//! runtime memory budget ([`crate::tasking::Runtime::local_with_budget`]),
//! this is what lets an array larger than RAM be ingested, transformed and
//! fitted end to end.
//!
//! Three formats, each with a symmetric parallel saver:
//!
//! | format    | load                                  | save                         |
//! |-----------|---------------------------------------|------------------------------|
//! | CSV       | [`load_csv`] (byte-range split) / [`load_csv_parts`] (one file per block-row) | [`save_csv_parts`] |
//! | SVMLight  | [`load_svmlight`] → (CSR features, labels) | [`save_svmlight_parts`] |
//! | NPY       | [`load_npy`] (exact binary ranges)    | [`save_npy`] (single pre-sized file, parallel range writes) |
//!
//! See `docs/IO.md` for the partitioned-format rules and runnable examples.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::storage::io::{
    self, partition_lines, probe_csv_cols, read_csv_range, read_npy_header, read_npy_rows,
    read_svmlight_range, LinePartition,
};
use crate::storage::{Block, BlockMeta, CsrMatrix, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future, Runtime};

use super::DsArray;

fn validate_block_shape(block_shape: (usize, usize)) -> Result<()> {
    if block_shape.0 == 0 || block_shape.1 == 0 {
        bail!("empty block shape {block_shape:?}");
    }
    Ok(())
}

/// Column-block widths of a row of `cols` logical columns under `bs1`.
fn col_blocks(cols: usize, bs1: usize) -> Vec<usize> {
    (0..DsArray::grid_dim(cols, bs1))
        .map(|j| (cols - j * bs1).min(bs1))
        .collect()
}

/// Split a dense row panel into its column blocks.
fn split_dense_panel(panel: &DenseMatrix, bs1: usize) -> Result<Vec<Block>> {
    let mut outs = Vec::new();
    let mut c0 = 0;
    while c0 < panel.cols() {
        let c = (panel.cols() - c0).min(bs1);
        outs.push(Block::Dense(panel.slice(0, c0, panel.rows(), c)?));
        c0 += c;
    }
    Ok(outs)
}

/// Load a delimiter-separated text file as a dense ds-array, in parallel.
///
/// The master streams the file once to find block-row line boundaries
/// (byte offsets, O(1) memory — the shape is *inferred*, not declared),
/// then submits one `dsarray.io.load_csv` task per block-row; each task
/// seeks to its byte range and parses only its own lines. Ingestion
/// parallelism therefore equals the block-row count, and no process ever
/// holds more than one block-row of parsed data.
///
/// If `path` is a directory, this delegates to [`load_csv_parts`] (one
/// partition file per block-row; `block_shape.0` is then taken from the
/// partition files themselves).
pub fn load_csv(
    rt: &Runtime,
    path: &Path,
    block_shape: (usize, usize),
    delimiter: char,
) -> Result<DsArray> {
    validate_block_shape(block_shape)?;
    if path.is_dir() {
        return load_csv_parts(rt, path, block_shape.1, delimiter);
    }
    let parts = partition_lines(path, block_shape.0)?;
    let rows: usize = parts.iter().map(|p| p.rows).sum();
    let cols = probe_csv_cols(path, delimiter)?;
    if rows == 0 || cols == 0 {
        bail!("{}: no data rows to load", path.display());
    }
    let widths = col_blocks(cols, block_shape.1);
    let mut batch = Vec::with_capacity(parts.len());
    for part in &parts {
        batch.push(load_csv_task(
            path.to_path_buf(),
            *part,
            cols,
            &widths,
            delimiter,
        ));
    }
    let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().flatten().collect();
    DsArray::from_parts(rt.clone(), (rows, cols), block_shape, blocks, false)
}

fn load_csv_task(
    path: PathBuf,
    part: LinePartition,
    cols: usize,
    widths: &[usize],
    delimiter: char,
) -> BatchTask {
    let metas: Vec<BlockMeta> = widths.iter().map(|&c| BlockMeta::dense(part.rows, c)).collect();
    let panel_bytes: f64 = metas.iter().map(|m| m.bytes() as f64).sum();
    let bs1 = widths[0];
    BatchTask::new(
        "dsarray.io.load_csv",
        Vec::new(),
        metas,
        CostHint::data_movement().with_bytes(panel_bytes * 2.0), // read + parse
        Arc::new(move |_| {
            let panel =
                read_csv_range(&path, part.offset, part.rows, delimiter, cols, part.lineno)?;
            split_dense_panel(&panel, bs1)
        }),
    )
}

/// Load a partition directory — **one file per block-row**, ordered by
/// file name — as a dense ds-array. All partition files must hold the same
/// number of data rows except the last (shorter is fine); that common row
/// count becomes `block_shape.0`. One `dsarray.io.load_csv` task per file.
pub fn load_csv_parts(
    rt: &Runtime,
    dir: &Path,
    block_cols: usize,
    delimiter: char,
) -> Result<DsArray> {
    if block_cols == 0 {
        bail!("empty block width");
    }
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading partition directory {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_file())
        // Editor droppings and OS metadata (.DS_Store, .gitignore, …) are
        // not partitions.
        .filter(|p| !matches!(p.file_name().and_then(|n| n.to_str()), Some(n) if n.starts_with('.')))
        .collect();
    // When the directory holds a `save_csv_parts` layout, read exactly
    // that: other formats saved alongside (part-*.svm) or stray files must
    // not be concatenated in as CSV rows. Arbitrary user-named partition
    // files still work in directories without `part-*.csv` entries.
    let canonical: Vec<PathBuf> = files
        .iter()
        .filter(|p| {
            matches!(p.file_name().and_then(|n| n.to_str()),
                     Some(n) if n.starts_with("part-") && n.ends_with(".csv"))
        })
        .cloned()
        .collect();
    if !canonical.is_empty() {
        files = canonical;
    }
    files.sort();
    if files.is_empty() {
        bail!("{}: empty partition directory", dir.display());
    }
    // One streaming scan per file: row count + first-data-line offset.
    let mut parts: Vec<(PathBuf, LinePartition)> = Vec::with_capacity(files.len());
    for f in files {
        let mut ps = partition_lines(&f, usize::MAX)?;
        match ps.pop() {
            Some(p) => parts.push((f, p)),
            None => bail!("{}: partition file holds no data rows", f.display()),
        }
    }
    let bs0 = parts[0].1.rows;
    for (i, (f, p)) in parts.iter().enumerate() {
        let last = i + 1 == parts.len();
        if (!last && p.rows != bs0) || (last && p.rows > bs0) {
            bail!(
                "{}: partition file has {} rows, expected {} (only the last may be shorter)",
                f.display(),
                p.rows,
                bs0
            );
        }
    }
    let cols = probe_csv_cols(&parts[0].0, delimiter)?;
    if cols == 0 {
        bail!("{}: no columns in first partition", parts[0].0.display());
    }
    let rows: usize = parts.iter().map(|(_, p)| p.rows).sum();
    let widths = col_blocks(cols, block_cols);
    let batch: Vec<BatchTask> = parts
        .into_iter()
        .map(|(f, p)| load_csv_task(f, p, cols, &widths, delimiter))
        .collect();
    let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().flatten().collect();
    DsArray::from_parts(rt.clone(), (rows, cols), (bs0, block_cols), blocks, false)
}

/// Partition file name of block-row `i` (shared by the `save_*_parts`
/// writers and readable back by the `load_*_parts` loaders, which sort by
/// name).
fn part_name(i: usize, ext: &str) -> String {
    format!("part-{i:05}.{ext}")
}

/// Remove every existing `part-*.{ext}` file from `dir` before a
/// partitioned save: a previous, larger save into the same directory must
/// not leave stale partitions behind for a reload to silently pick up.
fn clear_stale_parts(dir: &Path, ext: &str) -> Result<()> {
    let suffix = format!(".{ext}");
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        let Some(name) = p.file_name().and_then(|n| n.to_str()) else { continue };
        if name.starts_with("part-") && name.ends_with(&suffix) {
            std::fs::remove_file(&p)
                .with_context(|| format!("removing stale partition {}", p.display()))?;
        }
    }
    Ok(())
}

/// Write a ds-array as a partition directory of CSV files — one
/// `dsarray.io.save_csv` task (and one `part-NNNNN.csv` file) per
/// block-row, the symmetric write-back of [`load_csv_parts`]. Blocks are
/// synchronized *inside* the tasks, so write parallelism equals the
/// block-row count and the master materializes nothing. Blocks until every
/// partition is on disk.
pub fn save_csv_parts(arr: &DsArray, dir: &Path, delimiter: char) -> Result<()> {
    let arr = arr.force()?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating partition directory {}", dir.display()))?;
    clear_stale_parts(dir, "csv")?;
    let rt = arr.runtime().clone();
    let mut batch = Vec::with_capacity(arr.grid().0);
    for i in 0..arr.grid().0 {
        let reads = arr.block_row(i);
        let bytes: f64 = reads.iter().map(|f| f.meta.bytes() as f64).sum();
        let out = dir.join(part_name(i, "csv"));
        batch.push(BatchTask::new(
            "dsarray.io.save_csv",
            reads,
            Vec::new(),
            CostHint::data_movement().with_bytes(bytes * 2.0),
            Arc::new(move |ins: &[Arc<Block>]| {
                let dense: Vec<DenseMatrix> =
                    ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
                let refs: Vec<&DenseMatrix> = dense.iter().collect();
                io::write_csv(&out, &DenseMatrix::hstack(&refs)?, delimiter)?;
                Ok(Vec::new())
            }),
        ));
    }
    rt.submit_batch(batch);
    rt.barrier()
}

/// Load an SVMLight file (`label idx:val ...`, 1-based indices) in
/// parallel: one `dsarray.io.load_svmlight` task per block-row, each
/// parsing only its byte range. Returns `(samples, labels)` — samples as a
/// CSR-blocked sparse ds-array of width `n_features`, labels as an `n×1`
/// dense ds-array with the same row blocking. Out-of-range feature indices
/// are line-numbered errors.
pub fn load_svmlight(
    rt: &Runtime,
    path: &Path,
    n_features: usize,
    block_shape: (usize, usize),
) -> Result<(DsArray, DsArray)> {
    validate_block_shape(block_shape)?;
    if n_features == 0 {
        bail!("n_features must be positive");
    }
    let parts = partition_lines(path, block_shape.0)?;
    let rows: usize = parts.iter().map(|p| p.rows).sum();
    if rows == 0 {
        bail!("{}: no data rows to load", path.display());
    }
    let file_len = std::fs::metadata(path)?.len();
    let widths = col_blocks(n_features, block_shape.1);
    let bs1 = block_shape.1;
    let mut batch = Vec::with_capacity(parts.len());
    for (i, part) in parts.iter().enumerate() {
        let range_bytes = match parts.get(i + 1) {
            Some(next) => next.offset - part.offset,
            None => file_len.saturating_sub(part.offset),
        };
        // ~12 text bytes per stored feature — only an accounting estimate;
        // the true nnz is known when the task completes.
        let est_nnz = (range_bytes as usize / 12).max(1);
        let mut metas: Vec<BlockMeta> = widths
            .iter()
            .map(|&c| BlockMeta::sparse(part.rows, c, (est_nnz * c / n_features).max(1)))
            .collect();
        metas.push(BlockMeta::dense(part.rows, 1)); // labels
        let path = path.to_path_buf();
        let (part, nf) = (*part, n_features);
        batch.push(BatchTask::new(
            "dsarray.io.load_svmlight",
            Vec::new(),
            metas,
            CostHint::data_movement().with_bytes(range_bytes as f64 * 2.0),
            Arc::new(move |_| {
                let (panel, labels) =
                    read_svmlight_range(&path, part.offset, part.rows, nf, part.lineno)?;
                let mut outs = Vec::new();
                let mut c0 = 0;
                while c0 < nf {
                    let c = (nf - c0).min(bs1);
                    outs.push(Block::Csr(panel.slice(0, c0, part.rows, c)?));
                    c0 += c;
                }
                outs.push(Block::Dense(labels));
                Ok(outs)
            }),
        ));
    }
    let per_task = rt.submit_batch(batch);
    let mut feat_blocks = Vec::with_capacity(parts.len() * widths.len());
    let mut label_blocks = Vec::with_capacity(parts.len());
    for mut outs in per_task {
        label_blocks.push(outs.pop().expect("labels block declared last"));
        feat_blocks.extend(outs);
    }
    let samples = DsArray::from_parts(
        rt.clone(),
        (rows, n_features),
        block_shape,
        feat_blocks,
        true,
    )?;
    let labels = DsArray::from_parts(rt.clone(), (rows, 1), (block_shape.0, 1), label_blocks, false)?;
    Ok((samples, labels))
}

/// Write `(samples, labels)` as a partition directory of SVMLight files —
/// one `dsarray.io.save_svmlight` task per block-row, symmetric with
/// [`load_svmlight`] (load the directory back file by file, or
/// concatenate). Dense sample blocks are sparsified (exact zeros dropped).
/// Blocks until every partition is on disk.
pub fn save_svmlight_parts(samples: &DsArray, labels: &DsArray, dir: &Path) -> Result<()> {
    if labels.rows() != samples.rows() || labels.cols() != 1 {
        bail!(
            "labels must be {}x1, got {}x{}",
            samples.rows(),
            labels.rows(),
            labels.cols()
        );
    }
    if labels.block_shape().0 != samples.block_shape().0 {
        bail!(
            "labels row blocking {} != samples row blocking {}",
            labels.block_shape().0,
            samples.block_shape().0
        );
    }
    let samples = samples.force()?;
    let labels = labels.force()?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating partition directory {}", dir.display()))?;
    clear_stale_parts(dir, "svm")?;
    let rt = samples.runtime().clone();
    let mut batch = Vec::with_capacity(samples.grid().0);
    for i in 0..samples.grid().0 {
        let mut reads = samples.block_row(i);
        let gc = reads.len();
        reads.push(labels.block(i, 0));
        let bytes: f64 = reads.iter().map(|f| f.meta.bytes() as f64).sum();
        let out = dir.join(part_name(i, "svm"));
        batch.push(BatchTask::new(
            "dsarray.io.save_svmlight",
            reads,
            Vec::new(),
            CostHint::data_movement().with_bytes(bytes * 2.0),
            Arc::new(move |ins: &[Arc<Block>]| {
                let csrs: Vec<CsrMatrix> = ins[..gc]
                    .iter()
                    .map(|b| match &**b {
                        Block::Csr(m) => Ok(m.clone()),
                        Block::Dense(m) => Ok(CsrMatrix::from_dense(m, 0.0)),
                        Block::Phantom(_) => bail!("cannot save phantom blocks"),
                    })
                    .collect::<Result<_>>()?;
                let refs: Vec<&CsrMatrix> = csrs.iter().collect();
                let panel = CsrMatrix::hstack(&refs)?;
                io::write_svmlight(&out, &panel, &ins[gc].to_dense()?)?;
                Ok(Vec::new())
            }),
        ));
    }
    rt.submit_batch(batch);
    rt.barrier()
}

/// Load a `.npy` file (C-order `<f4`/`<f8`) as a dense ds-array. The fixed
/// row stride makes the split *exact*: the master reads only the header,
/// and each of the one-per-block-row `dsarray.io.load_npy` tasks seeks
/// straight to its byte range — no line scan at all.
pub fn load_npy(rt: &Runtime, path: &Path, block_shape: (usize, usize)) -> Result<DsArray> {
    validate_block_shape(block_shape)?;
    let h = read_npy_header(path)?;
    if h.rows == 0 || h.cols == 0 {
        bail!("{}: empty npy array", path.display());
    }
    let grid_rows = DsArray::grid_dim(h.rows, block_shape.0);
    let bs1 = block_shape.1;
    let mut batch = Vec::with_capacity(grid_rows);
    for i in 0..grid_rows {
        let r0 = i * block_shape.0;
        let r = (h.rows - r0).min(block_shape.0);
        let metas: Vec<BlockMeta> = col_blocks(h.cols, bs1)
            .into_iter()
            .map(|c| BlockMeta::dense(r, c))
            .collect();
        let panel_bytes: f64 = metas.iter().map(|m| m.bytes() as f64).sum();
        let path = path.to_path_buf();
        batch.push(BatchTask::new(
            "dsarray.io.load_npy",
            Vec::new(),
            metas,
            CostHint::data_movement().with_bytes(panel_bytes * 2.0),
            Arc::new(move |_| {
                let panel = read_npy_rows(&path, &h, r0, r)?;
                split_dense_panel(&panel, bs1)
            }),
        ));
    }
    let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().flatten().collect();
    DsArray::from_parts(rt.clone(), (h.rows, h.cols), block_shape, blocks, false)
}

/// Write a ds-array as a single `.npy` file with **parallel range writes**:
/// the master writes the header and pre-sizes the file; one
/// `dsarray.io.save_npy` task per block-row then fills its disjoint row
/// range in place. Blocks until the file is complete.
pub fn save_npy(arr: &DsArray, path: &Path) -> Result<()> {
    let arr = arr.force()?;
    let (rows, cols) = arr.shape();
    let data_offset = io::create_npy(path, rows, cols)?;
    let rt = arr.runtime().clone();
    let mut batch = Vec::with_capacity(arr.grid().0);
    for i in 0..arr.grid().0 {
        let reads = arr.block_row(i);
        let bytes: f64 = reads.iter().map(|f| f.meta.bytes() as f64).sum();
        let r0 = i * arr.block_shape().0;
        let path = path.to_path_buf();
        batch.push(BatchTask::new(
            "dsarray.io.save_npy",
            reads,
            Vec::new(),
            CostHint::data_movement().with_bytes(bytes * 2.0),
            Arc::new(move |ins: &[Arc<Block>]| {
                let dense: Vec<DenseMatrix> =
                    ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
                let refs: Vec<&DenseMatrix> = dense.iter().collect();
                io::write_npy_rows_at(&path, data_offset, rows, cols, r0, &DenseMatrix::hstack(&refs)?)?;
                Ok(Vec::new())
            }),
        ));
    }
    rt.submit_batch(batch);
    rt.barrier()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::storage::io::{read_csv, read_npy, read_svmlight, write_csv, write_svmlight};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rustdslib_dsio_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn parallel_load_csv_matches_serial_read() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(13, 7, |i, j| (i * 7 + j) as f32 * 0.25 - 3.0);
        let p = tmp("par.csv");
        write_csv(&p, &m, ',').unwrap();
        let a = load_csv(&rt, &p, (4, 3), ',').unwrap();
        assert_eq!(a.shape(), (13, 7));
        assert_eq!(a.grid(), (4, 3));
        // Parity: parallel ingestion equals master-side read + scatter.
        let b = creation::from_matrix(&rt, &read_csv(&p, ',').unwrap(), (4, 3)).unwrap();
        assert_eq!(a.collect().unwrap(), b.collect().unwrap());
        // One load task per block-row.
        assert_eq!(rt.metrics().tasks_for("dsarray.io.load_csv"), 4);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_csv_handles_comments_and_missing_trailing_newline() {
        let rt = Runtime::local(2);
        let p = tmp("cmt.csv");
        std::fs::write(&p, "# head\n1,2\n3,4\n# mid\n5,6\n7,8").unwrap();
        let a = load_csv(&rt, &p, (3, 2), ',').unwrap();
        assert_eq!(a.shape(), (4, 2));
        assert_eq!(a.collect().unwrap().data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_parts_save_load_round_trip() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(10, 6, |i, j| (i * 6 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (4, 6)).unwrap();
        let dir = tmp("csvparts");
        save_csv_parts(&a, &dir, ',').unwrap();
        // One partition file per block-row, written by parallel tasks.
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 3);
        assert_eq!(rt.metrics().tasks_for("dsarray.io.save_csv"), 3);
        let back = load_csv_parts(&rt, &dir, 2, ',').unwrap();
        assert_eq!(back.shape(), (10, 6));
        assert_eq!(back.block_shape(), (4, 2)); // rows-per-file becomes bs0
        assert_eq!(back.collect().unwrap(), m);
        // `load_csv` on a directory delegates to the partitioned loader.
        let via_dir = load_csv(&rt, &dir, (999, 3), ',').unwrap();
        assert_eq!(via_dir.collect().unwrap(), m);
        // Hidden files and foreign-format partitions are not CSV rows.
        std::fs::write(dir.join(".stray"), "not,a,partition\n").unwrap();
        std::fs::write(dir.join("part-00000.svm"), "1 1:2.0\n").unwrap();
        assert_eq!(load_csv_parts(&rt, &dir, 2, ',').unwrap().collect().unwrap(), m);
        // Re-saving a SMALLER array into the same directory clears the
        // stale higher-numbered partitions — a reload must not see them.
        let small = DenseMatrix::from_fn(4, 6, |i, j| -((i * 6 + j) as f32));
        let b = creation::from_matrix(&rt, &small, (4, 6)).unwrap();
        save_csv_parts(&b, &dir, ',').unwrap();
        assert_eq!(load_csv_parts(&rt, &dir, 6, ',').unwrap().collect().unwrap(), small);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_parts_rejects_ragged_partitions() {
        let dir = tmp("ragged_parts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("part-00000.csv"), "1,2\n3,4\n").unwrap();
        std::fs::write(dir.join("part-00001.csv"), "5,6\n7,8\n9,10\n").unwrap();
        let rt = Runtime::local(1);
        let err = load_csv_parts(&rt, &dir, 2, ',').unwrap_err().to_string();
        assert!(err.contains("only the last may be shorter"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parallel_svmlight_matches_serial_and_round_trips() {
        let rt = Runtime::local(2);
        let trips: Vec<(usize, usize, f32)> = (0..40)
            .map(|k| ((k * 7) % 11, (k * 3) % 6, k as f32 * 0.5 - 2.0))
            .collect();
        let csr = CsrMatrix::from_triplets(11, 6, &trips).unwrap();
        let labels = DenseMatrix::from_fn(11, 1, |i, _| if i % 2 == 0 { 1.0 } else { -1.0 });
        let p = tmp("par.svm");
        write_svmlight(&p, &csr, &labels).unwrap();

        let (x, y) = load_svmlight(&rt, &p, 6, (4, 3)).unwrap();
        assert!(x.is_sparse());
        assert_eq!(x.shape(), (11, 6));
        assert_eq!(rt.metrics().tasks_for("dsarray.io.load_svmlight"), 3);
        let (sx, sy) = read_svmlight(&p, 6).unwrap();
        assert_eq!(x.collect_csr().unwrap().to_dense(), sx.to_dense());
        assert_eq!(y.collect().unwrap(), sy);

        // Symmetric partitioned write-back, loadable file by file.
        let dir = tmp("svmparts");
        save_svmlight_parts(&x, &y, &dir).unwrap();
        let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        files.sort();
        assert_eq!(files.len(), 3);
        let mut row = 0;
        for f in files {
            let (ps, pl) = read_svmlight(&f, 6).unwrap();
            let want = csr.row_slice(row, ps.rows()).unwrap();
            assert_eq!(ps.to_dense(), want.to_dense());
            assert_eq!(pl.get(0, 0), labels.get(row, 0));
            row += ps.rows();
        }
        assert_eq!(row, 11);
        std::fs::remove_file(&p).ok();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn svmlight_out_of_range_index_is_line_numbered_error() {
        let rt = Runtime::local(2);
        let p = tmp("oob.svm");
        std::fs::write(&p, "1 1:1.0\n1 2:1.0\n-1 9:1.0\n").unwrap();
        let (x, _) = load_svmlight(&rt, &p, 5, (2, 5)).unwrap();
        let err = x.collect_csr().unwrap_err().to_string();
        assert!(err.contains(":3") && err.contains("out of range 1..=5"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_load_save_round_trip() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(9, 5, |i, j| (i * 5 + j) as f32 * 0.125);
        let p = tmp("rt.npy");
        io::write_npy(&p, &m).unwrap();
        let a = load_npy(&rt, &p, (4, 2)).unwrap();
        assert_eq!(a.shape(), (9, 5));
        assert_eq!(a.collect().unwrap(), m);
        assert_eq!(rt.metrics().tasks_for("dsarray.io.load_npy"), 3);

        let q = tmp("save.npy");
        save_npy(&a, &q).unwrap();
        assert_eq!(read_npy(&q).unwrap(), m);
        assert_eq!(rt.metrics().tasks_for("dsarray.io.save_npy"), 3);
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&q).ok();
    }

    #[test]
    fn loaders_reject_empty_inputs() {
        let rt = Runtime::local(1);
        let p = tmp("empty.csv");
        std::fs::write(&p, "# only comments\n").unwrap();
        assert!(load_csv(&rt, &p, (2, 2), ',').is_err());
        assert!(load_svmlight(&rt, &p, 4, (2, 2)).is_err());
        assert!(load_csv(&rt, &p, (0, 2), ',').is_err());
        std::fs::remove_file(&p).ok();
    }
}
