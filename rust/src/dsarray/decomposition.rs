//! Distributed matrix decomposition: TSQR (tall-skinny QR).
//!
//! The paper's §6 positions ds-arrays as the substrate for "common
//! mathematical operations, such as matrix multiplication and
//! decomposition". TSQR is the canonical blocked decomposition for
//! row-partitioned tall matrices (dislib ships one): factor each block-row
//! locally, reduce the R factors pairwise up a tree, then push Q
//! corrections back down. All stages are tasks; the reduction tree is
//! `2N-1` QR tasks for N block-rows, fully parallel within each level.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{CostHint, Future};

use super::DsArray;

impl DsArray {
    /// Thin QR of a tall-skinny ds-array (cols ≤ every block-row height,
    /// single block-column): returns `(Q, R)` with `Q` a ds-array with the
    /// same blocking and `R` an n×n future (synchronize with
    /// `runtime().wait`).
    pub fn tsqr(&self) -> Result<(DsArray, Future)> {
        if self.is_lazy() {
            return self.force()?.tsqr();
        }
        if self.grid.1 != 1 {
            bail!(
                "tsqr needs a single block column, got {} (rechunk to (bs, {}))",
                self.grid.1,
                self.shape.1
            );
        }
        let n = self.shape.1;
        for i in 0..self.grid.0 {
            if self.block_rows_at(i) < n {
                bail!(
                    "tsqr needs every block-row height >= {} cols (block {} has {})",
                    n,
                    i,
                    self.block_rows_at(i)
                );
            }
        }
        let rt = &self.rt;

        // ---- Stage 1: local QR per block-row. ----
        let mut qs: Vec<Future> = Vec::with_capacity(self.grid.0); // local Q factors
        let mut rs: Vec<Future> = Vec::with_capacity(self.grid.0); // local Rs
        for i in 0..self.grid.0 {
            let b = self.block(i, 0);
            let rows = b.meta.rows;
            let out = rt.submit(
                "dsarray.tsqr.local",
                &[b],
                vec![BlockMeta::dense(rows, n), BlockMeta::dense(n, n)],
                CostHint::flops(2.0 * rows as f64 * (n * n) as f64)
                    .with_bytes(b.meta.bytes() as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let (q, r) = ins[0].to_dense()?.qr_thin()?;
                    Ok(vec![Block::Dense(q), Block::Dense(r)])
                }),
            );
            qs.push(out[0]);
            rs.push(out[1]);
        }

        // ---- Stage 2: pairwise R reduction tree. Each merge stacks two
        // R factors (2n×n), QRs them, and emits the merged R plus the two
        // n×n correction blocks applied to the children's Qs. ----
        // We track, per live R, the list of (leaf index, correction chain
        // future) — corrections compose by matmul on the way down; to keep
        // the graph simple we accumulate the composed correction per leaf
        // eagerly at every merge level.
        struct Node {
            r: Future,
            /// (leaf, composed correction future) pairs under this node.
            leaves: Vec<(usize, Option<Future>)>,
        }
        let mut level: Vec<Node> = rs
            .iter()
            .enumerate()
            .map(|(i, &r)| Node {
                r,
                leaves: vec![(i, None)],
            })
            .collect();

        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut iter = level.into_iter();
            while let Some(a) = iter.next() {
                match iter.next() {
                    None => next.push(a),
                    Some(b) => {
                        let out = rt.submit(
                            "dsarray.tsqr.merge",
                            &[a.r, b.r],
                            vec![
                                BlockMeta::dense(n, n), // merged R
                                BlockMeta::dense(n, n), // correction for a
                                BlockMeta::dense(n, n), // correction for b
                            ],
                            CostHint::flops(4.0 * (n * n * n) as f64),
                            Arc::new(move |ins: &[Arc<Block>]| {
                                let ra = ins[0].to_dense()?;
                                let rb = ins[1].to_dense()?;
                                let stacked = DenseMatrix::vstack(&[&ra, &rb])?;
                                let (q, r) = stacked.qr_thin()?;
                                let ca = q.slice(0, 0, ra.rows(), r.cols())?;
                                let cb = q.slice(ra.rows(), 0, rb.rows(), r.cols())?;
                                Ok(vec![Block::Dense(r), Block::Dense(ca), Block::Dense(cb)])
                            }),
                        );
                        let (merged_r, corr_a, corr_b) = (out[0], out[1], out[2]);
                        // Compose corrections into every leaf under a and b.
                        let mut leaves = Vec::with_capacity(a.leaves.len() + b.leaves.len());
                        for (side, corr) in [(a.leaves, corr_a), (b.leaves, corr_b)] {
                            for (leaf, prev) in side {
                                let composed = match prev {
                                    None => corr,
                                    Some(p) => {
                                        // new = prev @ corr (n×n each)
                                        rt.submit(
                                            "dsarray.tsqr.compose",
                                            &[p, corr],
                                            vec![BlockMeta::dense(n, n)],
                                            CostHint::flops(2.0 * (n * n * n) as f64),
                                            crate::tasking::ops::matmul_op(),
                                        )[0]
                                    }
                                };
                                leaves.push((leaf, Some(composed)));
                            }
                        }
                        next.push(Node {
                            r: merged_r,
                            leaves,
                        });
                    }
                }
            }
            level = next;
        }
        let root = level.pop().expect("non-empty");

        // ---- Stage 3: apply composed corrections to the local Qs. ----
        let mut q_blocks: Vec<Option<Future>> = vec![None; self.grid.0];
        for (leaf, corr) in root.leaves {
            let q_local = qs[leaf];
            let rows = q_local.meta.rows;
            let fut = match corr {
                None => q_local, // single-block array: Q is already global
                Some(c) => rt.submit(
                    "dsarray.tsqr.apply",
                    &[q_local, c],
                    vec![BlockMeta::dense(rows, n)],
                    CostHint::flops(2.0 * rows as f64 * (n * n) as f64),
                    crate::tasking::ops::matmul_op(),
                )[0],
            };
            q_blocks[leaf] = Some(fut);
        }
        let blocks: Vec<Future> = q_blocks.into_iter().map(|b| b.expect("filled")).collect();
        let q = DsArray::from_parts(
            rt.clone(),
            self.shape,
            self.block_shape,
            blocks,
            false,
        )?;
        Ok((q, root.r))
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::{Runtime, SimConfig};
    use crate::util::rng::Xoshiro256;

    fn tall(rt: &Runtime, m: usize, n: usize, bs: usize, seed: u64) -> (DenseMatrix, super::DsArray) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let a = DenseMatrix::from_fn(m, n, |_, _| rng.next_normal());
        let d = creation::from_matrix(rt, &a, (bs, n)).unwrap();
        (a, d)
    }

    #[test]
    fn tsqr_reconstructs_and_q_orthonormal() {
        let rt = Runtime::local(2);
        let (a, d) = tall(&rt, 40, 5, 8, 1); // 5 block rows
        let (q, r) = d.tsqr().unwrap();
        let qm = q.collect().unwrap();
        let rm = rt.wait(r).unwrap().to_dense().unwrap();
        // QR = A.
        let qr = qm.matmul(&rm).unwrap();
        assert!(qr.max_abs_diff(&a) < 1e-3, "diff {}", qr.max_abs_diff(&a));
        // Global QᵀQ = I.
        let qtq = qm.transpose().matmul(&qm).unwrap();
        assert!(
            qtq.max_abs_diff(&DenseMatrix::identity(5)) < 1e-3,
            "QᵀQ diff {}",
            qtq.max_abs_diff(&DenseMatrix::identity(5))
        );
        // R matches a direct QR up to column signs: |R| equal.
        let (_, r_ref) = a.qr_thin().unwrap();
        let abs_diff = (0..5)
            .flat_map(|i| (0..5).map(move |j| (i, j)))
            .map(|(i, j)| (rm.get(i, j).abs() - r_ref.get(i, j).abs()).abs())
            .fold(0.0f32, f32::max);
        assert!(abs_diff < 1e-3, "|R| mismatch {abs_diff}");
    }

    #[test]
    fn tsqr_odd_block_count_and_single_block() {
        let rt = Runtime::local(2);
        for (m, bs) in [(21, 7), (12, 12)] {
            let (a, d) = tall(&rt, m, 3, bs, 2);
            let (q, r) = d.tsqr().unwrap();
            let qm = q.collect().unwrap();
            let rm = rt.wait(r).unwrap().to_dense().unwrap();
            assert!(qm.matmul(&rm).unwrap().max_abs_diff(&a) < 1e-3);
        }
    }

    #[test]
    fn tsqr_rejects_bad_shapes() {
        let rt = Runtime::local(1);
        // Multi-column grid.
        let d = creation::zeros(&rt, (20, 6), (5, 3)).unwrap();
        assert!(d.tsqr().is_err());
        // Block shorter than n.
        let d = creation::zeros(&rt, (20, 6), (4, 6)).unwrap();
        assert!(d.tsqr().is_err());
    }

    #[test]
    fn tsqr_task_count_in_sim() {
        // N local QRs + N-1 merges (+ compose/apply) — structure check.
        let sim = Runtime::sim(SimConfig::with_workers(4));
        let d = creation::phantom(&sim, (64, 4), (8, 4), None).unwrap();
        d.tsqr().unwrap();
        let m = sim.metrics();
        assert_eq!(m.tasks_for("dsarray.tsqr.local"), 8);
        assert_eq!(m.tasks_for("dsarray.tsqr.merge"), 7);
        assert_eq!(m.tasks_for("dsarray.tsqr.apply"), 8);
        sim.run_sim().unwrap();
    }
}
