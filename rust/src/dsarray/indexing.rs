//! Indexing (paper §4.2.3): `A[10:100]`-style row slices, 2-D region
//! slices, single-element access, and fancy indexing — the "filtering"
//! operations that were slow on Datasets.
//!
//! Everything here is **zero-copy at call time** (the view layer): slices
//! and index selections return lazy views sharing the parent's block
//! futures, submitting no runtime tasks. Block-aligned slices are returned
//! directly as canonical arrays and never pay a copy; other views
//! materialize through [`DsArray::force`] when an operation needs
//! canonical blocks.

use anyhow::{bail, Result};

use crate::util::rng::Xoshiro256;

use super::{DsArray, Sel};

impl DsArray {
    /// Rows `[r0, r1)` — `A[r0:r1]`. Zero-copy; see [`DsArray::slice`].
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, tasking::Runtime};
    /// let rt = Runtime::local(2);
    /// let a = creation::random(&rt, (8, 6), (4, 3), 0).unwrap();
    /// let top = a.slice_rows(0, 4).unwrap(); // block-aligned: pure metadata
    /// assert_eq!(top.shape(), (4, 6));
    /// assert!(!top.is_view()); // canonical, shares blocks with `a`
    /// ```
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Result<DsArray> {
        self.slice(r0, r1, 0, self.shape.1)
    }

    /// Columns `[c0, c1)` — `A[:, c0:c1]` (efficient on ds-arrays; the whole
    /// point of two-axis blocking). Zero-copy; see [`DsArray::slice`].
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Result<DsArray> {
        self.slice(0, self.shape.0, c0, c1)
    }

    /// Rectangular region `[r0, r1) × [c0, c1)` as a **zero-copy view**: no
    /// tasks are submitted, the result shares block futures with `self`
    /// (handle references retained, so the blocks outlive the parent).
    ///
    /// Block-aligned regions — offsets on block boundaries, extents ending
    /// on a block boundary or the array edge — come back canonical and are
    /// never copied at all. Anything else is a lazy view that materializes
    /// per-block only when [`DsArray::force`] runs (downstream operations
    /// force implicitly). Sparse arrays stay CSR throughout.
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, tasking::Runtime};
    /// let rt = Runtime::local(2);
    /// let a = creation::random(&rt, (8, 8), (4, 4), 1).unwrap();
    /// let aligned = a.slice(4, 8, 0, 4).unwrap();
    /// assert!(!aligned.is_view());
    /// let lazy = a.slice(1, 6, 2, 7).unwrap(); // crosses block boundaries
    /// assert!(lazy.is_view());
    /// assert_eq!(lazy.shape(), (5, 5));
    /// assert_eq!(lazy.get(0, 0).unwrap(), a.get(1, 2).unwrap());
    /// ```
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Result<DsArray> {
        if r0 >= r1 || c0 >= c1 || r1 > self.shape.0 || c1 > self.shape.1 {
            bail!(
                "slice [{r0}:{r1}, {c0}:{c1}] invalid for shape {:?} \
                 (needs r0 < r1 <= rows and c0 < c1 <= cols)",
                self.shape
            );
        }
        // Deferred elementwise expressions and planned gemms materialize
        // before slicing (the backing blocks hold un-evaluated inputs, or
        // don't exist yet); memoized, so slicing a chain several ways
        // executes it once.
        if self.expr.is_some() || self.gemm.is_some() {
            return self.force()?.slice(r0, r1, c0, c1);
        }
        let (nr, nc) = (r1 - r0, c1 - c0);
        // Compose each axis with the existing view (slice-of-slice,
        // slice-of-take): fancy axes restrict the index map, contiguous
        // axes shift the offset into stored coordinates. select_stored then
        // keeps only the touched backing blocks.
        let base = self.view.clone().unwrap_or_default();
        self.select_stored(base.row_sel(r0, nr), base.col_sel(c0, nc))
    }

    /// Single element — synchronizes exactly one backing block, applying
    /// the view mapping when `self` is a lazy view.
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, tasking::Runtime};
    /// let rt = Runtime::local(1);
    /// let a = creation::identity(&rt, 4, (2, 2)).unwrap();
    /// assert_eq!(a.get(2, 2).unwrap(), 1.0);
    /// assert_eq!(a.get(2, 1).unwrap(), 0.0);
    /// assert!(a.get(4, 0).is_err());
    /// ```
    pub fn get(&self, i: usize, j: usize) -> Result<f32> {
        if i >= self.shape.0 || j >= self.shape.1 {
            bail!("index ({i},{j}) out of bounds for shape {:?}", self.shape);
        }
        if self.expr.is_some() || self.gemm.is_some() {
            return self.force()?.get(i, j);
        }
        let (sr, sc) = match &self.view {
            None => (i, j),
            Some(v) => (v.map_row(i), v.map_col(j)),
        };
        let (bi, bj) = (sr / self.block_shape.0, sc / self.block_shape.1);
        let b = self.rt.wait(self.block(bi, bj))?;
        Ok(b.to_dense()?
            .get(sr - bi * self.block_shape.0, sc - bj * self.block_shape.1))
    }

    /// Select arbitrary rows by index (fancy indexing) as a **lazy view** —
    /// zero tasks at call time; arbitrary order and duplicates are allowed.
    /// Materialization ([`DsArray::force`]) gathers one task per output
    /// block, keeping CSR blocks CSR.
    ///
    /// The index list must be non-empty: a ds-array cannot have zero rows.
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, storage::DenseMatrix, tasking::Runtime};
    /// let rt = Runtime::local(2);
    /// let m = DenseMatrix::from_fn(6, 4, |i, j| (i * 4 + j) as f32);
    /// let a = creation::from_matrix(&rt, &m, (2, 2)).unwrap();
    /// let picked = a.take_rows(&[5, 0, 5]).unwrap();
    /// assert!(picked.is_view());
    /// let got = picked.collect().unwrap();
    /// assert_eq!(got.row(0), m.row(5));
    /// assert_eq!(got.row(1), m.row(0));
    /// assert_eq!(got.row(2), m.row(5));
    /// ```
    pub fn take_rows(&self, idx: &[usize]) -> Result<DsArray> {
        if idx.is_empty() {
            bail!("take_rows with an empty index list (a ds-array cannot have zero rows)");
        }
        for &i in idx {
            if i >= self.shape.0 {
                bail!("row index {i} out of bounds for {} rows", self.shape.0);
            }
        }
        if self.expr.is_some() || self.gemm.is_some() {
            return self.force()?.take_rows(idx);
        }
        let base = self.view.clone().unwrap_or_default();
        let mapped: Vec<usize> = idx.iter().map(|&k| base.map_row(k)).collect();
        self.select_stored(Sel::Idx(mapped), base.col_sel(0, self.shape.1))
    }

    /// Select arbitrary columns by index (fancy indexing) as a lazy view —
    /// the column-wise twin of [`DsArray::take_rows`], practical on
    /// ds-arrays because both axes are blocked.
    pub fn take_cols(&self, idx: &[usize]) -> Result<DsArray> {
        if idx.is_empty() {
            bail!("take_cols with an empty index list (a ds-array cannot have zero columns)");
        }
        for &j in idx {
            if j >= self.shape.1 {
                bail!("column index {j} out of bounds for {} columns", self.shape.1);
            }
        }
        if self.expr.is_some() || self.gemm.is_some() {
            return self.force()?.take_cols(idx);
        }
        let base = self.view.clone().unwrap_or_default();
        let mapped: Vec<usize> = idx.iter().map(|&k| base.map_col(k)).collect();
        self.select_stored(base.row_sel(0, self.shape.0), Sel::Idx(mapped))
    }

    /// Boolean-mask row filtering: keep row `i` where `mask[i]` is true
    /// (NumPy's `A[mask]`). The mask length must equal the row count and
    /// must select at least one row. Returns a lazy view.
    pub fn filter_rows(&self, mask: &[bool]) -> Result<DsArray> {
        if mask.len() != self.shape.0 {
            bail!(
                "boolean mask length {} != {} rows",
                mask.len(),
                self.shape.0
            );
        }
        let idx: Vec<usize> = mask
            .iter()
            .enumerate()
            .filter_map(|(i, &keep)| keep.then_some(i))
            .collect();
        if idx.is_empty() {
            bail!("boolean mask selects zero rows (a ds-array cannot have zero rows)");
        }
        self.take_rows(&idx)
    }

    /// Split rows into disjoint shuffled (train, test) views — the
    /// estimator-facing row partitioner. `test_fraction` is clamped so both
    /// sides keep at least one row; the permutation is seeded and
    /// reproducible. Both results are lazy views: no data moves until an
    /// estimator forces them.
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, tasking::Runtime};
    /// let rt = Runtime::local(2);
    /// let a = creation::random(&rt, (10, 4), (4, 4), 3).unwrap();
    /// let (train, test) = a.train_test_split(0.3, 42).unwrap();
    /// assert_eq!(train.shape(), (7, 4));
    /// assert_eq!(test.shape(), (3, 4));
    /// ```
    pub fn train_test_split(&self, test_fraction: f64, seed: u64) -> Result<(DsArray, DsArray)> {
        let n = self.shape.0;
        if n < 2 {
            bail!("train_test_split needs at least 2 rows, got {n}");
        }
        if !(0.0..=1.0).contains(&test_fraction) {
            bail!("test_fraction {test_fraction} outside [0, 1]");
        }
        let n_test = ((n as f64) * test_fraction).round() as usize;
        let n_test = n_test.clamp(1, n - 1);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let perm = rng.permutation(n);
        let test = self.take_rows(&perm[..n_test])?;
        let train = self.take_rows(&perm[n_test..])?;
        Ok((train, test))
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;

    fn setup() -> (Runtime, DenseMatrix, super::DsArray) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(9, 8, |i, j| (i * 8 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (3, 3)).unwrap();
        (rt, m, a)
    }

    #[test]
    fn aligned_and_unaligned_slices_match_reference() {
        let (_rt, m, a) = setup();
        // Aligned (canonical shared-block fast path).
        let s = a.slice(3, 6, 3, 6).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(3, 3, 3, 3).unwrap());
        // Unaligned (lazy view across block boundaries).
        let s = a.slice(1, 8, 2, 7).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(1, 2, 7, 5).unwrap());
        // Full-width row slice.
        let s = a.slice_rows(2, 9).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(2, 0, 7, 8).unwrap());
        // Column slice.
        let s = a.slice_cols(1, 4).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(0, 1, 9, 3).unwrap());
    }

    #[test]
    fn aligned_slices_submit_zero_tasks() {
        // The paper's §4.2.3 claim, measured: block-aligned slicing is pure
        // metadata — zero tasks at slice time AND at collect time.
        let (rt, m, a) = setup();
        let before = rt.metrics();
        let s = a.slice(3, 9, 3, 6).unwrap();
        let r = a.slice_rows(6, 9).unwrap();
        let c = a.slice_cols(0, 6).unwrap();
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        assert!(!s.is_view() && !r.is_view() && !c.is_view());
        // Blocks are shared with the parent, not copied.
        assert_eq!(s.block(0, 0), a.block(1, 1));
        assert_eq!(s.collect().unwrap(), m.slice(3, 3, 6, 3).unwrap());
        assert_eq!(r.collect().unwrap(), m.slice(6, 0, 3, 8).unwrap());
        assert_eq!(c.collect().unwrap(), m.slice(0, 0, 9, 6).unwrap());
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
    }

    #[test]
    fn unaligned_slices_are_lazy_until_forced() {
        let (rt, m, a) = setup();
        let before = rt.metrics();
        let v = a.slice(1, 8, 2, 7).unwrap();
        assert!(v.is_view());
        // Slicing and collecting a view submit no tasks.
        assert_eq!(v.collect().unwrap(), m.slice(1, 2, 7, 5).unwrap());
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        // Forcing materializes: one copy task per output block.
        let f = v.force().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.total_tasks(), f.n_blocks() as u64);
        assert_eq!(f.grid(), (3, 2));
        assert_eq!(f.collect().unwrap(), m.slice(1, 2, 7, 5).unwrap());
    }

    #[test]
    fn aligned_offset_with_partial_tail_is_view_but_collects_free() {
        // Offsets on block boundaries but the extent cuts a block mid-way:
        // still zero tasks at slice + collect; only force() copies.
        let (rt, m, a) = setup();
        let before = rt.metrics();
        let v = a.slice(0, 8, 0, 7).unwrap();
        assert!(v.is_view());
        assert_eq!(v.collect().unwrap(), m.slice(0, 0, 8, 7).unwrap());
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
    }

    #[test]
    fn single_row_and_column_slices() {
        let (_rt, m, a) = setup();
        let row = a.slice(4, 5, 0, 8).unwrap();
        assert_eq!(row.shape(), (1, 8));
        assert_eq!(row.collect().unwrap(), m.slice(4, 0, 1, 8).unwrap());
        let col = a.slice(0, 9, 7, 8).unwrap();
        assert_eq!(col.shape(), (9, 1));
        assert_eq!(col.collect().unwrap(), m.slice(0, 7, 9, 1).unwrap());
        // Forced copies agree too.
        assert_eq!(
            row.force().unwrap().collect().unwrap(),
            m.slice(4, 0, 1, 8).unwrap()
        );
        assert_eq!(
            col.force().unwrap().collect().unwrap(),
            m.slice(0, 7, 9, 1).unwrap()
        );
    }

    #[test]
    fn slice_of_slice_composes() {
        let (rt, m, a) = setup();
        let before = rt.metrics();
        let v1 = a.slice(1, 8, 2, 8).unwrap(); // 7x6 view at (1,2)
        let v2 = v1.slice(2, 6, 1, 5).unwrap(); // 4x4 view at (3,3) absolute
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        assert_eq!(v2.collect().unwrap(), m.slice(3, 3, 4, 4).unwrap());
        // Slice of an aligned (canonical) slice.
        let c1 = a.slice(3, 9, 0, 6).unwrap();
        let c2 = c1.slice(1, 5, 2, 6).unwrap();
        assert_eq!(c2.collect().unwrap(), m.slice(4, 2, 4, 4).unwrap());
        // Slice of a fancy-indexed view restricts the index map.
        let t = a.take_rows(&[8, 0, 4, 2]).unwrap();
        let ts = t.slice(1, 3, 2, 5).unwrap();
        let got = ts.collect().unwrap();
        assert_eq!(got.row(0), m.slice(0, 2, 1, 3).unwrap().row(0));
        assert_eq!(got.row(1), m.slice(4, 2, 1, 3).unwrap().row(0));
        // And forcing the composition matches.
        assert_eq!(ts.force().unwrap().collect().unwrap(), got);
    }

    #[test]
    fn invalid_slices_rejected_with_context() {
        let (_rt, _m, a) = setup();
        assert!(a.slice(5, 5, 0, 1).is_err());
        assert!(a.slice(0, 10, 0, 1).is_err());
        assert!(a.slice(0, 1, 7, 9).is_err());
        let msg = a.slice(0, 10, 0, 1).unwrap_err().to_string();
        assert!(msg.contains("[0:10, 0:1]"), "got: {msg}");
        assert!(msg.contains("(9, 8)"), "got: {msg}");
    }

    #[test]
    fn get_single_elements() {
        let (_rt, m, a) = setup();
        assert_eq!(a.get(0, 0).unwrap(), m.get(0, 0));
        assert_eq!(a.get(8, 7).unwrap(), m.get(8, 7));
        assert_eq!(a.get(4, 5).unwrap(), m.get(4, 5));
        assert!(a.get(9, 0).is_err());
        let msg = a.get(9, 0).unwrap_err().to_string();
        assert!(msg.contains("(9,0)") && msg.contains("(9, 8)"), "got: {msg}");
        // get through views maps coordinates without synchronizing extra blocks.
        let v = a.slice(2, 9, 1, 8).unwrap();
        assert_eq!(v.get(0, 0).unwrap(), m.get(2, 1));
        assert_eq!(v.get(6, 6).unwrap(), m.get(8, 7));
        let t = a.take_rows(&[7, 1]).unwrap();
        assert_eq!(t.get(0, 3).unwrap(), m.get(7, 3));
        assert_eq!(t.get(1, 0).unwrap(), m.get(1, 0));
        assert!(t.get(2, 0).is_err());
    }

    #[test]
    fn take_rows_matches_reference() {
        let (rt, m, a) = setup();
        let idx = vec![8, 0, 3, 3, 5, 1, 7];
        let before = rt.metrics();
        let t = a.take_rows(&idx).unwrap();
        // Fancy indexing is lazy: zero tasks until forced.
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        assert!(t.is_view());
        let got = t.collect().unwrap();
        for (k, &r) in idx.iter().enumerate() {
            assert_eq!(got.row(k), m.row(r), "row {k} (source {r})");
        }
        assert_eq!(t.force().unwrap().collect().unwrap(), got);
        assert!(a.take_rows(&[9]).is_err());
        let msg = a.take_rows(&[9]).unwrap_err().to_string();
        assert!(msg.contains("9") && msg.contains("out of bounds"), "got: {msg}");
    }

    #[test]
    fn take_rows_empty_index_rejected() {
        let (_rt, _m, a) = setup();
        let msg = a.take_rows(&[]).unwrap_err().to_string();
        assert!(msg.contains("empty"), "got: {msg}");
        let msg = a.take_cols(&[]).unwrap_err().to_string();
        assert!(msg.contains("empty"), "got: {msg}");
    }

    #[test]
    fn take_cols_matches_reference() {
        let (_rt, m, a) = setup();
        let idx = vec![7, 0, 0, 4];
        let t = a.take_cols(&idx).unwrap();
        assert_eq!(t.shape(), (9, 4));
        assert_eq!(t.collect().unwrap(), m.take_cols(&idx).unwrap());
        assert_eq!(
            t.force().unwrap().collect().unwrap(),
            m.take_cols(&idx).unwrap()
        );
        assert!(a.take_cols(&[8]).is_err());
        // Rows-of-cols composition: both index maps live on one view.
        let rc = a.take_rows(&[6, 2]).unwrap().take_cols(&[1, 5]).unwrap();
        let got = rc.collect().unwrap();
        assert_eq!(got.get(0, 0), m.get(6, 1));
        assert_eq!(got.get(0, 1), m.get(6, 5));
        assert_eq!(got.get(1, 0), m.get(2, 1));
        assert_eq!(rc.force().unwrap().collect().unwrap(), got);
    }

    #[test]
    fn filter_rows_boolean_mask() {
        let (_rt, m, a) = setup();
        let mask: Vec<bool> = (0..9).map(|i| i % 3 == 0).collect();
        let f = a.filter_rows(&mask).unwrap();
        assert_eq!(f.shape(), (3, 8));
        let got = f.collect().unwrap();
        assert_eq!(got.row(0), m.row(0));
        assert_eq!(got.row(1), m.row(3));
        assert_eq!(got.row(2), m.row(6));
        assert!(a.filter_rows(&[true; 4]).is_err());
        let msg = a.filter_rows(&[false; 9]).unwrap_err().to_string();
        assert!(msg.contains("zero rows"), "got: {msg}");
    }

    #[test]
    fn train_test_split_partitions_rows() {
        let (_rt, m, a) = setup();
        let (train, test) = a.train_test_split(0.33, 7).unwrap();
        assert_eq!(train.rows() + test.rows(), 9);
        assert_eq!(test.rows(), 3);
        // Every original row appears exactly once across the two views:
        // compare sorted first-column values.
        let mut firsts: Vec<f32> = Vec::new();
        let tr = train.collect().unwrap();
        let te = test.collect().unwrap();
        for i in 0..tr.rows() {
            firsts.push(tr.get(i, 0));
        }
        for i in 0..te.rows() {
            firsts.push(te.get(i, 0));
        }
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = (0..9).map(|i| m.get(i, 0)).collect();
        assert_eq!(firsts, want);
        // Reproducible.
        let (tr2, _) = a.train_test_split(0.33, 7).unwrap();
        assert_eq!(tr2.collect().unwrap(), tr);
        assert!(a.train_test_split(1.5, 0).is_err());
    }

    #[test]
    fn views_keep_shared_blocks_alive_after_parent_drop() {
        // Refcount interplay: the view owns handle references on the blocks
        // it shares, so dropping the parent (and letting its other blocks
        // be consumed + reclaimed) must not invalidate the view.
        let (rt, m, a) = setup();
        let v = a.slice(0, 3, 0, 8).unwrap(); // first block-row, aligned
        let b = a.add_scalar(1.0).unwrap(); // consumes every block of `a`
        drop(a);
        b.collect().unwrap();
        rt.barrier().unwrap();
        assert_eq!(v.collect().unwrap(), m.slice(0, 0, 3, 8).unwrap());
    }

    #[test]
    fn downstream_ops_force_views_transparently() {
        let (_rt, m, a) = setup();
        let v = a.slice(1, 7, 1, 7).unwrap();
        let got = v.add_scalar(1.0).unwrap().collect().unwrap();
        let want = m.slice(1, 1, 6, 6).unwrap().map(|x| x + 1.0);
        assert_eq!(got, want);
        let s = v.sum_axis(0).unwrap().collect().unwrap();
        let want = m.slice(1, 1, 6, 6).unwrap().sum_axis(0);
        assert_eq!(s, want);
        let t = a.take_rows(&[4, 2, 0]).unwrap();
        let tt = t.transpose().unwrap().collect().unwrap();
        assert_eq!(tt, m.take_rows(&[4, 2, 0]).unwrap().transpose());
    }

    #[test]
    fn sparse_slices_stay_sparse() {
        // Satellite fix: the gather path used to densify sparse inputs on
        // unaligned slices; the view materializer keeps CSR end to end.
        let rt = Runtime::local(2);
        let csr = crate::storage::CsrMatrix::from_triplets(
            6,
            6,
            &[(0, 0, 1.0), (3, 3, 2.0), (4, 1, -1.0), (5, 5, 3.0)],
        )
        .unwrap();
        let a = creation::from_csr(&rt, &csr, (3, 3)).unwrap();
        // Aligned: canonical, CSR blocks shared.
        let s = a.slice(3, 6, 3, 6).unwrap();
        assert!(s.is_sparse() && !s.is_view());
        assert_eq!(
            s.collect().unwrap(),
            csr.to_dense().slice(3, 3, 3, 3).unwrap()
        );
        // Unaligned: lazy view, still sparse; forcing gathers in CSR.
        let u = a.slice(1, 5, 1, 5).unwrap();
        assert!(u.is_sparse() && u.is_view());
        let f = u.force().unwrap();
        assert!(f.is_sparse());
        assert_eq!(
            f.collect_csr().unwrap().to_dense(),
            csr.to_dense().slice(1, 1, 4, 4).unwrap()
        );
        // Fancy row selection keeps CSR too.
        let t = a.take_rows(&[5, 0, 3]).unwrap();
        assert!(t.is_sparse());
        let ft = t.force().unwrap();
        assert_eq!(
            ft.collect_csr().unwrap().to_dense(),
            csr.to_dense().take_rows(&[5, 0, 3]).unwrap()
        );
    }

    #[test]
    fn unaligned_tail_block_geometry() {
        // 10x7 with 4x3 blocks: edge blocks are 2x1; slices crossing into
        // them must respect the smaller extents.
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(10, 7, |i, j| (i * 7 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (4, 3)).unwrap();
        let v = a.slice(5, 10, 2, 7).unwrap();
        assert_eq!(v.collect().unwrap(), m.slice(5, 2, 5, 5).unwrap());
        let f = v.force().unwrap();
        assert_eq!(f.grid(), (2, 2));
        assert_eq!(f.collect().unwrap(), m.slice(5, 2, 5, 5).unwrap());
        // A slice that IS the whole array is canonical and free.
        let whole = a.slice(0, 10, 0, 7).unwrap();
        assert!(!whole.is_view());
        assert_eq!(whole.collect().unwrap(), m);
    }
}
