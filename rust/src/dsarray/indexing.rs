//! Indexing (paper §4.2.3): `A[10:100]`-style row slices, 2-D region
//! slices, single-element access, and row selection by index list — the
//! "filtering" operation that was slow on Datasets.

use anyhow::{bail, Result};

use crate::storage::BlockMeta;
use crate::tasking::{ops, CostHint};

use super::DsArray;

impl DsArray {
    /// Rows `[r0, r1)` — `A[r0:r1]`.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Result<DsArray> {
        self.slice(r0, r1, 0, self.shape.1)
    }

    /// Columns `[c0, c1)` — `A[:, c0:c1]` (efficient on ds-arrays; the whole
    /// point of two-axis blocking).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Result<DsArray> {
        self.slice(0, self.shape.0, c0, c1)
    }

    /// Rectangular region `[r0, r1) x [c0, c1)`. One task per overlapped
    /// output block.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Result<DsArray> {
        if r0 >= r1 || c0 >= c1 || r1 > self.shape.0 || c1 > self.shape.1 {
            bail!(
                "slice [{r0}:{r1}, {c0}:{c1}] invalid for shape {:?}",
                self.shape
            );
        }
        let (nr, nc) = (r1 - r0, c1 - c0);
        let (bs0, bs1) = self.block_shape;
        let grid = (
            DsArray::grid_dim(nr, bs0),
            DsArray::grid_dim(nc, bs1),
        );
        let mut blocks = Vec::with_capacity(grid.0 * grid.1);
        for oi in 0..grid.0 {
            // Output block-row oi covers logical rows [or0, or0+orn).
            let or0 = r0 + oi * bs0;
            let orn = (r1 - or0).min(bs0);
            for oj in 0..grid.1 {
                let oc0 = c0 + oj * bs1;
                let ocn = (c1 - oc0).min(bs1);
                // Input blocks overlapping the output region.
                let bi0 = or0 / bs0;
                let bi1 = (or0 + orn - 1) / bs0;
                let bj0 = oc0 / bs1;
                let bj1 = (oc0 + ocn - 1) / bs1;
                let out_meta = if self.sparse {
                    self.expect_sparse_meta(orn, ocn)
                } else {
                    BlockMeta::dense(orn, ocn)
                };
                // Common fast path: the output block lives inside ONE input
                // block — a plain slice task. Otherwise assemble from up to
                // four neighbors with a gather task.
                if bi0 == bi1 && bj0 == bj1 {
                    let fut = self.block(bi0, bj0);
                    let lr = or0 - bi0 * bs0;
                    let lc = oc0 - bj0 * bs1;
                    let out = self.rt.submit(
                        "dsarray.index.slice",
                        &[fut],
                        vec![out_meta],
                        CostHint::default().with_bytes(out_meta.bytes() as f64),
                        ops::slice_op(lr, lc, orn, ocn),
                    );
                    blocks.push(out[0]);
                } else {
                    let mut futs = Vec::new();
                    let mut coords = Vec::new();
                    for bi in bi0..=bi1 {
                        for bj in bj0..=bj1 {
                            futs.push(self.block(bi, bj));
                            coords.push((bi, bj));
                        }
                    }
                    let (gbs0, gbs1) = (bs0, bs1);
                    let (gor0, goc0) = (or0, oc0);
                    let out = self.rt.submit(
                        "dsarray.index.gather",
                        &futs,
                        vec![out_meta],
                        CostHint::default().with_bytes(2.0 * out_meta.bytes() as f64),
                        std::sync::Arc::new(move |ins: &[std::sync::Arc<crate::storage::Block>]| {
                            let mut out =
                                crate::storage::DenseMatrix::zeros(orn, ocn);
                            for (b, &(bi, bj)) in ins.iter().zip(&coords) {
                                let d = b.to_dense()?;
                                // Intersection of this input block with the
                                // output region, in local coordinates.
                                let br0 = bi * gbs0;
                                let bc0 = bj * gbs1;
                                let ir0 = gor0.max(br0);
                                let ic0 = goc0.max(bc0);
                                let ir1 = (gor0 + orn).min(br0 + d.rows());
                                let ic1 = (goc0 + ocn).min(bc0 + d.cols());
                                if ir0 >= ir1 || ic0 >= ic1 {
                                    continue;
                                }
                                let part =
                                    d.slice(ir0 - br0, ic0 - bc0, ir1 - ir0, ic1 - ic0)?;
                                out.paste(ir0 - gor0, ic0 - goc0, &part)?;
                            }
                            Ok(vec![crate::storage::Block::Dense(out)])
                        }),
                    );
                    blocks.push(out[0]);
                }
            }
        }
        // Gather path densifies sparse inputs; keep the sparse flag only on
        // the aligned fast path.
        let aligned = r0 % bs0 == 0 && c0 % bs1 == 0;
        DsArray::from_parts(
            self.rt.clone(),
            (nr, nc),
            self.block_shape,
            blocks,
            self.sparse && aligned,
        )
    }

    fn expect_sparse_meta(&self, r: usize, c: usize) -> BlockMeta {
        let total_nnz: usize = self.blocks.iter().map(|b| b.meta.nnz).sum();
        let frac = (r * c) as f64 / (self.shape.0 * self.shape.1).max(1) as f64;
        BlockMeta::sparse(r, c, (total_nnz as f64 * frac).round() as usize)
    }

    /// Single element — synchronizes one block.
    pub fn get(&self, i: usize, j: usize) -> Result<f32> {
        if i >= self.shape.0 || j >= self.shape.1 {
            bail!("index ({i},{j}) out of bounds for {:?}", self.shape);
        }
        let (bi, bj) = (i / self.block_shape.0, j / self.block_shape.1);
        let b = self.rt.wait(self.block(bi, bj))?;
        Ok(b.to_dense()?
            .get(i - bi * self.block_shape.0, j - bj * self.block_shape.1))
    }

    /// Select arbitrary rows by index (fancy indexing). One task per output
    /// block-row, reading every input block-row it draws from.
    pub fn take_rows(&self, idx: &[usize]) -> Result<DsArray> {
        for &i in idx {
            if i >= self.shape.0 {
                bail!("row index {i} out of bounds for {} rows", self.shape.0);
            }
        }
        if idx.is_empty() {
            bail!("take_rows with empty index");
        }
        let bs0 = self.block_shape.0;
        let out_grid0 = DsArray::grid_dim(idx.len(), bs0);
        let mut blocks = Vec::new();
        for oi in 0..out_grid0 {
            let lo = oi * bs0;
            let hi = ((oi + 1) * bs0).min(idx.len());
            let rows: Vec<usize> = idx[lo..hi].to_vec();
            // Input block-rows feeding this output block-row.
            let mut needed: Vec<usize> = rows.iter().map(|&r| r / bs0).collect();
            needed.sort_unstable();
            needed.dedup();
            for oj in 0..self.grid.1 {
                let ocn = self.block_cols_at(oj);
                let futs: Vec<_> = needed.iter().map(|&bi| self.block(bi, oj)).collect();
                let needed_c = needed.clone();
                let rows_c = rows.clone();
                let meta = BlockMeta::dense(rows.len(), ocn);
                let out = self.rt.submit(
                    "dsarray.index.take_rows",
                    &futs,
                    vec![meta],
                    CostHint::default().with_bytes(meta.bytes() as f64 * 2.0),
                    std::sync::Arc::new(move |ins: &[std::sync::Arc<crate::storage::Block>]| {
                        let mut out =
                            crate::storage::DenseMatrix::zeros(rows_c.len(), ocn);
                        for (k, &gr) in rows_c.iter().enumerate() {
                            let bi = gr / bs0;
                            let pos = needed_c.binary_search(&bi).unwrap();
                            let d = ins[pos].to_dense()?;
                            let local = gr - bi * bs0;
                            out.row_mut(k).copy_from_slice(d.row(local));
                        }
                        Ok(vec![crate::storage::Block::Dense(out)])
                    }),
                );
                blocks.push(out[0]);
            }
        }
        DsArray::from_parts(
            self.rt.clone(),
            (idx.len(), self.shape.1),
            self.block_shape,
            blocks,
            false,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;

    fn setup() -> (Runtime, DenseMatrix, super::DsArray) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(9, 8, |i, j| (i * 8 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (3, 3)).unwrap();
        (rt, m, a)
    }

    #[test]
    fn aligned_and_unaligned_slices_match_reference() {
        let (_rt, m, a) = setup();
        // Aligned (single-block fast path).
        let s = a.slice(3, 6, 3, 6).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(3, 3, 3, 3).unwrap());
        // Unaligned (gather path across block boundaries).
        let s = a.slice(1, 8, 2, 7).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(1, 2, 7, 5).unwrap());
        // Full-width row slice.
        let s = a.slice_rows(2, 9).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(2, 0, 7, 8).unwrap());
        // Column slice.
        let s = a.slice_cols(1, 4).unwrap();
        assert_eq!(s.collect().unwrap(), m.slice(0, 1, 9, 3).unwrap());
    }

    #[test]
    fn invalid_slices_rejected() {
        let (_rt, _m, a) = setup();
        assert!(a.slice(5, 5, 0, 1).is_err());
        assert!(a.slice(0, 10, 0, 1).is_err());
        assert!(a.slice(0, 1, 7, 9).is_err());
    }

    #[test]
    fn get_single_elements() {
        let (_rt, m, a) = setup();
        assert_eq!(a.get(0, 0).unwrap(), m.get(0, 0));
        assert_eq!(a.get(8, 7).unwrap(), m.get(8, 7));
        assert_eq!(a.get(4, 5).unwrap(), m.get(4, 5));
        assert!(a.get(9, 0).is_err());
    }

    #[test]
    fn take_rows_matches_reference() {
        let (_rt, m, a) = setup();
        let idx = vec![8, 0, 3, 3, 5, 1, 7];
        let t = a.take_rows(&idx).unwrap();
        let got = t.collect().unwrap();
        for (k, &r) in idx.iter().enumerate() {
            assert_eq!(got.row(k), m.row(r), "row {k} (source {r})");
        }
        assert!(a.take_rows(&[9]).is_err());
        assert!(a.take_rows(&[]).is_err());
    }

    #[test]
    fn sparse_aligned_slice_stays_sparse() {
        let rt = Runtime::local(2);
        let csr = crate::storage::CsrMatrix::from_triplets(
            6,
            6,
            &[(0, 0, 1.0), (3, 3, 2.0), (5, 5, 3.0)],
        )
        .unwrap();
        let a = creation::from_csr(&rt, &csr, (3, 3)).unwrap();
        let s = a.slice(3, 6, 3, 6).unwrap();
        assert!(s.is_sparse());
        assert_eq!(
            s.collect().unwrap(),
            csr.to_dense().slice(3, 3, 3, 3).unwrap()
        );
        let u = a.slice(1, 5, 1, 5).unwrap();
        assert!(!u.is_sparse());
        assert_eq!(
            u.collect().unwrap(),
            csr.to_dense().slice(1, 1, 4, 4).unwrap()
        );
    }
}
