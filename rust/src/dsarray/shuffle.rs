//! Row shuffle (paper §5.4): redistributing rows across block-rows.
//!
//! With PyCOMPSs collection parameters a shuffle is **2N tasks** for an
//! N×M grid: N "part" tasks (each reads its block-row and emits N parts via
//! COLLECTION_OUT) and N "merge" tasks (each reads one part from every
//! source via COLLECTION_IN and emits the new block-row). The
//! no-collections variant — what the Dataset baseline is stuck with — needs
//! one task per (source, destination) pair: N²+N tasks. Both are
//! implemented here; the second feeds the ABL-COLL ablation.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future};
use crate::util::rng::Xoshiro256;

use super::DsArray;

/// Destination bookkeeping computed on the master (the permutation is
/// master-side in dislib too: task outputs must have known sizes).
struct Plan {
    /// For (source block-row i, dest block-row d): local source rows, in
    /// destination order.
    part_rows: Vec<Vec<Vec<usize>>>,
    /// For (i, d): destination-local positions of those rows.
    part_dest: Vec<Vec<Vec<usize>>>,
}

impl DsArray {
    fn shuffle_plan(&self, seed: u64) -> Plan {
        let n = self.grid.0;
        let bs0 = self.block_shape.0;
        let total = self.shape.0;
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // p[new_pos] = old_row  =>  dest[old_row] = new_pos.
        let p = rng.permutation(total);
        let mut dest = vec![0usize; total];
        for (new_pos, &old) in p.iter().enumerate() {
            dest[old] = new_pos;
        }
        let mut part_rows = vec![vec![Vec::new(); n]; n];
        let mut part_dest = vec![vec![Vec::new(); n]; n];
        for i in 0..n {
            let r0 = i * bs0;
            let rows = self.block_rows_at(i);
            // Collect (new_pos, local_row), sorted by new_pos within each dest.
            let mut by_dest: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
            for l in 0..rows {
                let np = dest[r0 + l];
                let d = np / bs0;
                by_dest[d].push((np, l));
            }
            for (d, mut v) in by_dest.into_iter().enumerate() {
                v.sort_unstable();
                part_rows[i][d] = v.iter().map(|&(_, l)| l).collect();
                part_dest[i][d] = v.iter().map(|&(np, _)| np - d * bs0).collect();
            }
        }
        Plan {
            part_rows,
            part_dest,
        }
    }

    /// Shuffle rows with collection parameters: 2N tasks (paper §4.3).
    /// Densifies sparse arrays (rows are reassembled elementwise).
    pub fn shuffle_rows(&self, seed: u64) -> Result<DsArray> {
        self.shuffle_impl(seed, true)
    }

    /// Ablation variant without collection outputs: one part task per
    /// (source, destination) pair — N²+N tasks, the pre-collections
    /// topology (paper §4.3: "2N with collections and N²+N without").
    pub fn shuffle_rows_no_collections(&self, seed: u64) -> Result<DsArray> {
        self.shuffle_impl(seed, false)
    }

    fn shuffle_impl(&self, seed: u64, collections: bool) -> Result<DsArray> {
        if self.is_lazy() {
            return self.force()?.shuffle_impl(seed, collections);
        }
        if self.shape.0 < 2 {
            bail!("shuffle needs at least 2 rows");
        }
        let n = self.grid.0;
        let gc = self.grid.1;
        let cols = self.shape.1;
        let plan = self.shuffle_plan(seed);

        // ---- Phase 1: part tasks (one batch for the whole phase) ----
        // parts[d][i] = future of the part moving from source i to dest d.
        let mut parts: Vec<Vec<Future>> = vec![Vec::with_capacity(n); n];
        let mut batch = Vec::with_capacity(if collections { n } else { n * n });
        for i in 0..n {
            let futs = self.block_row(i);
            let in_bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
            if collections {
                // One task, N collection outputs.
                let metas: Vec<BlockMeta> = (0..n)
                    .map(|d| BlockMeta::dense(plan.part_rows[i][d].len(), cols))
                    .collect();
                let rows_by_dest: Vec<Vec<usize>> = plan.part_rows[i].clone();
                batch.push(BatchTask::new(
                    "dsarray.shuffle.part",
                    futs,
                    metas,
                    CostHint::default().with_bytes(2.0 * in_bytes),
                    part_fn(rows_by_dest, cols),
                ));
            } else {
                // One task per destination.
                for d in 0..n {
                    let meta = BlockMeta::dense(plan.part_rows[i][d].len(), cols);
                    let rows_one = vec![plan.part_rows[i][d].clone()];
                    batch.push(BatchTask::new(
                        "dsarray.shuffle_nocoll.part",
                        futs.clone(),
                        vec![meta],
                        CostHint::default().with_bytes(in_bytes / n as f64 * 2.0),
                        part_fn(rows_one, cols),
                    ));
                }
            }
        }
        for (t, out) in self.rt.submit_batch(batch).into_iter().enumerate() {
            if collections {
                // Task t is source block-row t; output d goes to dest d.
                for (d, f) in out.into_iter().enumerate() {
                    parts[d].push(f);
                }
            } else {
                // Task t = (source i, dest d) in row-major order.
                parts[t % n].push(out[0]);
            }
        }

        // ---- Phase 2: merge tasks (one per destination block-row, one
        // batch for the phase; merges read part futures from phase 1) ----
        let op_name: &'static str = if collections {
            "dsarray.shuffle.merge"
        } else {
            "dsarray.shuffle_nocoll.merge"
        };
        let mut batch = Vec::with_capacity(n);
        for d in 0..n {
            let rows_d = self.block_rows_at(d);
            let futs = parts[d].clone();
            let in_bytes: f64 = futs.iter().map(|f| f.meta.bytes() as f64).sum();
            let metas: Vec<BlockMeta> = (0..gc)
                .map(|j| BlockMeta::dense(rows_d, self.block_cols_at(j)))
                .collect();
            // Destination-local position of each incoming part row, in
            // source-major order.
            let positions: Vec<Vec<usize>> = (0..n).map(|i| plan.part_dest[i][d].clone()).collect();
            let bs1 = self.block_shape.1;
            batch.push(BatchTask::new(
                op_name,
                futs,
                metas,
                CostHint::default().with_bytes(2.0 * in_bytes),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let mut panel = DenseMatrix::zeros(rows_d, cols);
                    for (part, pos) in ins.iter().zip(&positions) {
                        let p = part.to_dense()?;
                        debug_assert_eq!(p.rows(), pos.len());
                        for (k, &dst) in pos.iter().enumerate() {
                            panel.row_mut(dst).copy_from_slice(p.row(k));
                        }
                    }
                    // Split the assembled row panel into grid blocks.
                    let mut outs = Vec::new();
                    let mut c0 = 0;
                    while c0 < cols {
                        let c = (cols - c0).min(bs1);
                        outs.push(Block::Dense(panel.slice(0, c0, rows_d, c)?));
                        c0 += c;
                    }
                    Ok(outs)
                }),
            ));
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().flatten().collect();
        DsArray::from_parts(self.rt.clone(), self.shape, self.block_shape, blocks, false)
    }
}

/// Part task: read a block-row (as blocks), emit one part per destination
/// (rows in destination order, full width).
fn part_fn(rows_by_dest: Vec<Vec<usize>>, cols: usize) -> crate::tasking::TaskFn {
    Arc::new(move |ins: &[Arc<Block>]| {
        // Assemble the full-width row panel once.
        let dense: Vec<DenseMatrix> = ins
            .iter()
            .map(|b| b.to_dense())
            .collect::<Result<_>>()?;
        let refs: Vec<&DenseMatrix> = dense.iter().collect();
        let panel = DenseMatrix::hstack(&refs)?;
        debug_assert_eq!(panel.cols(), cols);
        let mut outs = Vec::with_capacity(rows_by_dest.len());
        for rows in &rows_by_dest {
            let mut part = DenseMatrix::zeros(rows.len(), cols);
            for (k, &l) in rows.iter().enumerate() {
                part.row_mut(k).copy_from_slice(panel.row(l));
            }
            outs.push(Block::Dense(part));
        }
        Ok(outs)
    })
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;

    /// Sorted rows (as tuples) for multiset comparison.
    fn row_multiset(m: &DenseMatrix) -> Vec<Vec<u32>> {
        let mut rows: Vec<Vec<u32>> = (0..m.rows())
            .map(|i| m.row(i).iter().map(|&x| x.to_bits()).collect())
            .collect();
        rows.sort();
        rows
    }

    fn setup(rows: usize, cols: usize, bs: (usize, usize)) -> (Runtime, DenseMatrix, super::DsArray) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(rows, cols, |i, j| (i * cols + j) as f32);
        let a = creation::from_matrix(&rt, &m, bs).unwrap();
        (rt, m, a)
    }

    #[test]
    fn shuffle_preserves_row_multiset() {
        let (_rt, m, a) = setup(10, 6, (3, 2));
        let s = a.shuffle_rows(99).unwrap();
        let got = s.collect().unwrap();
        assert_eq!(row_multiset(&got), row_multiset(&m));
        assert_ne!(got, m, "seeded shuffle should move rows");
    }

    #[test]
    fn shuffle_task_count_is_2n() {
        let (rt, _m, a) = setup(12, 4, (3, 2)); // N = 4 block rows
        let before = rt.metrics();
        a.shuffle_rows(1).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dsarray.shuffle.part"), 4);
        assert_eq!(d.tasks_for("dsarray.shuffle.merge"), 4);
        assert_eq!(d.total_tasks(), 8); // 2N
    }

    #[test]
    fn no_collections_variant_same_result_more_tasks() {
        let (rt, m, a) = setup(12, 4, (3, 2)); // N = 4
        let s1 = a.shuffle_rows(7).unwrap().collect().unwrap();
        let before = rt.metrics();
        let s2 = a.shuffle_rows_no_collections(7).unwrap();
        let d = rt.metrics().since(&before);
        // N² part tasks + N merge tasks.
        assert_eq!(d.tasks_for("dsarray.shuffle_nocoll.part"), 16);
        assert_eq!(d.tasks_for("dsarray.shuffle_nocoll.merge"), 4);
        let s2 = s2.collect().unwrap();
        // Same seed => identical permutation either way.
        assert_eq!(s1, s2);
        assert_eq!(row_multiset(&s1), row_multiset(&m));
    }

    #[test]
    fn shuffle_is_seed_deterministic() {
        let (_rt, _m, a) = setup(9, 3, (2, 3));
        let s1 = a.shuffle_rows(5).unwrap().collect().unwrap();
        let s2 = a.shuffle_rows(5).unwrap().collect().unwrap();
        let s3 = a.shuffle_rows(6).unwrap().collect().unwrap();
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn shuffle_multi_column_grid() {
        let (_rt, m, a) = setup(8, 9, (2, 4)); // 4x3 grid
        let s = a.shuffle_rows(3).unwrap();
        assert_eq!(s.shape(), (8, 9));
        assert_eq!(s.grid(), (4, 3));
        // Rows stay intact across the full width (no column mixing).
        assert_eq!(row_multiset(&s.collect().unwrap()), row_multiset(&m));
    }
}
