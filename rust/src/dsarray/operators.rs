//! `std::ops` operator overloads for dense ds-arrays — `&a + &b`,
//! `&a - &b`, `&a * &b` (elementwise), and the scalar forms `&a + 2.0`,
//! `&a * 2.0`, `2.0 * &a`, plus unary `-&a`.
//!
//! Every operator delegates to the deferred elementwise engine
//! ([`DsArray::add`], [`DsArray::mul_scalar`], …), so chained operator
//! expressions build one pending expression and fuse to a single task per
//! block at [`DsArray::force`] / [`DsArray::collect`] — and, at
//! [`crate::plan::Level::Full`], a unary epilogue on a pending matmul
//! grafts into the gemm tiles instead of spawning its own pass.
//!
//! Following the standard library's convention for infallible operator
//! syntax over fallible methods (`Index` panics on out-of-bounds), these
//! impls **panic** on shape mismatch or sparse inputs; use the named
//! methods when you need a `Result`.
//!
//! Operands are borrowed (`&a + &b`), never consumed: a ds-array is a
//! handle to distributed blocks, and the expression engine retains the
//! operand grids it closes over.
//!
//! ```
//! use rustdslib::{dsarray::creation, tasking::Runtime};
//! let rt = Runtime::local(2);
//! let a = creation::random(&rt, (8, 8), (4, 4), 1).unwrap();
//! let b = creation::random(&rt, (8, 8), (4, 4), 2).unwrap();
//! let c = &(&a + &b) * 0.5 + 1.0; // deferred: zero tasks so far
//! let got = c.collect().unwrap();
//! let want = a
//!     .add(&b)
//!     .unwrap()
//!     .mul_scalar(0.5)
//!     .unwrap()
//!     .add_scalar(1.0)
//!     .unwrap()
//!     .collect()
//!     .unwrap();
//! assert_eq!(got, want);
//! ```

use std::ops::{Add, Mul, Neg, Sub};

use super::DsArray;

impl Add<&DsArray> for &DsArray {
    type Output = DsArray;
    fn add(self, rhs: &DsArray) -> DsArray {
        DsArray::add(self, rhs).expect("`a + b` on mismatched or sparse ds-arrays")
    }
}

impl Sub<&DsArray> for &DsArray {
    type Output = DsArray;
    fn sub(self, rhs: &DsArray) -> DsArray {
        DsArray::sub(self, rhs).expect("`a - b` on mismatched or sparse ds-arrays")
    }
}

/// Elementwise (Hadamard) product — matrix multiplication stays the
/// explicit [`DsArray::matmul`], as in NumPy (`*` vs `@`).
impl Mul<&DsArray> for &DsArray {
    type Output = DsArray;
    fn mul(self, rhs: &DsArray) -> DsArray {
        DsArray::mul(self, rhs).expect("`a * b` on mismatched or sparse ds-arrays")
    }
}

impl Add<f32> for &DsArray {
    type Output = DsArray;
    fn add(self, s: f32) -> DsArray {
        self.add_scalar(s).expect("`a + s` on a sparse ds-array")
    }
}

impl Sub<f32> for &DsArray {
    type Output = DsArray;
    fn sub(self, s: f32) -> DsArray {
        self.add_scalar(-s).expect("`a - s` on a sparse ds-array")
    }
}

impl Mul<f32> for &DsArray {
    type Output = DsArray;
    fn mul(self, s: f32) -> DsArray {
        self.mul_scalar(s).expect("`a * s` on a sparse ds-array")
    }
}

impl Add<&DsArray> for f32 {
    type Output = DsArray;
    fn add(self, a: &DsArray) -> DsArray {
        a.add_scalar(self).expect("`s + a` on a sparse ds-array")
    }
}

impl Mul<&DsArray> for f32 {
    type Output = DsArray;
    fn mul(self, a: &DsArray) -> DsArray {
        a.mul_scalar(self).expect("`s * a` on a sparse ds-array")
    }
}

impl Neg for &DsArray {
    type Output = DsArray;
    fn neg(self) -> DsArray {
        DsArray::neg(self).expect("`-a` on a sparse ds-array")
    }
}

// Owned-value forms so chained expressions (`&a + &b` yields an owned
// DsArray) keep composing without intermediate bindings.
impl Add<f32> for DsArray {
    type Output = DsArray;
    fn add(self, s: f32) -> DsArray {
        &self + s
    }
}

impl Sub<f32> for DsArray {
    type Output = DsArray;
    fn sub(self, s: f32) -> DsArray {
        &self - s
    }
}

impl Mul<f32> for DsArray {
    type Output = DsArray;
    fn mul(self, s: f32) -> DsArray {
        &self * s
    }
}

impl Neg for DsArray {
    type Output = DsArray;
    fn neg(self) -> DsArray {
        -&self
    }
}

#[cfg(test)]
mod tests {
    use crate::dsarray::creation;
    use crate::tasking::Runtime;

    #[test]
    fn operators_defer_and_match_named_methods() {
        let rt = Runtime::local(2);
        let a = creation::random(&rt, (6, 6), (3, 3), 7).unwrap();
        let b = creation::random(&rt, (6, 6), (3, 3), 8).unwrap();
        let before = rt.metrics().total_tasks();
        let c = &(&a - &b) * 2.0 + 1.0;
        assert_eq!(
            rt.metrics().total_tasks(),
            before,
            "operator chain must stay deferred"
        );
        let got = c.collect().unwrap();
        let want = a
            .sub(&b)
            .unwrap()
            .mul_scalar(2.0)
            .unwrap()
            .add_scalar(1.0)
            .unwrap()
            .collect()
            .unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn scalar_left_forms_and_neg() {
        let rt = Runtime::local(1);
        let a = creation::identity(&rt, 4, (2, 2)).unwrap();
        assert_eq!((2.0 * &a).collect().unwrap().get(0, 0), 2.0);
        assert_eq!((1.0 + &a).collect().unwrap().get(0, 1), 1.0);
        assert_eq!((-&a).collect().unwrap().get(2, 2), -1.0);
        assert_eq!((&a * &a).collect().unwrap().get(3, 3), 1.0);
    }

    #[test]
    #[should_panic(expected = "mismatched")]
    fn shape_mismatch_panics() {
        let rt = Runtime::local(1);
        let a = creation::zeros(&rt, (4, 4), (2, 2)).unwrap();
        let b = creation::zeros(&rt, (4, 2), (2, 2)).unwrap();
        let _ = &a + &b;
    }
}
