//! The fused lazy elementwise expression engine (§Perf optimization).
//!
//! Elementwise operators used to submit one task and allocate one full
//! intermediate block per op per block, so a standardize chain like
//! `(x − μ) / σ` paid 3× the tasks and 3× the allocations it needed. This
//! module makes the elementwise layer *deferred*: scalar ops, unary maps,
//! array∘array ops and row-broadcasts attach an [`ExprSpec`] DAG to the
//! `DsArray` (mirroring the view layer's `ViewSpec` pattern) and submit
//! **zero tasks**. The whole chain collapses to exactly one fused task per
//! block when something consumes the array ([`DsArray::force`], `collect`,
//! or any operation that needs canonical blocks).
//!
//! Fused tasks are *ownership-aware* (`TaskBody::Owned`): at claim time the
//! executor hands over any input block it can prove no other reader,
//! handle, or pin will ever need again (the refcount-reclamation condition,
//! with the claiming read outstanding), and the evaluator then mutates that
//! buffer **in place** through the entire chain — zero allocations. Inputs
//! still referenced elsewhere are copied exactly once (copy-on-write), so a
//! parent array that is still alive is never mutated. `Metrics` counts the
//! effect end-to-end: `tasks_fused` (submissions avoided), `inplace_hits`
//! (exclusive grants) and `bytes_allocated` (fresh output bytes).
//!
//! Materialization is memoized: the first `force` stores the canonical
//! result in the expression's shared state, so repeated consumers of one
//! deferred chain execute it once. At that point the expression releases
//! its own handle references early (the fused tasks hold reads on every
//! operand, so nothing can be evicted prematurely) — which is exactly what
//! lets a dead intermediate's blocks be granted in place.
//!
//! Since the kernel-layer PR, expression nodes carry closed op *kinds*
//! ([`UnaryKind`]/[`BinaryKind`]) instead of boxed closures: the evaluator
//! interprets each chain over SIMD lanes through the [`Kernels`] vtable the
//! `Runtime` resolved once at startup (captured at submission time — no
//! per-block feature detection), and each op pass may split across the
//! executor's deques via `kernels::{unary,binary,bcast}_par` while
//! preserving the in-place `take_exclusive` path unchanged.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::kernels::{self, BinaryKind, Kernels, UnaryKind};
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future, TaskInput};

use super::DsArray;

/// How an operand's block grid maps onto the result grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum OperandKind {
    /// Same grid as the result: fused task (i, j) reads block (i, j).
    Full,
    /// A 1×cols row array on a 1×gc grid: task (i, j) reads block (0, j).
    Row,
}

/// One leaf array of a deferred expression, with its per-block futures.
#[derive(Clone)]
pub(crate) struct Operand {
    pub blocks: Vec<Future>,
    pub kind: OperandKind,
}

/// A node of the deferred scalar-expression DAG. Leaves reference operand
/// slots; every slot is referenced exactly once (a repeated array appears
/// as separate slots), which lets evaluation consume inputs by move.
pub(crate) enum ExprNode {
    Input(usize),
    Map {
        op: UnaryKind,
        child: Arc<ExprNode>,
    },
    Zip {
        op: BinaryKind,
        lhs: Arc<ExprNode>,
        rhs: Arc<ExprNode>,
    },
    /// Row broadcast: `rhs` must evaluate to a 1×cols block, combined with
    /// every row of `lhs`.
    Bcast {
        op: BinaryKind,
        lhs: Arc<ExprNode>,
        rhs: Arc<ExprNode>,
    },
}

/// Mutable shared state of one logical expression (shared by clones of the
/// deferred array).
#[derive(Default)]
pub(crate) struct ExprState {
    /// Memoized materialization: filled by the first `force`, reused by
    /// later consumers so a chain executes once.
    pub forced: Option<DsArray>,
    /// Set when `force` released this expression's handle references early
    /// (enabling in-place grants); exactly one subsequent `Drop` consumes
    /// the credit instead of releasing again.
    pub release_credit: bool,
}

/// Deferred elementwise expression carried by a [`DsArray`] — the op-layer
/// twin of the view layer's `ViewSpec`.
#[derive(Clone)]
pub(crate) struct ExprSpec {
    /// Operands beyond the base array (`DsArray::blocks` is slot 0);
    /// `extra[k]` is slot `k + 1`.
    pub extra: Vec<Operand>,
    pub root: Arc<ExprNode>,
    /// Logical elementwise ops folded into this expression.
    pub n_ops: usize,
    pub state: Arc<Mutex<ExprState>>,
}

/// Rebuild `node` with every input slot shifted by `by` (composing two
/// expressions into one operand list).
fn shift_slots(node: &Arc<ExprNode>, by: usize) -> Arc<ExprNode> {
    if by == 0 {
        return Arc::clone(node);
    }
    match &**node {
        ExprNode::Input(s) => Arc::new(ExprNode::Input(s + by)),
        ExprNode::Map { op, child } => Arc::new(ExprNode::Map {
            op: *op,
            child: shift_slots(child, by),
        }),
        ExprNode::Zip { op, lhs, rhs } => Arc::new(ExprNode::Zip {
            op: *op,
            lhs: shift_slots(lhs, by),
            rhs: shift_slots(rhs, by),
        }),
        ExprNode::Bcast { op, lhs, rhs } => Arc::new(ExprNode::Bcast {
            op: *op,
            lhs: shift_slots(lhs, by),
            rhs: shift_slots(rhs, by),
        }),
    }
}

/// Evaluate the DAG over one block's inputs. Each leaf consumes its slot by
/// move: an exclusively-owned dense input becomes the working buffer with
/// zero copies, and every interior node mutates that buffer in place — the
/// whole chain costs at most one allocation (none when the base input was
/// granted owned). Op passes run through `ker`'s lane kernels and may split
/// across the executor's deques when the block is long.
fn eval(
    ker: &'static Kernels,
    node: &ExprNode,
    slots: &mut [Option<TaskInput>],
) -> Result<DenseMatrix> {
    match node {
        ExprNode::Input(s) => {
            let inp = slots
                .get_mut(*s)
                .and_then(|slot| slot.take())
                .ok_or_else(|| anyhow!("expression slot {s} missing or consumed twice"))?;
            inp.into_dense()
        }
        ExprNode::Map { op, child } => {
            let mut m = eval(ker, child, slots)?;
            kernels::unary_par(ker, *op, m.data_mut());
            Ok(m)
        }
        ExprNode::Zip { op, lhs, rhs } => {
            let mut a = eval(ker, lhs, slots)?;
            combine_into(ker, &mut a, *op, rhs, slots, false)?;
            Ok(a)
        }
        ExprNode::Bcast { op, lhs, rhs } => {
            let mut a = eval(ker, lhs, slots)?;
            combine_into(ker, &mut a, *op, rhs, slots, true)?;
            Ok(a)
        }
    }
}

/// Fold the rhs of a zip/broadcast into `a` in place. The rhs is only ever
/// *read*, so a leaf rhs borrows its dense payload straight from the input
/// block — no copy — keeping a fused zip between two live parents at
/// exactly one allocation (the lhs working buffer), same as the eager path
/// it replaces. Interior rhs nodes evaluate recursively.
fn combine_into(
    ker: &'static Kernels,
    a: &mut DenseMatrix,
    op: BinaryKind,
    rhs: &ExprNode,
    slots: &mut [Option<TaskInput>],
    bcast: bool,
) -> Result<()> {
    if let ExprNode::Input(s) = rhs {
        let inp = slots
            .get_mut(*s)
            .and_then(|slot| slot.take())
            .ok_or_else(|| anyhow!("expression slot {s} missing or consumed twice"))?;
        return match inp.block() {
            Block::Dense(m) => apply_rhs(ker, a, op, m, bcast),
            other => apply_rhs(ker, a, op, &other.to_dense()?, bcast),
        };
    }
    let b = eval(ker, rhs, slots)?;
    apply_rhs(ker, a, op, &b, bcast)
}

/// Apply `a[i][j] = op(a[i][j], b[...])` element-wise (`bcast`: `b` is a
/// 1×cols row combined with every row of `a`).
fn apply_rhs(
    ker: &'static Kernels,
    a: &mut DenseMatrix,
    op: BinaryKind,
    b: &DenseMatrix,
    bcast: bool,
) -> Result<()> {
    if bcast {
        if b.rows() != 1 || b.cols() != a.cols() {
            bail!(
                "fused broadcast needs a 1x{} row, got {}x{}",
                a.cols(),
                b.rows(),
                b.cols()
            );
        }
        let cols = a.cols();
        kernels::bcast_par(ker, op, a.data_mut(), cols, b.data());
        return Ok(());
    }
    if a.rows() != b.rows() || a.cols() != b.cols() {
        bail!(
            "fused zip shape mismatch: {}x{} vs {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        );
    }
    kernels::binary_par(ker, op, a.data_mut(), b.data());
    Ok(())
}

impl DsArray {
    /// Whether this array carries a deferred elementwise expression that
    /// has not been consumed yet (see [`DsArray::force`]).
    ///
    /// Elementwise chains on dense arrays submit zero tasks until consumed;
    /// they materialize as exactly one fused task per block:
    ///
    /// ```
    /// use rustdslib::{dsarray::creation, tasking::Runtime};
    /// let rt = Runtime::local(2);
    /// let a = creation::random(&rt, (8, 8), (4, 4), 1).unwrap();
    /// let chain = a.add_scalar(1.0).unwrap().sqrt().unwrap();
    /// assert!(chain.is_deferred()); // zero tasks so far
    /// let owned = chain.force().unwrap(); // one fused task per block
    /// assert!(!owned.is_deferred());
    /// // Materialization is memoized: re-consuming the chain is free.
    /// assert_eq!(chain.force().unwrap().block(0, 0), owned.block(0, 0));
    /// ```
    pub fn is_deferred(&self) -> bool {
        self.expr.is_some()
    }

    /// Whether consuming this array requires materialization first — a
    /// lazy view, a deferred elementwise expression, or a deferred gemm
    /// plan (`crate::plan`, optimizer `Level::Full`).
    pub fn is_lazy(&self) -> bool {
        self.view.is_some() || self.expr.is_some() || self.gemm.is_some()
    }

    /// Snapshot this array as expression operands rooted at slot `slot0`,
    /// retaining one handle reference per block on behalf of the new
    /// expression. Already-materialized expressions snapshot their cached
    /// canonical result instead (extending a consumed chain must read the
    /// result, not re-read possibly-reclaimed sources); the check and the
    /// retains run under the expression's state lock, serializing against a
    /// concurrent `force`'s early release.
    fn expr_parts(&self, slot0: usize, kind: OperandKind) -> (Vec<Operand>, Arc<ExprNode>, usize) {
        // Deferred gemm arrays have no block grid to snapshot; every lazy
        // entry point forces (or grafts) them before reaching here.
        debug_assert!(self.gemm.is_none(), "expr_parts on a deferred gemm array");
        if let Some(expr) = &self.expr {
            let st = expr.state.lock().unwrap();
            if let Some(f) = &st.forced {
                let f = f.clone();
                drop(st);
                return f.expr_parts(slot0, kind);
            }
            self.rt.retain(&self.blocks);
            for op in &expr.extra {
                self.rt.retain(&op.blocks);
            }
            let mut ops = Vec::with_capacity(1 + expr.extra.len());
            ops.push(Operand {
                blocks: self.blocks.clone(),
                kind,
            });
            // A row array used as a broadcast operand narrows ALL of its
            // own operands to Row (they live on its 1×gc grid).
            ops.extend(expr.extra.iter().map(|op| Operand {
                blocks: op.blocks.clone(),
                kind: if kind == OperandKind::Row {
                    OperandKind::Row
                } else {
                    op.kind
                },
            }));
            (ops, shift_slots(&expr.root, slot0), expr.n_ops)
        } else {
            self.rt.retain(&self.blocks);
            (
                vec![Operand {
                    blocks: self.blocks.clone(),
                    kind,
                }],
                Arc::new(ExprNode::Input(slot0)),
                0,
            )
        }
    }

    /// Assemble a deferred-expression array over pre-retained operands
    /// (callers snapshot operands via [`DsArray::expr_parts`], which
    /// retains). Geometry is inherited from `self`.
    fn from_lazy(&self, operands: Vec<Operand>, root: Arc<ExprNode>, n_ops: usize) -> DsArray {
        let mut it = operands.into_iter();
        let base = it.next().expect("expression has a base operand");
        DsArray {
            rt: self.rt.clone(),
            shape: self.shape,
            block_shape: self.block_shape,
            grid: self.grid,
            blocks: base.blocks,
            sparse: false,
            view: None,
            expr: Some(ExprSpec {
                extra: it.collect(),
                root,
                n_ops,
                state: Arc::default(),
            }),
            gemm: None,
        }
    }

    /// Defer a unary elementwise map: zero tasks now, folded into one fused
    /// task per block at consume time. Sparse arrays take the eager per-op
    /// path instead (preserving the CSR backend and its zero-preserving-map
    /// check); lazy views are forced first.
    pub(crate) fn map_lazy(&self, name: &'static str, op: UnaryKind) -> Result<DsArray> {
        if self.sparse {
            return self.map_blocks_eager(name, move |x| op.apply(x));
        }
        if self.view.is_some() {
            return self.force()?.map_lazy(name, op);
        }
        if let Some(g) = &self.gemm {
            // Epilogue grafting (the plan layer): fold the elementwise op
            // into the pending gemm's output tiles while they are cache-hot
            // instead of spawning a separate pass. The check and the operand
            // retains run under the spec's state lock, serializing against a
            // concurrent force's early release (mirrors `expr_parts`).
            let st = g.state.lock().unwrap();
            if st.forced.is_none() && self.rt.planner().fuse_enabled() {
                let mut spec = g.clone();
                spec.epilogue.push(op);
                spec.state = Arc::default();
                self.rt.retain(&spec.a);
                self.rt.retain(&spec.b);
                drop(st);
                return Ok(DsArray::from_gemm(self.rt.clone(), spec));
            }
            let forced = st.forced.clone();
            drop(st);
            return match forced {
                Some(f) => f.map_lazy(name, op),
                None => self.force()?.map_lazy(name, op),
            };
        }
        let (ops, root, n) = self.expr_parts(0, OperandKind::Full);
        let root = Arc::new(ExprNode::Map { op, child: root });
        Ok(self.from_lazy(ops, root, n + 1))
    }

    /// Defer a binary elementwise op over two same-geometry dense arrays;
    /// both sides' pending expressions fold into one DAG.
    pub(crate) fn zip_lazy(&self, other: &DsArray, op: BinaryKind) -> Result<DsArray> {
        // Deferred gemm operands materialize first: a binary op cannot be
        // grafted as a gemm epilogue (it would read a second grid mid-tile).
        if self.gemm.is_some() {
            return self.force()?.zip_lazy(other, op);
        }
        if other.gemm.is_some() {
            return self.zip_lazy(&other.force()?, op);
        }
        let (mut ops, lroot, ln) = self.expr_parts(0, OperandKind::Full);
        let (rops, rroot, rn) = other.expr_parts(ops.len(), OperandKind::Full);
        ops.extend(rops);
        let root = Arc::new(ExprNode::Zip {
            op,
            lhs: lroot,
            rhs: rroot,
        });
        Ok(self.from_lazy(ops, root, ln + rn + 1))
    }

    /// Defer a row-broadcast op (`self ∘ row` per column); the row array's
    /// own pending expression folds in too.
    pub(crate) fn bcast_lazy(&self, row: &DsArray, op: BinaryKind) -> Result<DsArray> {
        if self.gemm.is_some() {
            return self.force()?.bcast_lazy(row, op);
        }
        if row.gemm.is_some() {
            return self.bcast_lazy(&row.force()?, op);
        }
        let (mut ops, lroot, ln) = self.expr_parts(0, OperandKind::Full);
        let (rops, rroot, rn) = row.expr_parts(ops.len(), OperandKind::Row);
        ops.extend(rops);
        let root = Arc::new(ExprNode::Bcast {
            op,
            lhs: lroot,
            rhs: rroot,
        });
        Ok(self.from_lazy(ops, root, ln + rn + 1))
    }

    /// Materialize a deferred expression: exactly one fused ownership-aware
    /// task per block, submitted as one batch. Memoized — repeated
    /// consumers of the same deferred array share the first result.
    pub(crate) fn force_expr(&self) -> Result<DsArray> {
        let expr = self.expr.as_ref().expect("force_expr on expression arrays only");
        let mut st = expr.state.lock().unwrap();
        if let Some(f) = &st.forced {
            return Ok(f.clone());
        }
        let (gr, gc) = self.grid;
        let n_slots = 1 + expr.extra.len();
        // The vtable was resolved once at Runtime construction; capturing
        // it here means the per-block closures never re-run feature
        // detection (satellite: no per-task dispatch).
        let ker = self.rt.kernels();
        let mut batch = Vec::with_capacity(gr * gc);
        for i in 0..gr {
            for j in 0..gc {
                let base = self.blocks[i * gc + j];
                let mut reads = Vec::with_capacity(n_slots);
                reads.push(base);
                for op in &expr.extra {
                    reads.push(match op.kind {
                        OperandKind::Full => op.blocks[i * gc + j],
                        OperandKind::Row => op.blocks[j],
                    });
                }
                let meta = BlockMeta::dense(base.meta.rows, base.meta.cols);
                let bytes: f64 = reads.iter().map(|r| r.meta.bytes() as f64).sum();
                let flops = (expr.n_ops * meta.rows * meta.cols) as f64;
                let root = Arc::clone(&expr.root);
                batch.push(
                    BatchTask::new_owned(
                        "dsarray.ew.fused",
                        reads,
                        vec![meta],
                        CostHint::flops(flops).with_bytes(bytes),
                        Arc::new(move |ins: Vec<TaskInput>| {
                            kernels::record_hit(ker);
                            let mut slots: Vec<Option<TaskInput>> =
                                ins.into_iter().map(Some).collect();
                            let out = eval(ker, &root, &mut slots)?;
                            Ok(vec![Block::Dense(out)])
                        }),
                    )
                    .with_fused_ops(expr.n_ops as u32),
                );
            }
        }
        // Early release, atomic with the submission: the fused tasks'
        // reads register before this expression's handle references drop,
        // so nothing is evicted prematurely — and no claim ever observes
        // the stale handles, which makes in-place grants for dead operands
        // deterministic. One future Drop consumes the credit.
        let mut release: Vec<Future> = self.blocks.clone();
        for op in &expr.extra {
            release.extend_from_slice(&op.blocks);
        }
        let blocks: Vec<Future> = self
            .rt
            .submit_batch_releasing(batch, &release)
            .into_iter()
            .map(|v| v[0])
            .collect();
        // Credit is armed as soon as the handles are gone, so a failure
        // below can never lead Drop to double-release.
        st.release_credit = true;
        let out =
            DsArray::from_parts(self.rt.clone(), self.shape, self.block_shape, blocks, false)?;
        st.forced = Some(out.clone());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use super::*;
    use crate::tasking::Runtime;

    fn setup() -> (Runtime, DenseMatrix, DsArray) {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(6, 8, |i, j| (i as f32 - 2.5) * 0.5 + j as f32);
        let a = creation::from_matrix(&rt, &m, (2, 3)).unwrap();
        (rt, m, a)
    }

    #[test]
    fn deferred_ops_submit_zero_tasks_until_forced() {
        let (rt, m, a) = setup();
        let before = rt.metrics();
        let chain = a
            .add_scalar(1.0)
            .unwrap()
            .mul_scalar(0.5)
            .unwrap()
            .sqrt()
            .unwrap();
        assert!(chain.is_deferred());
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        let forced = chain.force().unwrap();
        let d = rt.metrics().since(&before);
        // Exactly one fused task per block, crediting 2 fused-away ops each.
        assert_eq!(d.total_tasks(), a.n_blocks() as u64);
        assert_eq!(d.tasks_for("dsarray.ew.fused"), a.n_blocks() as u64);
        assert_eq!(d.tasks_fused, 2 * a.n_blocks() as u64);
        let want = m.map(|x| ((x + 1.0) * 0.5).sqrt());
        assert!(forced.collect().unwrap().max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn force_is_memoized_and_extension_reads_the_cache() {
        let (rt, m, a) = setup();
        let chain = a.add_scalar(2.0).unwrap();
        let f1 = chain.force().unwrap();
        let before = rt.metrics();
        let f2 = chain.force().unwrap();
        // Second force: zero tasks, same blocks.
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        assert_eq!(f1.block(0, 0), f2.block(0, 0));
        // Extending an already-consumed chain must base itself on the
        // cached result (the sources may have been reclaimed in place).
        let ext = chain.mul_scalar(3.0).unwrap();
        let got = ext.collect().unwrap();
        assert!(got.max_abs_diff(&m.map(|x| (x + 2.0) * 3.0)) < 1e-5);
    }

    #[test]
    fn live_parent_is_never_mutated_in_place() {
        let (rt, m, a) = setup();
        let chain = a.add_scalar(100.0).unwrap();
        let before = rt.metrics();
        let forced = chain.force().unwrap();
        rt.barrier().unwrap();
        // `a` is still alive: its blocks stay shared, no in-place grant.
        assert_eq!(rt.metrics().since(&before).inplace_hits, 0);
        assert_eq!(a.collect().unwrap(), m);
        assert!(forced.collect().unwrap().max_abs_diff(&m.map(|x| x + 100.0)) < 1e-5);
    }

    #[test]
    fn dead_intermediates_execute_in_place() {
        let (rt, _m, a) = setup();
        // Materialize a fresh generation owned only by `tmp`, chain over
        // it, drop it: the fused tasks must be granted every block.
        let tmp = a.add_scalar(1.0).unwrap().force().unwrap();
        rt.barrier().unwrap();
        let n = tmp.n_blocks() as u64;
        let chain = tmp.mul_scalar(2.0).unwrap();
        drop(tmp);
        let before = rt.metrics();
        let out = chain.force().unwrap();
        out.runtime().barrier().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.inplace_hits, n, "every dead input granted in place");
        // In-place execution allocates no fresh output bytes.
        assert_eq!(d.bytes_allocated, 0);
    }

    #[test]
    fn zip_and_broadcast_fuse_into_one_task() {
        let (rt, m, a) = setup();
        let n = DenseMatrix::from_fn(6, 8, |i, j| (i + 2 * j) as f32 + 1.0);
        let b = creation::from_matrix(&rt, &n, (2, 3)).unwrap();
        let row = DenseMatrix::from_fn(1, 8, |_, j| j as f32 * 0.25 + 1.0);
        let r = creation::from_matrix(&rt, &row, (1, 3)).unwrap();
        let before = rt.metrics();
        // ((a + 1) * b − row) / 2 : four logical ops, one task per block.
        let expr = a
            .add_scalar(1.0)
            .unwrap()
            .mul(&b)
            .unwrap()
            .sub_row_broadcast(&r)
            .unwrap()
            .mul_scalar(0.5)
            .unwrap();
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        let got = expr.collect().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.total_tasks(), a.n_blocks() as u64);
        assert_eq!(d.tasks_fused, 3 * a.n_blocks() as u64);
        let want = DenseMatrix::from_fn(6, 8, |i, j| {
            ((m.get(i, j) + 1.0) * n.get(i, j) - row.get(0, j)) * 0.5
        });
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn fusion_composes_with_lazy_views_and_split() {
        let (_rt, m, a) = setup();
        // Unaligned view → one gather per block, then the chain fuses.
        let v = a.slice(1, 6, 1, 7).unwrap();
        assert!(v.is_view());
        let got = v
            .add_scalar(-1.0)
            .unwrap()
            .pow(2.0)
            .unwrap()
            .collect()
            .unwrap();
        let want = m.slice(1, 1, 5, 6).unwrap().map(|x| (x - 1.0) * (x - 1.0));
        assert!(got.max_abs_diff(&want) < 1e-4);
        // train_test_split views feed fused chains too.
        let (train, test) = a.train_test_split(0.25, 7).unwrap();
        let t = train.mul_scalar(2.0).unwrap().collect().unwrap();
        let want = train.collect().unwrap().map(|x| x * 2.0);
        assert!(t.max_abs_diff(&want) < 1e-5);
        let t = test.neg().unwrap().collect().unwrap();
        assert!(t.max_abs_diff(&test.collect().unwrap().map(|x| -x)) < 1e-5);
    }

    #[test]
    fn self_zip_and_shared_operands_stay_correct() {
        let (rt, m, a) = setup();
        // a ⊙ a through one deferred expression: duplicate operand slots
        // must not trigger an in-place grant (pending_reads = 2 per block).
        let sq = a.mul(&a).unwrap().collect().unwrap();
        assert!(sq.max_abs_diff(&m.map(|x| x * x)) < 1e-4);
        assert_eq!(a.collect().unwrap(), m);
        // Same with a dead duplicated operand: both reads resolve shared,
        // the value is read consistently, nothing is granted twice.
        let tmp = a.add_scalar(1.0).unwrap().force().unwrap();
        rt.barrier().unwrap();
        let z = tmp.mul(&tmp).unwrap();
        drop(tmp);
        let got = z.collect().unwrap();
        let want = m.map(|x| (x + 1.0) * (x + 1.0));
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn deep_chain_fuses_to_single_pass() {
        let (rt, m, a) = setup();
        let mut cur = a.clone();
        for _ in 0..60 {
            cur = cur.add_scalar(1.0).unwrap();
        }
        let before = rt.metrics();
        let got = cur.collect().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.total_tasks(), a.n_blocks() as u64);
        assert_eq!(d.tasks_fused, 59 * a.n_blocks() as u64);
        assert_eq!(got, m.map(|x| x + 60.0));
    }
}
