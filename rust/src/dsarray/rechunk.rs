//! Re-blocking: change an array's block size (one gather task per output
//! block). Datasets fix the partitioning at load time; ds-arrays can adapt
//! it to the access pattern (paper §4.2 — "blocks of an arbitrary size").

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future};

use super::DsArray;

impl DsArray {
    /// Return a new ds-array with the same contents and a different block
    /// size. One task per output block, reading the overlapping inputs.
    pub fn rechunk(&self, new_block: (usize, usize)) -> Result<DsArray> {
        if new_block.0 == 0 || new_block.1 == 0 {
            bail!("empty block shape {new_block:?}");
        }
        if self.is_lazy() {
            // Materialize first: rechunk always yields a canonical array.
            return self.force()?.rechunk(new_block);
        }
        if new_block == self.block_shape {
            return Ok(self.clone());
        }
        let (bs0, bs1) = self.block_shape;
        let grid = (
            DsArray::grid_dim(self.shape.0, new_block.0),
            DsArray::grid_dim(self.shape.1, new_block.1),
        );
        // One gather task per output block, submitted as one batch.
        let mut batch = Vec::with_capacity(grid.0 * grid.1);
        for oi in 0..grid.0 {
            let or0 = oi * new_block.0;
            let orn = (self.shape.0 - or0).min(new_block.0);
            for oj in 0..grid.1 {
                let oc0 = oj * new_block.1;
                let ocn = (self.shape.1 - oc0).min(new_block.1);
                let bi0 = or0 / bs0;
                let bi1 = (or0 + orn - 1) / bs0;
                let bj0 = oc0 / bs1;
                let bj1 = (oc0 + ocn - 1) / bs1;
                let mut futs = Vec::new();
                let mut coords = Vec::new();
                for bi in bi0..=bi1 {
                    for bj in bj0..=bj1 {
                        futs.push(self.block(bi, bj));
                        coords.push((bi, bj));
                    }
                }
                let meta = BlockMeta::dense(orn, ocn);
                batch.push(BatchTask::new(
                    "dsarray.rechunk.block",
                    futs,
                    vec![meta],
                    CostHint::default().with_bytes(2.0 * meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let mut out = DenseMatrix::zeros(orn, ocn);
                        for (b, &(bi, bj)) in ins.iter().zip(&coords) {
                            let d = b.to_dense()?;
                            let br0 = bi * bs0;
                            let bc0 = bj * bs1;
                            let ir0 = or0.max(br0);
                            let ic0 = oc0.max(bc0);
                            let ir1 = (or0 + orn).min(br0 + d.rows());
                            let ic1 = (oc0 + ocn).min(bc0 + d.cols());
                            if ir0 >= ir1 || ic0 >= ic1 {
                                continue;
                            }
                            let part = d.slice(ir0 - br0, ic0 - bc0, ir1 - ir0, ic1 - ic0)?;
                            out.paste(ir0 - or0, ic0 - oc0, &part)?;
                        }
                        Ok(vec![Block::Dense(out)])
                    }),
                ));
            }
        }
        let blocks: Vec<Future> = self.rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(self.rt.clone(), self.shape, new_block, blocks, false)
    }
}

#[cfg(test)]
mod tests {
    use super::super::creation;
    use crate::storage::DenseMatrix;
    use crate::tasking::Runtime;

    #[test]
    fn rechunk_preserves_contents() {
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(10, 9, |i, j| (i * 9 + j) as f32);
        let a = creation::from_matrix(&rt, &m, (3, 4)).unwrap();
        for nb in [(2, 2), (5, 3), (10, 9), (4, 7), (1, 1)] {
            let r = a.rechunk(nb).unwrap();
            assert_eq!(r.block_shape(), nb);
            assert_eq!(r.collect().unwrap(), m, "rechunk to {nb:?}");
        }
    }

    #[test]
    fn rechunk_same_shape_is_free() {
        let rt = Runtime::local(1);
        let a = creation::zeros(&rt, (4, 4), (2, 2)).unwrap();
        let before = rt.metrics().total_tasks();
        let r = a.rechunk((2, 2)).unwrap();
        assert_eq!(rt.metrics().total_tasks(), before);
        assert_eq!(r.grid(), a.grid());
    }

    #[test]
    fn rechunk_task_count_one_per_output_block() {
        let rt = Runtime::local(1);
        let a = creation::zeros(&rt, (8, 8), (2, 2)).unwrap();
        let before = rt.metrics();
        a.rechunk((4, 4)).unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dsarray.rechunk.block"), 4);
    }

    #[test]
    fn enables_blocked_matmul_after_rechunk() {
        let rt = Runtime::local(2);
        let a = DenseMatrix::from_fn(4, 6, |i, j| (i + j) as f32);
        let b = DenseMatrix::from_fn(6, 4, |i, j| (i * 4 + j) as f32 * 0.1);
        let da = creation::from_matrix(&rt, &a, (2, 3)).unwrap();
        let db = creation::from_matrix(&rt, &b, (2, 2)).unwrap();
        // Incompatible inner blocks -> rechunk -> works.
        assert!(da.matmul(&db).is_err());
        let db2 = db.rechunk((3, 2)).unwrap();
        let got = da.matmul(&db2).unwrap().collect().unwrap();
        assert!(got.max_abs_diff(&a.matmul(&b).unwrap()) < 1e-5);
    }
}
