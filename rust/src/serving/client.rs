//! Minimal blocking client for the serving tier: one TCP connection, one
//! in-flight request at a time, speaking the same length-prefixed wire
//! protocol as the cluster ([`crate::tasking::wire`]). Concurrency comes
//! from many clients (threads/processes), which is exactly what the
//! micro-batcher coalesces.

use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::storage::{Block, DenseMatrix};
use crate::tasking::wire::{self, Request, Response};

/// What a predict request came back as. Transport and protocol failures are
/// `Err` on the call itself; these are the server's explicit answers.
#[derive(Debug)]
pub enum PredictOutcome {
    /// Scored rows, aligned with the request rows.
    Predicted(DenseMatrix),
    /// Shed by admission control — back off and retry.
    Shed(String),
}

/// One serving connection. Reusable across requests; cheap to open per
/// client thread.
pub struct ServingClient {
    stream: TcpStream,
}

impl ServingClient {
    /// Connect to a serving coordinator at `host:port`.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to server {addr}"))?;
        Ok(Self { stream })
    }

    /// Score `rows` with the model registered under `model`. Returns the
    /// server's explicit outcome; `Err` means transport failure or a
    /// request the server rejected outright (unknown model, feature
    /// mismatch, failed predict task).
    pub fn predict(&mut self, model: &str, rows: &DenseMatrix) -> Result<PredictOutcome> {
        wire::write_request(
            &mut self.stream,
            &Request::Predict {
                model: model.to_string(),
                block: Block::Dense(rows.clone()),
            },
        )?;
        match wire::read_response(&mut self.stream)?.0 {
            Response::PredictResult(block) => Ok(PredictOutcome::Predicted(block.to_dense()?)),
            Response::Overloaded(reason) => Ok(PredictOutcome::Shed(reason)),
            Response::Err(msg) => bail!("predict failed: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        wire::write_request(&mut self.stream, &Request::Ping)?;
        match wire::read_response(&mut self.stream)?.0 {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Ask the server to stop (acknowledged, then the server drains and
    /// exits its serve loop) — how the CLI smoke lane ends a run.
    pub fn shutdown(&mut self) -> Result<()> {
        wire::write_request(&mut self.stream, &Request::Shutdown)?;
        match wire::read_response(&mut self.stream)?.0 {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
