//! Persistent model artifacts: a fitted estimator's parameters, serialized
//! in the same self-describing DSBK block-record format the spill store and
//! the wire protocol already use ([`crate::storage::store::write_block`]).
//!
//! An artifact file is:
//!
//! ```text
//! magic    "DSMA" (4 bytes)
//! version  u16 LE                         (currently 1)
//! kind     u8                             0=kmeans 1=linreg 2=scaler 3=pca
//! nscalars u8, then per scalar:           nlen u8 + name UTF-8 + f64 LE
//! nblocks  u8, then per block:            nlen u8 + name UTF-8 + DSBK record
//! ```
//!
//! Every parameter matrix is one ordinary DSBK record, so the block codec —
//! bounds-checked, tested once, bit-exact — is reused rather than re-invented,
//! and a model artifact costs exactly the bytes its parameter blocks occupy
//! in a spill file, plus a few header bytes.
//!
//! [`ModelArtifact::predict_rows`] is the single-process scoring path. It
//! replicates each estimator's `predict` arithmetic operation-for-operation
//! (same kernel vtable, same accumulation order), so a prediction computed
//! from a reloaded artifact is **bit-identical** to the fitted estimator's
//! batch `predict` — the round-trip property the serving test suite enforces.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::estimators::{KMeans, LinearRegression, Pca, StandardScaler};
use crate::storage::store::{read_block, write_block};
use crate::storage::{Block, DenseMatrix};

/// Artifact file magic, sibling to the block store's `DSBK`.
pub const ARTIFACT_MAGIC: [u8; 4] = *b"DSMA";
/// Bumped on any layout change; readers reject unknown versions.
pub const ARTIFACT_VERSION: u16 = 1;

const KIND_KMEANS: u8 = 0;
const KIND_LINREG: u8 = 1;
const KIND_SCALER: u8 = 2;
const KIND_PCA: u8 = 3;

/// The parameters of one fitted estimator, ready to persist or serve.
///
/// Only what `predict`/`transform` needs is kept — fit-time configuration
/// (iteration caps, tolerances, seeds) stays with the training run.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelArtifact {
    /// Cluster centers, `(k, features)`. Prediction is the nearest-center
    /// label per row.
    KMeans { centers: DenseMatrix },
    /// Ridge weights `(features, 1)` plus intercept.
    LinReg {
        weights: DenseMatrix,
        intercept: f32,
    },
    /// Column means and inverse standard deviations, each `(1, features)`.
    /// Prediction is the standardized row: `(x − μ) · σ⁻¹`.
    Scaler {
        mean: DenseMatrix,
        inv_std: DenseMatrix,
    },
    /// Column means `(1, features)` and principal components
    /// `(components, features)`. Prediction is the first-component
    /// projection per row, matching [`Pca`]'s `predict`.
    Pca {
        mean: DenseMatrix,
        components: DenseMatrix,
    },
}

impl ModelArtifact {
    /// Capture a fitted [`KMeans`]'s parameters. Errors before `fit`.
    pub fn from_kmeans(m: &KMeans) -> Result<Self> {
        let centers = m
            .centers
            .clone()
            .ok_or_else(|| anyhow::anyhow!("artifact before fit"))?;
        Ok(Self::KMeans { centers })
    }

    /// Capture a fitted [`LinearRegression`]'s parameters. Errors before `fit`.
    pub fn from_linreg(m: &LinearRegression) -> Result<Self> {
        let weights = m
            .weights
            .clone()
            .ok_or_else(|| anyhow::anyhow!("artifact before fit"))?;
        Ok(Self::LinReg {
            weights,
            intercept: m.intercept,
        })
    }

    /// Capture a fitted [`StandardScaler`]'s parameters. Errors before `fit`.
    pub fn from_scaler(m: &StandardScaler) -> Result<Self> {
        match (&m.mean, &m.inv_std) {
            (Some(mean), Some(inv_std)) => Ok(Self::Scaler {
                mean: mean.clone(),
                inv_std: inv_std.clone(),
            }),
            _ => bail!("artifact before fit"),
        }
    }

    /// Capture a fitted [`Pca`]'s parameters. Errors before `fit`.
    pub fn from_pca(m: &Pca) -> Result<Self> {
        match (&m.mean, &m.components) {
            (Some(mean), Some(components)) => Ok(Self::Pca {
                mean: mean.clone(),
                components: components.clone(),
            }),
            _ => bail!("artifact before fit"),
        }
    }

    /// Short stable kind tag, also used in CLI output and docs.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Self::KMeans { .. } => "kmeans",
            Self::LinReg { .. } => "linreg",
            Self::Scaler { .. } => "scaler",
            Self::Pca { .. } => "pca",
        }
    }

    /// Feature count a request row must match.
    pub fn n_features(&self) -> usize {
        match self {
            Self::KMeans { centers } => centers.cols(),
            Self::LinReg { weights, .. } => weights.rows(),
            Self::Scaler { mean, .. } => mean.cols(),
            Self::Pca { mean, .. } => mean.cols(),
        }
    }

    /// Output columns per prediction row.
    pub fn output_cols(&self) -> usize {
        match self {
            Self::KMeans { .. } | Self::LinReg { .. } | Self::Pca { .. } => 1,
            Self::Scaler { mean, .. } => mean.cols(),
        }
    }

    /// The parameter matrices, in a fixed per-kind order. The serving tier
    /// registers each as one pinned (and, on a replicated cluster, k-way
    /// replicated) runtime block.
    pub fn param_blocks(&self) -> Vec<DenseMatrix> {
        match self {
            Self::KMeans { centers } => vec![centers.clone()],
            Self::LinReg { weights, .. } => vec![weights.clone()],
            Self::Scaler { mean, inv_std } => vec![mean.clone(), inv_std.clone()],
            Self::Pca { mean, components } => vec![mean.clone(), components.clone()],
        }
    }

    /// Rebuild the artifact from parameter blocks in [`Self::param_blocks`]
    /// order plus this artifact's scalars — the serving task closure's view,
    /// where parameters arrive as runtime blocks fetched from workers.
    pub fn with_params(&self, params: &[DenseMatrix]) -> Result<Self> {
        let want = self.param_blocks().len();
        if params.len() != want {
            bail!("expected {want} parameter blocks, got {}", params.len());
        }
        Ok(match self {
            Self::KMeans { .. } => Self::KMeans {
                centers: params[0].clone(),
            },
            Self::LinReg { intercept, .. } => Self::LinReg {
                weights: params[0].clone(),
                intercept: *intercept,
            },
            Self::Scaler { .. } => Self::Scaler {
                mean: params[0].clone(),
                inv_std: params[1].clone(),
            },
            Self::Pca { .. } => Self::Pca {
                mean: params[0].clone(),
                components: params[1].clone(),
            },
        })
    }

    /// Score `rows` (`(n, features)`): the serving tier's compute kernel and
    /// the reference path for the bit-identicality contract. Each arm mirrors
    /// the corresponding estimator's `predict`/`transform` arithmetic exactly
    /// — same kernel vtable calls, same accumulation order — so the result
    /// matches the distributed batch path bit for bit.
    pub fn predict_rows(&self, rows: &DenseMatrix) -> Result<DenseMatrix> {
        if rows.cols() != self.n_features() {
            bail!(
                "{} model fitted on {} features, got {}",
                self.kind_name(),
                self.n_features(),
                rows.cols()
            );
        }
        match self {
            Self::KMeans { centers } => {
                // Mirrors kmeans.predict's per-block closure: kernel dist2
                // argmin per row, first-best wins on ties.
                let ker = crate::kernels::active();
                crate::kernels::record_hit(ker);
                let mut labels = DenseMatrix::zeros(rows.rows(), 1);
                for r in 0..rows.rows() {
                    let row = rows.row(r);
                    let mut best = (f32::INFINITY, 0usize);
                    for kk in 0..centers.rows() {
                        let d2 = (ker.dist2)(row, centers.row(kk));
                        if d2 < best.0 {
                            best = (d2, kk);
                        }
                    }
                    labels.set(r, 0, best.1 as f32);
                }
                Ok(labels)
            }
            Self::LinReg { weights, intercept } => {
                // Mirrors linreg.predict's per-panel closure: one gemm into
                // a zeroed output, then the intercept added elementwise.
                let mut pred = rows.matmul(weights)?;
                let b = *intercept;
                for v in pred.data_mut() {
                    *v += b;
                }
                Ok(pred)
            }
            Self::Scaler { mean, inv_std } => {
                // Mirrors the scaler's fused `(x − μ) · σ⁻¹` chain per
                // element (the fused SIMD table is property-tested
                // bit-identical to this scalar form).
                Ok(DenseMatrix::from_fn(rows.rows(), rows.cols(), |i, j| {
                    (rows.get(i, j) - mean.get(0, j)) * inv_std.get(0, j)
                }))
            }
            Self::Pca { mean, components } => {
                // Mirrors pca.transform: center, project with one gemm per
                // panel, then keep the first component (pca.predict).
                let centered = DenseMatrix::from_fn(rows.rows(), rows.cols(), |i, j| {
                    rows.get(i, j) - mean.get(0, j)
                });
                let proj = centered.matmul(&components.transpose())?;
                proj.slice(0, 0, proj.rows(), 1)
            }
        }
    }

    fn kind_byte(&self) -> u8 {
        match self {
            Self::KMeans { .. } => KIND_KMEANS,
            Self::LinReg { .. } => KIND_LINREG,
            Self::Scaler { .. } => KIND_SCALER,
            Self::Pca { .. } => KIND_PCA,
        }
    }

    fn scalars(&self) -> Vec<(&'static str, f64)> {
        match self {
            Self::LinReg { intercept, .. } => vec![("intercept", *intercept as f64)],
            _ => Vec::new(),
        }
    }

    fn named_blocks(&self) -> Vec<(&'static str, &DenseMatrix)> {
        match self {
            Self::KMeans { centers } => vec![("centers", centers)],
            Self::LinReg { weights, .. } => vec![("weights", weights)],
            Self::Scaler { mean, inv_std } => vec![("mean", mean), ("inv_std", inv_std)],
            Self::Pca { mean, components } => vec![("mean", mean), ("components", components)],
        }
    }

    /// Serialize to any writer. Returns the bytes written.
    pub fn save(&self, w: &mut impl Write) -> Result<u64> {
        let mut n = 0u64;
        w.write_all(&ARTIFACT_MAGIC)?;
        w.write_all(&ARTIFACT_VERSION.to_le_bytes())?;
        w.write_all(&[self.kind_byte()])?;
        n += 7;
        let scalars = self.scalars();
        w.write_all(&[scalars.len() as u8])?;
        n += 1;
        for (name, v) in scalars {
            w.write_all(&[name.len() as u8])?;
            w.write_all(name.as_bytes())?;
            w.write_all(&v.to_le_bytes())?;
            n += 1 + name.len() as u64 + 8;
        }
        let blocks = self.named_blocks();
        w.write_all(&[blocks.len() as u8])?;
        n += 1;
        for (name, m) in blocks {
            w.write_all(&[name.len() as u8])?;
            w.write_all(name.as_bytes())?;
            n += 1 + name.len() as u64;
            n += write_block(w, &Block::Dense(m.clone()))
                .with_context(|| format!("writing model block `{name}`"))?;
        }
        w.flush()?;
        Ok(n)
    }

    /// Deserialize from any reader; rejects bad magic, unknown versions,
    /// unknown kinds, and missing parameters.
    pub fn load(r: &mut impl Read) -> Result<Self> {
        let mut hdr = [0u8; 7];
        r.read_exact(&mut hdr).context("reading artifact header")?;
        if hdr[..4] != ARTIFACT_MAGIC {
            bail!("not a model artifact (bad magic)");
        }
        let version = u16::from_le_bytes([hdr[4], hdr[5]]);
        if version != ARTIFACT_VERSION {
            bail!("unsupported artifact version {version}");
        }
        let kind = hdr[6];
        let mut scalars = std::collections::BTreeMap::new();
        let mut count = [0u8; 1];
        r.read_exact(&mut count)?;
        for _ in 0..count[0] {
            let name = read_name(r)?;
            let mut v = [0u8; 8];
            r.read_exact(&mut v)?;
            scalars.insert(name, f64::from_le_bytes(v));
        }
        let mut blocks = std::collections::BTreeMap::new();
        r.read_exact(&mut count)?;
        for _ in 0..count[0] {
            let name = read_name(r)?;
            let block = read_block(r).with_context(|| format!("reading model block `{name}`"))?;
            let dense = block.to_dense()?;
            blocks.insert(name, dense);
        }
        let take = |name: &str, blocks: &mut std::collections::BTreeMap<String, DenseMatrix>| {
            blocks
                .remove(name)
                .ok_or_else(|| anyhow::anyhow!("artifact missing `{name}` block"))
        };
        Ok(match kind {
            KIND_KMEANS => Self::KMeans {
                centers: take("centers", &mut blocks)?,
            },
            KIND_LINREG => Self::LinReg {
                weights: take("weights", &mut blocks)?,
                intercept: *scalars
                    .get("intercept")
                    .ok_or_else(|| anyhow::anyhow!("artifact missing `intercept` scalar"))?
                    as f32,
            },
            KIND_SCALER => Self::Scaler {
                mean: take("mean", &mut blocks)?,
                inv_std: take("inv_std", &mut blocks)?,
            },
            KIND_PCA => Self::Pca {
                mean: take("mean", &mut blocks)?,
                components: take("components", &mut blocks)?,
            },
            other => bail!("unknown artifact kind {other}"),
        })
    }

    /// Save to a file path (buffered).
    pub fn save_path(&self, path: impl AsRef<Path>) -> Result<u64> {
        let path = path.as_ref();
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        self.save(&mut w)
    }

    /// Load from a file path (buffered).
    pub fn load_path(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        Self::load(&mut r)
    }
}

fn read_name(r: &mut impl Read) -> Result<String> {
    let mut nlen = [0u8; 1];
    r.read_exact(&mut nlen)?;
    let mut name = vec![0u8; nlen[0] as usize];
    r.read_exact(&mut name)?;
    String::from_utf8(name).context("artifact field name is not UTF-8")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(a: &ModelArtifact) -> ModelArtifact {
        let mut buf = Vec::new();
        let written = a.save(&mut buf).unwrap();
        assert_eq!(written as usize, buf.len());
        ModelArtifact::load(&mut buf.as_slice()).unwrap()
    }

    #[test]
    fn every_kind_round_trips_bit_for_bit() {
        let m = |r, c, s: f32| DenseMatrix::from_fn(r, c, |i, j| (i * c + j) as f32 * s - 1.0);
        let arts = [
            ModelArtifact::KMeans {
                centers: m(4, 6, 0.25),
            },
            ModelArtifact::LinReg {
                weights: m(6, 1, 0.5),
                intercept: -2.75,
            },
            ModelArtifact::Scaler {
                mean: m(1, 6, 0.125),
                inv_std: m(1, 6, 0.0625),
            },
            ModelArtifact::Pca {
                mean: m(1, 6, 0.2),
                components: m(2, 6, 0.3),
            },
        ];
        for a in &arts {
            assert_eq!(&round_trip(a), a);
        }
    }

    #[test]
    fn corrupt_artifacts_error_cleanly() {
        assert!(ModelArtifact::load(&mut &b"NOPE"[..]).is_err());
        let a = ModelArtifact::KMeans {
            centers: DenseMatrix::zeros(2, 3),
        };
        let mut buf = Vec::new();
        a.save(&mut buf).unwrap();
        // Truncation errors, never panics.
        assert!(ModelArtifact::load(&mut &buf[..buf.len() - 5]).is_err());
        // Version bump is rejected.
        let mut bumped = buf.clone();
        bumped[4] = 0x7f;
        assert!(ModelArtifact::load(&mut bumped.as_slice()).is_err());
        // Unknown kind is rejected.
        let mut bad_kind = buf;
        bad_kind[6] = 0x7f;
        assert!(ModelArtifact::load(&mut bad_kind.as_slice()).is_err());
    }

    #[test]
    fn predict_rows_validates_feature_count() {
        let a = ModelArtifact::KMeans {
            centers: DenseMatrix::zeros(2, 3),
        };
        assert!(a.predict_rows(&DenseMatrix::zeros(1, 4)).is_err());
        assert_eq!(a.n_features(), 3);
        assert_eq!(a.output_cols(), 1);
        let s = ModelArtifact::Scaler {
            mean: DenseMatrix::zeros(1, 5),
            inv_std: DenseMatrix::full(1, 5, 1.0),
        };
        assert_eq!(s.output_cols(), 5);
    }

    #[test]
    fn unfitted_estimators_refuse_to_export() {
        assert!(ModelArtifact::from_linreg(&LinearRegression::new(0.0, true)).is_err());
        assert!(ModelArtifact::from_scaler(&StandardScaler::default()).is_err());
        assert!(ModelArtifact::from_pca(&Pca::new(1)).is_err());
    }
}
