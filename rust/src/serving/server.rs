//! The serving coordinator: pinned model parameters, an adaptive
//! micro-batcher, admission control, and a TCP loop speaking the cluster
//! wire protocol's `Predict`/`PredictResult`/`Overloaded` frames.
//!
//! ## Request path
//!
//! Each client connection gets a thread that reads `Predict` frames,
//! validates them against the registered model (name, feature count), and
//! enqueues the rows with a reply channel. A single batcher thread owns the
//! queue: when a request arrives it opens a small deadline window
//! ([`ServeOptions::batch_window_ms`]) during which further concurrent
//! requests coalesce into the same batch, up to
//! [`ServeOptions::max_batch_rows`] rows. The batch executes as **one**
//! runtime task (`serve.predict`) reading the request block plus the model's
//! pinned parameter blocks, and the output rows are sliced back to the
//! waiting connections. Every predict path is row-independent with
//! deterministic kernels, so a coalesced answer is bit-identical to the
//! answer each request would have gotten alone — batching changes latency,
//! never values.
//!
//! ## Admission control
//!
//! The queue refuses rows past [`ServeOptions::max_pending_rows`] (and past
//! [`ServeOptions::max_pending_bytes`] when the serving tier is wired to a
//! memory budget — the CLI derives this cap from `--memory-budget-bytes`).
//! A refused request is answered with an explicit `Overloaded` frame
//! immediately: the server sheds load at the door instead of queueing
//! toward OOM, and the client knows to back off.
//!
//! ## Fault tolerance
//!
//! Model parameters live in ordinary runtime blocks: pinned against
//! eviction, placed on cluster workers, and — when the runtime was built
//! `with_replication(k)` — k-way replicated. A SIGKILLed worker therefore
//! costs nothing: the predict task reads a surviving replica (or lineage
//! recovery replays the root from the coordinator journal) and traffic
//! continues with zero failed requests, which `tests/serving.rs` enforces
//! under the chaos harness.

use std::collections::{BTreeMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::artifact::ModelArtifact;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::metrics::{latency_bucket, Metrics, LATENCY_BUCKETS};
use crate::tasking::wire::{self, Request, Response};
use crate::tasking::{CostHint, Future, Runtime};

/// Serving-tier knobs. All have conservative defaults; the CLI and
/// [`crate::config::Config`] expose each one.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Deadline window (milliseconds) a batch stays open after its first
    /// request, letting concurrent requests coalesce. `0` disables
    /// micro-batching: every request runs alone (the uncoalesced baseline
    /// the bench suite compares against).
    pub batch_window_ms: u64,
    /// Row cap per coalesced batch — one block-sized task.
    pub max_batch_rows: usize,
    /// Admission control: total queued rows past this are shed with an
    /// explicit `Overloaded` response.
    pub max_pending_rows: usize,
    /// Optional byte-denominated admission cap, wired from the runtime's
    /// memory budget (the CLI sets `budget / 8`): queued request payload
    /// past this is shed rather than queued toward OOM.
    pub max_pending_bytes: Option<u64>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            batch_window_ms: 2,
            max_batch_rows: 256,
            max_pending_rows: 4096,
            max_pending_bytes: None,
        }
    }
}

impl ServeOptions {
    pub fn with_batch_window_ms(mut self, ms: u64) -> Self {
        self.batch_window_ms = ms;
        self
    }

    pub fn with_max_batch_rows(mut self, rows: usize) -> Self {
        self.max_batch_rows = rows.max(1);
        self
    }

    pub fn with_max_pending_rows(mut self, rows: usize) -> Self {
        self.max_pending_rows = rows.max(1);
        self
    }

    pub fn with_max_pending_bytes(mut self, bytes: Option<u64>) -> Self {
        self.max_pending_bytes = bytes;
        self
    }
}

/// Serving counters, also overlaid onto [`Metrics`] by
/// [`ServerHandle::metrics`] so `metrics_json` carries them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServingStats {
    /// Predict requests answered with a `PredictResult`.
    pub requests_served: u64,
    /// Batches that coalesced more than one request into one task.
    pub batches_coalesced: u64,
    /// Requests shed by admission control with an `Overloaded` response.
    pub requests_shed: u64,
    /// Log₂ request-latency histogram: bucket `b` counts requests answered
    /// in `[2^b, 2^(b+1))` microseconds (enqueue to reply).
    pub latency_us_hist: Vec<u64>,
}

enum Reply {
    Answer(DenseMatrix),
    Failed(String),
}

struct Pending {
    model: String,
    rows: DenseMatrix,
    enqueued: Instant,
    reply: mpsc::Sender<Reply>,
}

#[derive(Default)]
struct Queue {
    pending: VecDeque<Pending>,
    pending_rows: usize,
    pending_bytes: u64,
}

struct HostedModel {
    /// Template carrying the kind and scalar parameters; matrices are
    /// re-read from the runtime blocks at task time.
    template: ModelArtifact,
    /// Pinned parameter block futures, [`ModelArtifact::param_blocks`] order.
    params: Vec<Future>,
}

struct Shared {
    rt: Runtime,
    opts: ServeOptions,
    models: RwLock<BTreeMap<String, HostedModel>>,
    queue: Mutex<Queue>,
    arrived: Condvar,
    shutdown: AtomicBool,
    requests_served: AtomicU64,
    batches_coalesced: AtomicU64,
    requests_shed: AtomicU64,
    latency_us_hist: Mutex<Vec<u64>>,
}

impl Shared {
    fn begin_shutdown(&self, addr: &str) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the batcher (under the queue lock so the wake can't race a
        // wait re-entry)…
        let guard = self.queue.lock().unwrap();
        self.arrived.notify_all();
        drop(guard);
        // …and unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(addr);
    }

    /// Admission-controlled enqueue; `Err(reason)` means shed.
    fn enqueue(&self, p: Pending) -> std::result::Result<(), String> {
        let rows = p.rows.rows();
        let bytes = (4 * rows * p.rows.cols()) as u64;
        let mut q = self.queue.lock().unwrap();
        if q.pending_rows + rows > self.opts.max_pending_rows {
            return Err(format!(
                "pending rows at budget ({} queued, cap {})",
                q.pending_rows, self.opts.max_pending_rows
            ));
        }
        if let Some(cap) = self.opts.max_pending_bytes {
            if q.pending_bytes + bytes > cap {
                return Err(format!(
                    "pending bytes at memory budget ({} queued, cap {cap})",
                    q.pending_bytes
                ));
            }
        }
        q.pending_rows += rows;
        q.pending_bytes += bytes;
        q.pending.push_back(p);
        self.arrived.notify_all();
        Ok(())
    }

    fn record_latency(&self, elapsed: Duration) {
        let us = elapsed.as_micros() as u64;
        self.latency_us_hist.lock().unwrap()[latency_bucket(us)] += 1;
    }

    fn stats(&self) -> ServingStats {
        ServingStats {
            requests_served: self.requests_served.load(Ordering::SeqCst),
            batches_coalesced: self.batches_coalesced.load(Ordering::SeqCst),
            requests_shed: self.requests_shed.load(Ordering::SeqCst),
            latency_us_hist: self.latency_us_hist.lock().unwrap().clone(),
        }
    }
}

/// A model server bound to one runtime. Register artifacts, then
/// [`ModelServer::serve`] a listener; the returned [`ServerHandle`] owns the
/// background threads.
pub struct ModelServer {
    shared: Arc<Shared>,
}

impl ModelServer {
    pub fn new(rt: Runtime, opts: ServeOptions) -> Self {
        Self {
            shared: Arc::new(Shared {
                rt,
                opts,
                models: RwLock::new(BTreeMap::new()),
                queue: Mutex::new(Queue::default()),
                arrived: Condvar::new(),
                shutdown: AtomicBool::new(false),
                requests_served: AtomicU64::new(0),
                batches_coalesced: AtomicU64::new(0),
                requests_shed: AtomicU64::new(0),
                latency_us_hist: Mutex::new(vec![0; LATENCY_BUCKETS]),
            }),
        }
    }

    /// The runtime predictions execute on.
    pub fn runtime(&self) -> &Runtime {
        &self.shared.rt
    }

    /// Host `artifact` under `name`: its parameter matrices become pinned
    /// runtime blocks (replicated across workers when the runtime was built
    /// `with_replication(k)`), and `Predict { model: name, .. }` requests
    /// are answered from them. Re-registering a name replaces the model for
    /// subsequent batches.
    pub fn register(&self, name: &str, artifact: ModelArtifact) -> Result<()> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        let params: Vec<Future> = artifact
            .param_blocks()
            .into_iter()
            .map(|m| {
                let fut = self.shared.rt.put_block(Block::Dense(m));
                // Pinned: never spilled or evicted out from under traffic.
                self.shared.rt.pin(fut);
                fut
            })
            .collect();
        // Surface placement errors now, not on the first request.
        self.shared.rt.barrier()?;
        self.shared.models.write().unwrap().insert(
            name.to_string(),
            HostedModel {
                template: artifact,
                params,
            },
        );
        Ok(())
    }

    /// Start serving on `listener`: spawns the batcher and the accept loop,
    /// returns a handle with the bound address and the live counters.
    pub fn serve(&self, listener: TcpListener) -> Result<ServerHandle> {
        let addr = listener
            .local_addr()
            .context("serving listener has no local address")?
            .to_string();
        let batcher = {
            let shared = self.shared.clone();
            std::thread::spawn(move || batcher_loop(&shared))
        };
        let accept = {
            let shared = self.shared.clone();
            std::thread::spawn(move || accept_loop(&shared, listener))
        };
        Ok(ServerHandle {
            shared: self.shared.clone(),
            addr,
            batcher: Some(batcher),
            accept: Some(accept),
        })
    }
}

/// Handle to a running server: address, live counters, orderly shutdown.
pub struct ServerHandle {
    shared: Arc<Shared>,
    addr: String,
    batcher: Option<std::thread::JoinHandle<()>>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound `host:port` clients connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Live serving counters.
    pub fn stats(&self) -> ServingStats {
        self.shared.stats()
    }

    /// Runtime metrics with the serving counters overlaid — the snapshot
    /// [`crate::bench::report::metrics_json`] serializes.
    pub fn metrics(&self) -> Metrics {
        let mut m = self.shared.rt.metrics();
        let s = self.shared.stats();
        m.requests_served = s.requests_served;
        m.batches_coalesced = s.batches_coalesced;
        m.requests_shed = s.requests_shed;
        m.predict_latency_us_hist = s.latency_us_hist;
        m
    }

    /// Stop accepting, drain the queue (queued requests are still
    /// answered), and join the background threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// True once a client's `Shutdown` frame (or [`Self::shutdown`]) has
    /// stopped the server — lets a CLI host park until told to exit.
    pub fn is_shut_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    fn stop_and_join(&mut self) {
        self.shared.begin_shutdown(&self.addr);
        if let Some(t) = self.batcher.take() {
            let _ = t.join();
        }
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = stream else { continue };
        let shared = shared.clone();
        let addr = listener.local_addr().map(|a| a.to_string());
        std::thread::spawn(move || {
            conn_loop(&shared, stream, addr.as_deref().unwrap_or(""));
        });
    }
}

fn conn_loop(shared: &Arc<Shared>, mut stream: TcpStream, addr: &str) {
    loop {
        let req = match wire::read_request(&mut stream) {
            Ok(r) => r,
            Err(_) => return, // client hung up
        };
        let resp = match req {
            Request::Ping => Response::Ok,
            Request::Shutdown => {
                let _ = wire::write_response(&mut stream, &Response::Ok);
                shared.begin_shutdown(addr);
                return;
            }
            Request::Predict { model, block } => answer_predict(shared, &model, &block),
            _ => Response::Err("unsupported request on serving socket".into()),
        };
        if wire::write_response(&mut stream, &resp).is_err() {
            return;
        }
    }
}

/// Validate, enqueue, and wait for the batcher's reply — the whole
/// per-request path other than the shared batch task.
fn answer_predict(shared: &Arc<Shared>, model: &str, block: &Block) -> Response {
    let rows = match block.to_dense() {
        Ok(r) => r,
        Err(e) => return Response::Err(format!("bad request block: {e}")),
    };
    if rows.rows() == 0 {
        return Response::Err("empty request block".into());
    }
    {
        let models = shared.models.read().unwrap();
        let Some(hosted) = models.get(model) else {
            return Response::Err(format!("unknown model `{model}`"));
        };
        let want = hosted.template.n_features();
        if rows.cols() != want {
            return Response::Err(format!(
                "model `{model}` expects {want} features, request has {}",
                rows.cols()
            ));
        }
    }
    let (tx, rx) = mpsc::channel();
    let pending = Pending {
        model: model.to_string(),
        rows,
        enqueued: Instant::now(),
        reply: tx,
    };
    if let Err(reason) = shared.enqueue(pending) {
        shared.requests_shed.fetch_add(1, Ordering::SeqCst);
        return Response::Overloaded(reason);
    }
    // Generous backstop so a wedged runtime yields an error, never a hang.
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(Reply::Answer(m)) => Response::PredictResult(Block::Dense(m)),
        Ok(Reply::Failed(msg)) => Response::Err(msg),
        Err(_) => Response::Err("predict timed out".into()),
    }
}

/// The single batch-forming loop: wait for a first request, hold the
/// deadline window open for concurrent arrivals, drain up to a block's
/// worth of rows, execute one task per model, reply per request.
fn batcher_loop(shared: &Arc<Shared>) {
    loop {
        let mut q = shared.queue.lock().unwrap();
        while q.pending.is_empty() {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let (guard, _) = shared
                .arrived
                .wait_timeout(q, Duration::from_millis(100))
                .unwrap();
            q = guard;
        }
        // Adaptive window: the batch stays open until the deadline or the
        // row cap, coalescing whatever concurrency the moment offers.
        let window = Duration::from_millis(shared.opts.batch_window_ms);
        let deadline = Instant::now() + window;
        while q.pending_rows < shared.opts.max_batch_rows
            && !shared.shutdown.load(Ordering::SeqCst)
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = shared.arrived.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
        let mut batch = Vec::new();
        let mut took_rows = 0usize;
        while let Some(front) = q.pending.front() {
            let n = front.rows.rows();
            if !batch.is_empty() && took_rows + n > shared.opts.max_batch_rows {
                break;
            }
            took_rows += n;
            let p = q.pending.pop_front().unwrap();
            q.pending_rows -= p.rows.rows();
            q.pending_bytes -= (4 * p.rows.rows() * p.rows.cols()) as u64;
            batch.push(p);
        }
        drop(q);
        if batch.is_empty() {
            continue;
        }
        // Contiguous arrival order per model is preserved: requests for the
        // same model score as one task, slices map back by offset.
        let mut by_model: BTreeMap<String, Vec<Pending>> = BTreeMap::new();
        for p in batch {
            by_model.entry(p.model.clone()).or_default().push(p);
        }
        for (model, group) in by_model {
            execute_batch(shared, &model, group);
        }
    }
}

fn execute_batch(shared: &Arc<Shared>, model: &str, group: Vec<Pending>) {
    let (template, params) = {
        let models = shared.models.read().unwrap();
        match models.get(model) {
            Some(h) => (h.template.clone(), h.params.clone()),
            None => {
                for p in group {
                    let _ = p.reply.send(Reply::Failed(format!("unknown model `{model}`")));
                }
                return;
            }
        }
    };
    let coalesced = group.len() > 1;
    let total_rows: usize = group.iter().map(|p| p.rows.rows()).sum();
    let out_cols = template.output_cols();
    let stacked = if group.len() == 1 {
        Ok(group[0].rows.clone())
    } else {
        let refs: Vec<&DenseMatrix> = group.iter().map(|p| &p.rows).collect();
        DenseMatrix::vstack(&refs)
    };
    let stacked = match stacked {
        Ok(m) => m,
        Err(e) => {
            for p in group {
                let _ = p.reply.send(Reply::Failed(format!("batch assembly failed: {e}")));
            }
            return;
        }
    };
    let rows_fut = shared.rt.put_block(Block::Dense(stacked));
    let mut reads = vec![rows_fut];
    reads.extend_from_slice(&params);
    let nparams = params.len();
    let closure_template = template.clone();
    let futs = shared.rt.submit(
        "serve.predict",
        &reads,
        vec![BlockMeta::dense(total_rows, out_cols)],
        CostHint::flops(
            2.0 * total_rows as f64 * template.n_features() as f64 * out_cols.max(2) as f64,
        ),
        std::sync::Arc::new(move |ins: &[std::sync::Arc<Block>]| {
            let rows = ins[0].to_dense()?;
            let mats: Vec<DenseMatrix> = ins[1..1 + nparams]
                .iter()
                .map(|b| b.to_dense())
                .collect::<Result<_>>()?;
            let live = closure_template.with_params(&mats)?;
            Ok(vec![Block::Dense(live.predict_rows(&rows)?)])
        }),
    );
    let result = shared.rt.wait(futs[0]);
    match result {
        Ok(out_block) => {
            let out = match out_block.as_dense() {
                Ok(d) => d.clone(),
                Err(e) => {
                    let msg = format!("predict produced a non-dense block: {e}");
                    for p in group {
                        let _ = p.reply.send(Reply::Failed(msg.clone()));
                    }
                    shared.rt.release(&[rows_fut, futs[0]]);
                    return;
                }
            };
            let mut off = 0usize;
            for p in &group {
                let n = p.rows.rows();
                match out.slice(off, 0, n, out_cols) {
                    Ok(slice) => {
                        shared.record_latency(p.enqueued.elapsed());
                        shared.requests_served.fetch_add(1, Ordering::SeqCst);
                        let _ = p.reply.send(Reply::Answer(slice));
                    }
                    Err(e) => {
                        let _ = p.reply.send(Reply::Failed(format!("result slicing failed: {e}")));
                    }
                }
                off += n;
            }
            if coalesced {
                shared.batches_coalesced.fetch_add(1, Ordering::SeqCst);
            }
        }
        Err(e) => {
            let msg = format!("predict task failed: {e}");
            for p in &group {
                let _ = p.reply.send(Reply::Failed(msg.clone()));
            }
        }
    }
    // Mirror DsArray's lifecycle: the batch input and output blocks are
    // one-shot — release them so refcount reclamation bounds server memory
    // by the in-flight frontier, not the request history.
    shared.rt.release(&[rows_fut, futs[0]]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::client::{PredictOutcome, ServingClient};

    fn kmeans_artifact() -> ModelArtifact {
        ModelArtifact::KMeans {
            centers: DenseMatrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32 * 0.5 - 2.0),
        }
    }

    fn serve_local(opts: ServeOptions) -> (ModelServer, ServerHandle) {
        let server = ModelServer::new(Runtime::local(2), opts);
        server.register("m", kmeans_artifact()).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = server.serve(listener).unwrap();
        (server, handle)
    }

    #[test]
    fn single_request_round_trips_and_counts() {
        let (_server, handle) = serve_local(ServeOptions::default());
        let mut c = ServingClient::connect(handle.addr()).unwrap();
        let rows = DenseMatrix::from_fn(2, 4, |i, j| (i + j) as f32);
        let want = kmeans_artifact().predict_rows(&rows).unwrap();
        match c.predict("m", &rows).unwrap() {
            PredictOutcome::Predicted(got) => assert_eq!(got, want),
            other => panic!("got {other:?}"),
        }
        let s = handle.stats();
        assert_eq!(s.requests_served, 1);
        assert_eq!(s.requests_shed, 0);
        assert_eq!(s.latency_us_hist.iter().sum::<u64>(), 1);
        let m = handle.metrics();
        assert_eq!(m.requests_served, 1);
        handle.shutdown();
    }

    #[test]
    fn bad_requests_get_explicit_errors() {
        let (_server, handle) = serve_local(ServeOptions::default());
        let mut c = ServingClient::connect(handle.addr()).unwrap();
        let rows = DenseMatrix::zeros(1, 4);
        // Unknown model.
        assert!(c.predict("ghost", &rows).is_err());
        // Feature mismatch (model has 4 features).
        assert!(c.predict("m", &DenseMatrix::zeros(1, 3)).is_err());
        // The connection survives errors: a good request still works.
        assert!(matches!(
            c.predict("m", &rows).unwrap(),
            PredictOutcome::Predicted(_)
        ));
        handle.shutdown();
    }

    #[test]
    fn shutdown_frame_stops_the_server() {
        let (_server, handle) = serve_local(ServeOptions::default());
        let mut c = ServingClient::connect(handle.addr()).unwrap();
        c.shutdown().unwrap();
        for _ in 0..100 {
            if handle.is_shut_down() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(handle.is_shut_down());
        handle.shutdown();
    }

    #[test]
    fn admission_control_sheds_past_the_row_cap() {
        // Cap of 1 pending row + a long window: the first request parks in
        // the open batch window, the second is shed at the door.
        let (_server, handle) = serve_local(
            ServeOptions::default()
                .with_batch_window_ms(200)
                .with_max_pending_rows(1),
        );
        let addr = handle.addr().to_string();
        let first = std::thread::spawn({
            let addr = addr.clone();
            move || {
                let mut c = ServingClient::connect(&addr).unwrap();
                c.predict("m", &DenseMatrix::zeros(1, 4)).unwrap()
            }
        });
        // Give the first request time to enqueue and open the window.
        std::thread::sleep(Duration::from_millis(50));
        let mut c = ServingClient::connect(&addr).unwrap();
        match c.predict("m", &DenseMatrix::zeros(1, 4)).unwrap() {
            PredictOutcome::Shed(reason) => assert!(reason.contains("budget")),
            other => panic!("expected shed, got {other:?}"),
        }
        assert!(matches!(first.join().unwrap(), PredictOutcome::Predicted(_)));
        let s = handle.stats();
        assert_eq!(s.requests_shed, 1);
        assert_eq!(s.requests_served, 1);
        handle.shutdown();
    }
}
