//! Online model serving on top of the distributed runtime — ROADMAP item 3.
//!
//! Batch fit produces a [`ModelArtifact`] (KMeans, linear regression,
//! standard scaler, PCA) persisted in the same DSBK block-record format the
//! spill store and wire protocol use. `dsarray serve` hosts artifacts
//! behind a [`ModelServer`]: parameters become pinned, replicated runtime
//! blocks; concurrent `Predict` requests coalesce through an adaptive
//! micro-batcher into block-sized tasks; admission control sheds overload
//! with explicit `Overloaded` frames instead of queueing toward OOM.
//!
//! The serving contract, enforced by `tests/serving.rs`:
//!
//! - **Bit-identical**: a served prediction equals the fitted estimator's
//!   local batch `predict` bit for bit, coalesced or not, before and after
//!   an artifact round-trip through disk.
//! - **Every request is answered**: a `PredictResult`, an explicit
//!   `Overloaded` shed, or an explicit `Err` — never a hang.
//! - **Worker death is absorbed**: with `with_replication(k)` the loss of a
//!   worker mid-traffic costs zero failed requests.
//!
//! See `docs/SERVING.md` (rendered as [`crate::serving_guide`]) for the
//! artifact format and an end-to-end example.

pub mod artifact;
pub mod client;
pub mod server;

pub use artifact::ModelArtifact;
pub use client::{PredictOutcome, ServingClient};
pub use server::{ModelServer, ServeOptions, ServerHandle, ServingStats};
