//! Deterministic fault injection for the cluster runtime.
//!
//! Recovery code is only as trustworthy as the ways we can kill it, so
//! chaos here is **never** random at run time: a [`FaultPlan`] fixes, per
//! worker, exactly which fault fires after exactly how many served
//! requests, and the whole plan derives from one seed. Re-running with the
//! same seed reproduces the same kill points, so every failing chaos
//! scenario replays.
//!
//! The plan travels to real worker processes as a compact spec string
//! (`dsarray worker --fault-plan die@7`); in-process test workers consume
//! it directly via the `fault_spec` field of
//! [`WorkerOptions`](super::cluster::WorkerOptions). Workers consult their
//! [`FaultState`] once per served request at a single defined point (after
//! the request is decoded, before it is handled), so the trigger counter is
//! exact regardless of connection interleaving.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::util::rng::Xoshiro256;

/// What a triggered fault does to the worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker dies abruptly — no response, no spill-store cleanup, as
    /// close to SIGKILL as the process can self-inflict. In-process test
    /// workers instead go permanently silent (every connection drops, new
    /// ones are refused), which the coordinator cannot distinguish from a
    /// real death.
    Die,
    /// The connection serving the triggering request is cut mid-frame: a
    /// partial response header is written, then the stream closes. The
    /// worker itself stays alive — this is the "dropped connection
    /// mid-block-transfer" scenario, and the coordinator must treat the
    /// broken conversation as a worker loss.
    DropConn,
    /// The worker turns into a straggler: from the triggering request on,
    /// every served request stalls for [`SLOW_STALL_MS`] before being
    /// handled. The TCP connection stays open and the (late) response is
    /// still correct, so nothing *errors* — only proactive liveness checks
    /// and straggler speculation can notice. This is the deterministic
    /// stand-in for an overloaded or swapping node.
    Slow,
}

/// How long a [`FaultKind::Slow`] worker stalls each request, in
/// milliseconds. Long enough that a straggler monitor with a sub-second
/// check interval reliably fires first, short enough that tests that let
/// the stalled call finish (first-completion-wins races) stay fast.
pub const SLOW_STALL_MS: u64 = 800;

/// One scheduled fault: fire `kind` while serving this worker's
/// `after`-th request (1-based, counted across all connections).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultRule {
    pub after: u64,
    pub kind: FaultKind,
}

/// A whole cluster's fault schedule: one rule list per worker, derived
/// deterministically from a seed. An empty rule list means the worker runs
/// fault-free.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub workers: Vec<Vec<FaultRule>>,
}

impl FaultPlan {
    /// A plan with no faults for `n_workers` workers.
    pub fn none(n_workers: usize) -> Self {
        Self {
            workers: vec![Vec::new(); n_workers],
        }
    }

    /// Derive a kill schedule from `seed`: between 1 and `n_workers - 1`
    /// workers get exactly one fault each (at least one worker always
    /// survives, or recovery would be impossible), triggered between the
    /// 3rd and 20th served request — late enough that boot pings and the
    /// first data distribution usually land, early enough to strike
    /// mid-workload. Mostly [`FaultKind::Die`], with the occasional
    /// mid-transfer connection drop.
    pub fn random(seed: u64, n_workers: usize) -> Self {
        let mut plan = Self::none(n_workers);
        if n_workers < 2 {
            return plan; // a sole worker must survive
        }
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let n_faults = 1 + rng.next_below(n_workers as u64 - 1) as usize;
        let victims = rng.permutation(n_workers);
        for &w in victims.iter().take(n_faults) {
            let after = 3 + rng.next_below(18);
            let kind = if rng.next_below(4) == 0 {
                FaultKind::DropConn
            } else {
                FaultKind::Die
            };
            plan.workers[w].push(FaultRule { after, kind });
        }
        plan
    }

    /// The spec string for worker `w` (what `--fault-plan` accepts):
    /// comma-separated `die@N` / `drop@N` rules, empty when fault-free.
    pub fn spec_for(&self, w: usize) -> String {
        self.workers
            .get(w)
            .map(|rules| {
                rules
                    .iter()
                    .map(|r| {
                        let k = match r.kind {
                            FaultKind::Die => "die",
                            FaultKind::DropConn => "drop",
                            FaultKind::Slow => "slow",
                        };
                        format!("{k}@{}", r.after)
                    })
                    .collect::<Vec<_>>()
                    .join(",")
            })
            .unwrap_or_default()
    }

    /// Parse one worker's spec string back into rules (inverse of
    /// [`FaultPlan::spec_for`]). Empty input parses to no rules.
    pub fn parse_spec(spec: &str) -> Result<Vec<FaultRule>> {
        let mut rules = Vec::new();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (kind, after) = part
                .trim()
                .split_once('@')
                .with_context(|| format!("fault rule `{part}` is not <kind>@<count>"))?;
            let kind = match kind {
                "die" => FaultKind::Die,
                "drop" => FaultKind::DropConn,
                "slow" => FaultKind::Slow,
                other => bail!("unknown fault kind `{other}` (want die, drop or slow)"),
            };
            let after: u64 = after
                .parse()
                .with_context(|| format!("fault trigger count `{after}` is not a number"))?;
            if after == 0 {
                bail!("fault trigger count must be >= 1 (requests are 1-based)");
            }
            rules.push(FaultRule { after, kind });
        }
        Ok(rules)
    }
}

/// One worker's live fault state: the parsed rules plus the served-request
/// counter. Shared across connection threads, so the counter is atomic and
/// [`FaultState::on_request`] needs no lock.
#[derive(Debug, Default)]
pub struct FaultState {
    rules: Vec<FaultRule>,
    served: AtomicU64,
}

impl FaultState {
    pub fn new(rules: Vec<FaultRule>) -> Self {
        Self {
            rules,
            served: AtomicU64::new(0),
        }
    }

    /// Parse a `--fault-plan` spec string.
    pub fn from_spec(spec: &str) -> Result<Self> {
        Ok(Self::new(FaultPlan::parse_spec(spec)?))
    }

    /// Count one served request and return the fault scheduled for this
    /// request number, if any. Called once per request at the worker's
    /// single injection point. `die`/`drop` rules fire at exactly their
    /// request number; a `slow` rule is a *state*, not an event — once its
    /// request number is reached, every later request stalls too.
    pub fn on_request(&self) -> Option<FaultKind> {
        let n = self.served.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(r) = self.rules.iter().find(|r| r.after == n) {
            return Some(r.kind);
        }
        self.rules
            .iter()
            .find(|r| r.kind == FaultKind::Slow && n >= r.after)
            .map(|r| r.kind)
    }

    /// Requests served so far (test introspection).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_plans_are_seed_deterministic() {
        let a = FaultPlan::random(42, 4);
        let b = FaultPlan::random(42, 4);
        assert_eq!(a, b, "same seed must derive the same plan");
        // Different seeds eventually differ (checked over a small range so
        // the test stays meaningful without being flaky about one seed).
        assert!(
            (0..16).any(|s| FaultPlan::random(s, 4) != a),
            "plans must actually depend on the seed"
        );
    }

    #[test]
    fn random_plans_always_leave_a_survivor() {
        for seed in 0..64 {
            for n in 1..=5 {
                let plan = FaultPlan::random(seed, n);
                assert_eq!(plan.workers.len(), n);
                let faulted = plan.workers.iter().filter(|r| !r.is_empty()).count();
                assert!(
                    faulted < n.max(1),
                    "seed {seed}, n {n}: every worker got a fault"
                );
                for rules in &plan.workers {
                    for r in rules {
                        assert!((3..=20).contains(&r.after));
                    }
                }
            }
        }
    }

    #[test]
    fn spec_round_trips() {
        for seed in 0..32 {
            let plan = FaultPlan::random(seed, 3);
            for w in 0..3 {
                let spec = plan.spec_for(w);
                let back = FaultPlan::parse_spec(&spec).unwrap();
                assert_eq!(back, plan.workers[w], "seed {seed} worker {w}: `{spec}`");
            }
        }
        let rules = FaultPlan::parse_spec("drop@3,die@9,slow@5").unwrap();
        assert_eq!(
            rules,
            vec![
                FaultRule {
                    after: 3,
                    kind: FaultKind::DropConn
                },
                FaultRule {
                    after: 9,
                    kind: FaultKind::Die
                },
                FaultRule {
                    after: 5,
                    kind: FaultKind::Slow
                },
            ]
        );
        let plan = FaultPlan {
            workers: vec![vec![FaultRule {
                after: 4,
                kind: FaultKind::Slow,
            }]],
        };
        assert_eq!(plan.spec_for(0), "slow@4");
        assert_eq!(
            FaultPlan::parse_spec(&plan.spec_for(0)).unwrap(),
            plan.workers[0]
        );
        assert!(FaultPlan::parse_spec("").unwrap().is_empty());
        assert!(FaultPlan::parse_spec("die").is_err());
        assert!(FaultPlan::parse_spec("melt@3").is_err());
        assert!(FaultPlan::parse_spec("die@zero").is_err());
        assert!(FaultPlan::parse_spec("die@0").is_err());
    }

    #[test]
    fn fault_state_triggers_exactly_once_at_the_scheduled_request() {
        let st = FaultState::from_spec("die@3").unwrap();
        assert_eq!(st.on_request(), None); // request 1
        assert_eq!(st.on_request(), None); // request 2
        assert_eq!(st.on_request(), Some(FaultKind::Die)); // request 3
        assert_eq!(st.on_request(), None); // request 4
        assert_eq!(st.served(), 4);
        // Fault-free state never triggers.
        let quiet = FaultState::from_spec("").unwrap();
        for _ in 0..10 {
            assert_eq!(quiet.on_request(), None);
        }
    }

    #[test]
    fn slow_is_a_state_not_an_event() {
        let st = FaultState::from_spec("slow@3").unwrap();
        assert_eq!(st.on_request(), None); // request 1
        assert_eq!(st.on_request(), None); // request 2
        for _ in 0..5 {
            // From the trigger on, every request stalls.
            assert_eq!(st.on_request(), Some(FaultKind::Slow));
        }
    }
}
