//! Task and data identifiers, cost hints, and the task specification record.

use std::sync::Arc;

use anyhow::Result;

use crate::storage::{Block, BlockMeta};

/// Index into the runtime's data table. Single-assignment: exactly one
/// producer task (or a `put_block`) ever writes an id — this is PyCOMPSs'
/// data renaming made explicit, and it makes dependency inference exact.
pub type DataId = u32;

/// Index into the runtime's task table.
pub type TaskId = u32;

/// The computation a task performs over its resolved input blocks.
/// Must return exactly as many blocks as the task declared output metas.
pub type TaskFn = Arc<dyn Fn(&[Arc<Block>]) -> Result<Vec<Block>> + Send + Sync>;

/// Cost hint captured at submission time; the discrete-event simulator turns
/// it into a duration via the calibrated [`crate::tasking::sim::CostModel`].
/// Real executors ignore it.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostHint {
    /// Floating-point work the task performs.
    pub flops: f64,
    /// Bytes the task touches beyond its declared inputs/outputs (e.g. a
    /// file-parse task streaming from storage).
    pub extra_bytes: f64,
}

impl CostHint {
    pub fn flops(flops: f64) -> Self {
        Self {
            flops,
            extra_bytes: 0.0,
        }
    }

    pub fn with_bytes(mut self, bytes: f64) -> Self {
        self.extra_bytes = bytes;
        self
    }

    /// Hint for a task that only moves/repacks its inputs (transpose, merge,
    /// slice): cost is byte traffic, not FLOPs.
    pub fn data_movement() -> Self {
        Self::default()
    }
}

/// A submitted task. Kept lean: graphs at paper scale reach millions of
/// tasks (Dataset transpose at N=1536 emits N²+N ≈ 2.36M), so every field
/// here is sized for that.
pub struct TaskSpec {
    pub name: &'static str,
    pub reads: Box<[DataId]>,
    pub writes: Box<[DataId]>,
    pub hint: CostHint,
    /// Total bytes of the declared inputs (precomputed at submission so the
    /// simulator never needs the data table to price a task).
    pub read_bytes: f64,
    /// Total bytes of the declared outputs.
    pub write_bytes: f64,
    /// The actual computation; `None` never occurs today but the simulator
    /// path simply ignores it.
    pub func: TaskFn,
}

impl TaskSpec {
    pub fn arity_in(&self) -> usize {
        self.reads.len()
    }
    pub fn arity_out(&self) -> usize {
        self.writes.len()
    }

    /// Scalar work estimate used by the work-stealing scheduler: a victim
    /// with a larger queued score is a better steal target. Floors at 1 so
    /// zero-hint tasks still count as backlog.
    pub fn cost_score(&self) -> f64 {
        (self.hint.flops + self.hint.extra_bytes + self.read_bytes + self.write_bytes).max(1.0)
    }
}

/// A fully-resolved submission record — the executor-facing form of one
/// task, with reads already lowered from [`crate::tasking::Future`] handles
/// to [`DataId`]s. Built by `Runtime::submit_batch`; a whole slice of these
/// is inserted into the graph under a single lock acquisition.
pub struct TaskSubmit {
    pub name: &'static str,
    pub reads: Vec<DataId>,
    pub out_metas: Vec<BlockMeta>,
    pub hint: CostHint,
    /// Total bytes of the declared inputs (precomputed by the submitter).
    pub read_bytes: f64,
    pub func: TaskFn,
}

/// Per-data record in the runtime table.
pub struct DataState {
    pub meta: BlockMeta,
    /// Resolved value (local mode only; sim mode keeps `None`).
    pub value: Option<Arc<Block>>,
    /// Producing task, or `None` for blocks registered via `put_block`.
    pub producer: Option<TaskId>,
    /// Outstanding reads by submitted-but-incomplete tasks (occurrence
    /// count: a task reading the id twice contributes two).
    pub pending_reads: u32,
    /// Live application handles (`DsArray` block ownership / explicit
    /// `Runtime::retain`).
    pub handle_refs: u32,
    /// Set once any handle has ever owned this id. Reclamation requires it,
    /// so bare futures that never passed through a handle container are
    /// kept forever — the safe (pre-refactor) default.
    pub ever_owned: bool,
    /// Pinned blocks are never reclaimed regardless of refcounts.
    pub pinned: bool,
    /// True once the value has been reclaimed by refcount eviction.
    pub evicted: bool,
}

impl DataState {
    pub fn new(meta: BlockMeta, value: Option<Arc<Block>>, producer: Option<TaskId>) -> Self {
        Self {
            meta,
            value,
            producer,
            pending_reads: 0,
            handle_refs: 0,
            ever_owned: false,
            pinned: false,
            evicted: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_hint_builders() {
        let h = CostHint::flops(2e9).with_bytes(4096.0);
        assert_eq!(h.flops, 2e9);
        assert_eq!(h.extra_bytes, 4096.0);
        let m = CostHint::data_movement();
        assert_eq!(m.flops, 0.0);
    }

    #[test]
    fn task_spec_arities() {
        let spec = TaskSpec {
            name: "t",
            reads: vec![1, 2, 3].into_boxed_slice(),
            writes: vec![4].into_boxed_slice(),
            hint: CostHint::default(),
            read_bytes: 0.0,
            write_bytes: 0.0,
            func: Arc::new(|_| Ok(vec![])),
        };
        assert_eq!(spec.arity_in(), 3);
        assert_eq!(spec.arity_out(), 1);
        assert_eq!(spec.cost_score(), 1.0); // floored for zero-hint tasks
    }
}
