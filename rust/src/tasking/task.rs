//! Task and data identifiers, cost hints, and the task specification record.

use std::sync::Arc;

use anyhow::Result;

use crate::storage::{Block, BlockMeta, DenseMatrix};

/// Index into the runtime's data table. Single-assignment: exactly one
/// producer task (or a `put_block`) ever writes an id — this is PyCOMPSs'
/// data renaming made explicit, and it makes dependency inference exact.
pub type DataId = u32;

/// Index into the runtime's task table.
pub type TaskId = u32;

/// The computation a task performs over its resolved input blocks.
/// Must return exactly as many blocks as the task declared output metas.
pub type TaskFn = Arc<dyn Fn(&[Arc<Block>]) -> Result<Vec<Block>> + Send + Sync>;

/// One resolved input of an ownership-aware task (see [`OwnedTaskFn`]).
pub enum TaskInput {
    /// Still readable by other tasks or application handles — read-only.
    Shared(Arc<Block>),
    /// Exclusively granted: at claim time the executor proved no other
    /// reader, handle, or pin will ever need this value (the same condition
    /// refcount reclamation uses — the block would have been evicted right
    /// after this read anyway) and removed it from the data table. The task
    /// may consume the buffer in place. Only a task's FIRST input is ever
    /// granted — by convention the working buffer of fused evaluation; the
    /// rest are read-only and arrive [`TaskInput::Shared`].
    Owned(Arc<Block>),
}

impl TaskInput {
    /// Borrow the block regardless of ownership.
    pub fn block(&self) -> &Block {
        match self {
            TaskInput::Shared(b) | TaskInput::Owned(b) => b,
        }
    }

    pub fn is_owned(&self) -> bool {
        matches!(self, TaskInput::Owned(_))
    }

    /// Dense payload — by move (zero-copy) for exclusively-owned dense
    /// blocks, by copy otherwise. The copy fallback also covers the rare
    /// case where a `wait` client still holds a clone of an owned `Arc`.
    pub fn into_dense(self) -> Result<DenseMatrix> {
        match self {
            TaskInput::Owned(arc) => match Arc::try_unwrap(arc) {
                Ok(Block::Dense(m)) => Ok(m),
                Ok(b) => b.to_dense(),
                Err(arc) => arc.to_dense(),
            },
            TaskInput::Shared(arc) => arc.to_dense(),
        }
    }
}

/// An ownership-aware task function: inputs arrive as [`TaskInput`]s so the
/// closure can mutate exclusively-owned blocks in place instead of
/// allocating fresh outputs. Used by the fused elementwise engine
/// (`dsarray::expr`); ordinary tasks keep the simpler [`TaskFn`] shape.
pub type OwnedTaskFn = Arc<dyn Fn(Vec<TaskInput>) -> Result<Vec<Block>> + Send + Sync>;

/// The executable body of a task: a plain shared-input function, or an
/// ownership-aware one eligible for in-place input grants.
#[derive(Clone)]
pub enum TaskBody {
    Shared(TaskFn),
    Owned(OwnedTaskFn),
}

impl TaskBody {
    /// Whether the executor should attempt exclusive input grants.
    pub fn wants_ownership(&self) -> bool {
        matches!(self, TaskBody::Owned(_))
    }
}

/// Cost hint captured at submission time; the discrete-event simulator turns
/// it into a duration via the calibrated [`crate::tasking::sim::CostModel`].
/// Real executors ignore it.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostHint {
    /// Floating-point work the task performs.
    pub flops: f64,
    /// Bytes the task touches beyond its declared inputs/outputs (e.g. a
    /// file-parse task streaming from storage).
    pub extra_bytes: f64,
}

impl CostHint {
    pub fn flops(flops: f64) -> Self {
        Self {
            flops,
            extra_bytes: 0.0,
        }
    }

    pub fn with_bytes(mut self, bytes: f64) -> Self {
        self.extra_bytes = bytes;
        self
    }

    /// Hint for a task that only moves/repacks its inputs (transpose, merge,
    /// slice): cost is byte traffic, not FLOPs.
    pub fn data_movement() -> Self {
        Self::default()
    }
}

/// A submitted task. Kept lean: graphs at paper scale reach millions of
/// tasks (Dataset transpose at N=1536 emits N²+N ≈ 2.36M), so every field
/// here is sized for that.
pub struct TaskSpec {
    pub name: &'static str,
    pub reads: Box<[DataId]>,
    pub writes: Box<[DataId]>,
    pub hint: CostHint,
    /// Total bytes of the declared inputs (precomputed at submission so the
    /// simulator never needs the data table to price a task).
    pub read_bytes: f64,
    /// Total bytes of the declared outputs.
    pub write_bytes: f64,
    /// The actual computation (the simulator path simply ignores it).
    pub body: TaskBody,
}

impl TaskSpec {
    pub fn arity_in(&self) -> usize {
        self.reads.len()
    }
    pub fn arity_out(&self) -> usize {
        self.writes.len()
    }

    /// Scalar work estimate used by the work-stealing scheduler: a victim
    /// with a larger queued score is a better steal target. Floors at 1 so
    /// zero-hint tasks still count as backlog.
    pub fn cost_score(&self) -> f64 {
        (self.hint.flops + self.hint.extra_bytes + self.read_bytes + self.write_bytes).max(1.0)
    }
}

/// A fully-resolved submission record — the executor-facing form of one
/// task, with reads already lowered from [`crate::tasking::Future`] handles
/// to [`DataId`]s. Built by `Runtime::submit_batch`; a whole slice of these
/// is inserted into the graph under a single lock acquisition.
pub struct TaskSubmit {
    pub name: &'static str,
    pub reads: Vec<DataId>,
    pub out_metas: Vec<BlockMeta>,
    pub hint: CostHint,
    /// Total bytes of the declared inputs (precomputed by the submitter).
    pub read_bytes: f64,
    pub body: TaskBody,
    /// Logical operations this task fuses (1 for ordinary tasks). The
    /// metrics layer credits `fused_ops - 1` to `Metrics::tasks_fused`.
    pub fused_ops: u32,
}

/// Per-data record in the runtime table.
pub struct DataState {
    pub meta: BlockMeta,
    /// Resolved value (local mode only; sim mode keeps `None`).
    pub value: Option<Arc<Block>>,
    /// Producing task, or `None` for blocks registered via `put_block`.
    pub producer: Option<TaskId>,
    /// Outstanding reads by submitted-but-incomplete tasks (occurrence
    /// count: a task reading the id twice contributes two).
    pub pending_reads: u32,
    /// Live application handles (`DsArray` block ownership / explicit
    /// `Runtime::retain`).
    pub handle_refs: u32,
    /// Set once any handle has ever owned this id. Reclamation requires it,
    /// so bare futures that never passed through a handle container are
    /// kept forever — the safe (pre-refactor) default.
    pub ever_owned: bool,
    /// Pinned blocks are never reclaimed regardless of refcounts — and
    /// never spilled by the memory-budget policy.
    pub pinned: bool,
    /// True once the value has been reclaimed by refcount eviction.
    pub evicted: bool,
    /// The value currently lives only in the spill store (still referenced;
    /// faults back in on next use). Implies `on_disk`.
    pub spilled: bool,
    /// A valid copy of the value exists in the spill store. Stays set after
    /// a fault-in ("clean" residency: re-spilling is a free drop, no
    /// write-back needed — values are single-assignment, so a disk copy
    /// never goes stale while the block lives).
    pub on_disk: bool,
    /// Logical timestamp of the last resolution/synchronization touching
    /// this value — the LRU key of the spill policy.
    pub last_use: u64,
}

impl DataState {
    pub fn new(meta: BlockMeta, value: Option<Arc<Block>>, producer: Option<TaskId>) -> Self {
        Self {
            meta,
            value,
            producer,
            pending_reads: 0,
            handle_refs: 0,
            ever_owned: false,
            pinned: false,
            evicted: false,
            spilled: false,
            on_disk: false,
            last_use: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_hint_builders() {
        let h = CostHint::flops(2e9).with_bytes(4096.0);
        assert_eq!(h.flops, 2e9);
        assert_eq!(h.extra_bytes, 4096.0);
        let m = CostHint::data_movement();
        assert_eq!(m.flops, 0.0);
    }

    #[test]
    fn task_spec_arities() {
        let spec = TaskSpec {
            name: "t",
            reads: vec![1, 2, 3].into_boxed_slice(),
            writes: vec![4].into_boxed_slice(),
            hint: CostHint::default(),
            read_bytes: 0.0,
            write_bytes: 0.0,
            body: TaskBody::Shared(Arc::new(|_| Ok(vec![]))),
        };
        assert_eq!(spec.arity_in(), 3);
        assert_eq!(spec.arity_out(), 1);
        assert_eq!(spec.cost_score(), 1.0); // floored for zero-hint tasks
    }

    #[test]
    fn task_input_ownership_semantics() {
        let m = DenseMatrix::from_fn(2, 2, |i, j| (i * 2 + j) as f32);
        // Owned with a sole reference: the dense payload moves out.
        let owned = TaskInput::Owned(Arc::new(Block::Dense(m.clone())));
        assert!(owned.is_owned());
        assert_eq!(owned.into_dense().unwrap(), m);
        // Owned but a clone escaped (e.g. a wait client): copy fallback.
        let arc = Arc::new(Block::Dense(m.clone()));
        let escaped = Arc::clone(&arc);
        let owned = TaskInput::Owned(arc);
        assert_eq!(owned.into_dense().unwrap(), m);
        assert_eq!(escaped.as_dense().unwrap(), &m);
        // Shared never moves.
        let shared = TaskInput::Shared(Arc::new(Block::Dense(m.clone())));
        assert!(!shared.is_owned());
        assert_eq!(shared.block().meta(), BlockMeta::dense(2, 2));
        assert_eq!(shared.into_dense().unwrap(), m);
    }
}
