//! Reusable task-function constructors for common block operations.
//!
//! Both the ds-array layer and the Dataset baseline build their task graphs
//! from these closures, so the two structures differ *only* in graph shape —
//! exactly the comparison the paper makes.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, CsrMatrix, DenseMatrix};

use super::task::TaskFn;

/// Unary elementwise op over one block, preserving backend.
pub fn map_op(f: impl Fn(f32) -> f32 + Send + Sync + 'static) -> TaskFn {
    Arc::new(move |ins: &[Arc<Block>]| {
        let b = &*ins[0];
        match b {
            Block::Dense(m) => Ok(vec![Block::Dense(m.map(&f))]),
            Block::Csr(m) => {
                // Elementwise maps with f(0) != 0 would densify; ds-arrays
                // (like SciPy) only support zero-preserving maps on CSR.
                if f(0.0) != 0.0 {
                    bail!("non-zero-preserving map on a sparse block");
                }
                let d = m.to_dense().map(&f);
                Ok(vec![Block::Csr(CsrMatrix::from_dense(&d, 0.0))])
            }
            Block::Phantom(_) => bail!("map on phantom block"),
        }
    })
}

/// Binary elementwise op over two same-shape blocks (densifies mixed pairs).
pub fn zip_op(f: impl Fn(f32, f32) -> f32 + Send + Sync + 'static) -> TaskFn {
    Arc::new(move |ins: &[Arc<Block>]| {
        let a = ins[0].to_dense()?;
        let b = ins[1].to_dense()?;
        Ok(vec![Block::Dense(a.zip_map(&b, &f)?)])
    })
}

/// Transpose a single block.
pub fn transpose_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| Ok(vec![ins[0].transpose()]))
}

/// Vertically stack all input blocks into one.
pub fn vstack_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| {
        if ins.iter().all(|b| matches!(&**b, Block::Csr(_))) {
            let parts: Vec<&CsrMatrix> = ins.iter().map(|b| b.as_csr().unwrap()).collect();
            Ok(vec![Block::Csr(CsrMatrix::vstack(&parts)?)])
        } else {
            let dense: Vec<DenseMatrix> =
                ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
            let refs: Vec<&DenseMatrix> = dense.iter().collect();
            Ok(vec![Block::Dense(DenseMatrix::vstack(&refs)?)])
        }
    })
}

/// Horizontally stack all input blocks into one.
pub fn hstack_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| {
        if ins.iter().all(|b| matches!(&**b, Block::Csr(_))) {
            let parts: Vec<&CsrMatrix> = ins.iter().map(|b| b.as_csr().unwrap()).collect();
            Ok(vec![Block::Csr(CsrMatrix::hstack(&parts)?)])
        } else {
            let dense: Vec<DenseMatrix> =
                ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
            let refs: Vec<&DenseMatrix> = dense.iter().collect();
            Ok(vec![Block::Dense(DenseMatrix::hstack(&refs)?)])
        }
    })
}

/// Slice one block: `[r0, r0+nr) x [c0, c0+nc)`.
pub fn slice_op(r0: usize, c0: usize, nr: usize, nc: usize) -> TaskFn {
    Arc::new(move |ins: &[Arc<Block>]| Ok(vec![ins[0].slice(r0, c0, nr, nc)?]))
}

/// Sum-reduce all input blocks elementwise (same shape).
pub fn add_reduce_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| {
        let mut acc = ins[0].to_dense()?;
        for b in &ins[1..] {
            acc.axpy(1.0, &b.to_dense()?)?;
        }
        Ok(vec![Block::Dense(acc)])
    })
}

/// Matmul of two blocks (dense@dense or csr@dense).
pub fn matmul_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| {
        let out = match (&*ins[0], &*ins[1]) {
            (Block::Csr(a), Block::Dense(b)) => a.matmul_dense(b)?,
            (a, b) => a.to_dense()?.matmul(&b.to_dense()?)?,
        };
        Ok(vec![Block::Dense(out)])
    })
}

/// `C += A @ B` accumulate: inputs [A, B, C]; used by blocked matmul
/// chains. Accumulates straight into C through the tiled
/// `DenseMatrix::gemm_acc` / CSR `matmul_dense_acc` kernels — no
/// temporary product block.
pub fn gemm_acc_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| {
        let mut c = ins[2].to_dense()?;
        match (&*ins[0], &*ins[1]) {
            (Block::Csr(a), Block::Dense(b)) => a.matmul_dense_acc(b, &mut c)?,
            (a, b) => c.gemm_acc(&a.to_dense()?, &b.to_dense()?)?,
        }
        Ok(vec![Block::Dense(c)])
    })
}

/// Pairwise squared Euclidean distances between the rows of two blocks:
/// inputs [X (mx×f), Y (my×f)] → mx×my matrix of `‖xᵢ − yⱼ‖²`. Runs the
/// kernel-layer distance micro-kernel (`DenseMatrix::pairwise_dist2`), the
/// inner loop of the KNN / K-means estimators.
pub fn pairwise_dist2_op() -> TaskFn {
    Arc::new(|ins: &[Arc<Block>]| {
        let x = ins[0].to_dense()?;
        let y = ins[1].to_dense()?;
        Ok(vec![Block::Dense(x.pairwise_dist2(&y)?)])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::BlockMeta;

    fn dense(r: usize, c: usize, f: impl FnMut(usize, usize) -> f32) -> Arc<Block> {
        Arc::new(Block::Dense(DenseMatrix::from_fn(r, c, f)))
    }

    #[test]
    fn map_preserves_sparsity_when_zero_preserving() {
        let m = CsrMatrix::from_triplets(2, 3, &[(0, 1, 2.0), (1, 0, -1.0)]).unwrap();
        let out = map_op(|x| x * 2.0)(&[Arc::new(Block::Csr(m))]).unwrap();
        match &out[0] {
            Block::Csr(c) => {
                assert_eq!(c.nnz(), 2);
                assert_eq!(c.to_dense().get(0, 1), 4.0);
            }
            _ => panic!("expected CSR out"),
        }
    }

    #[test]
    fn map_rejects_densifying_sparse() {
        let m = CsrMatrix::from_triplets(1, 1, &[]).unwrap();
        assert!(map_op(|x| x + 1.0)(&[Arc::new(Block::Csr(m))]).is_err());
    }

    #[test]
    fn zip_and_reduce() {
        let a = dense(2, 2, |i, j| (i + j) as f32);
        let b = dense(2, 2, |_, _| 10.0);
        let s = zip_op(|x, y| x + y)(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s[0].as_dense().unwrap().get(1, 1), 12.0);
        let r = add_reduce_op()(&[a.clone(), a.clone(), a]).unwrap();
        assert_eq!(r[0].as_dense().unwrap().get(1, 1), 6.0);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let a = dense(2, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        let b = dense(2, 2, |i, j| (i * 2 + j) as f32);
        let c = dense(2, 2, |_, _| 100.0);
        let out = gemm_acc_op()(&[a, b.clone(), c]).unwrap();
        assert_eq!(out[0].as_dense().unwrap().get(0, 1), 101.0);
    }

    #[test]
    fn pairwise_dist2_matches_definition() {
        let x = dense(3, 4, |i, j| (i * 4 + j) as f32 * 0.5);
        let y = dense(2, 4, |i, j| 1.0 - (i + j) as f32);
        let out = pairwise_dist2_op()(&[x.clone(), y.clone()]).unwrap();
        let d = out[0].as_dense().unwrap();
        assert_eq!(d.rows(), 3);
        assert_eq!(d.cols(), 2);
        let (xm, ym) = (x.as_dense().unwrap(), y.as_dense().unwrap());
        for i in 0..3 {
            for j in 0..2 {
                let want: f32 = (0..4)
                    .map(|k| {
                        let dk = xm.get(i, k) - ym.get(j, k);
                        dk * dk
                    })
                    .sum();
                assert!((d.get(i, j) - want).abs() <= 1e-4 * want.max(1.0));
            }
        }
    }

    #[test]
    fn stack_ops_roundtrip() {
        let a = dense(1, 2, |_, j| j as f32);
        let b = dense(1, 2, |_, j| 10.0 + j as f32);
        let v = vstack_op()(&[a.clone(), b]).unwrap();
        assert_eq!(v[0].meta(), BlockMeta::dense(2, 2));
        let h = hstack_op()(&[a.clone(), a]).unwrap();
        assert_eq!(h[0].meta(), BlockMeta::dense(1, 4));
        let s = slice_op(0, 1, 1, 1)(&[Arc::new(h[0].clone())]).unwrap();
        assert_eq!(s[0].as_dense().unwrap().get(0, 0), 1.0);
    }
}
