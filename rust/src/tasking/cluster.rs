//! Multi-process cluster executor: a coordinator that distributes block
//! residency across N worker **processes** over TCP, with locality-aware
//! task placement — the third [`Executor`] backend the PR-1 trait refactor
//! was built for.
//!
//! ## Model
//!
//! Task bodies are Rust closures and cannot cross a process boundary, so
//! the split of work follows the data, not the code:
//!
//! * **Workers** (`dsarray worker --listen <addr>`) are block daemons: they
//!   hold block payloads, serve `Put`/`Get`/`Free`, pull blocks from peer
//!   workers on command, and spill to their own [`BlockStore`] directory
//!   when a per-worker memory budget is exceeded.
//! * **The coordinator** (this executor) keeps the dependency [`Graph`],
//!   a **block-location table** (which workers hold which block), and a
//!   pool of executor threads that run task closures against blocks fetched
//!   over the wire, then push outputs back out — so the coordinator's own
//!   resident set stays flat no matter how large the arrays are.
//!
//! ## Locality-aware scheduling
//!
//! Each ready task is *placed* on the worker already holding the most input
//! bytes; its outputs land there, so chains over the same blocks keep
//! reading and writing one worker. Inputs held elsewhere are **pulled
//! worker-to-worker** to the placement worker ([`TransferMode::Pull`],
//! the default) or relayed through the coordinator from wherever they live
//! ([`TransferMode::Relay`]). Blocks are single-assignment (SSA), so a
//! pulled replica can never go stale — replication needs no coherence
//! protocol at all. [`Metrics`] counts `locality_hits` (inputs already at
//! the placement worker), `remote_transfers` (inputs that crossed workers)
//! and `bytes_on_wire` (every payload byte moved).
//!
//! ## Reclamation and fault recovery
//!
//! Refcount reclamation extends across the wire: when the graph proves a
//! block dead it queues the id (the same `dead_files` channel the
//! out-of-core store uses) and the coordinator sends `Free` to every worker
//! holding a copy.
//!
//! A worker whose TCP conversation breaks is presumed **dead** and, by
//! default, *recovered from* rather than fatal: the single-assignment task
//! graph doubles as a lineage log, so the coordinator marks the dead
//! worker's resident blocks lost, walks producers transitively until every
//! replay input is held by a survivor or re-loadable from the coordinator's
//! root journal, flips that sub-graph back to runnable, and re-queues the
//! in-flight task — results stay bit-identical because the replayed
//! closures are deterministic over bit-identical inputs. `wait` fetches
//! retry against recovered locations instead of poisoning, and the replay's
//! `pending_reads` re-increments defer refcount frees for blocks a replay
//! may still need. Opt-in k-way replication
//! ([`ClusterOptions::with_replication`]) turns recovery of replicated
//! blocks into a location-table lookup. With recovery disabled
//! ([`ClusterOptions::with_recovery`]`(false)` / `--no-recovery`), a death
//! poisons the runtime with the worker address and the task name ("task
//! \`x\` failed on cluster backend: worker 127.0.0.1:…") — never a hang —
//! which is also the fate of genuinely unrecoverable losses (every worker
//! dead). An application-level worker *error* (a live worker answering
//! `Err`) is never treated as a death and always poisons.
//!
//! See `docs/CLUSTER.md` (rustdoc: `crate::cluster_guide`) for the frame
//! format and placement policy, and `docs/FAULT_TOLERANCE.md` (rustdoc:
//! `crate::fault_tolerance_guide`) for the failure model, the lineage walk
//! and the deterministic fault-injection harness behind its tests.

use std::collections::{BTreeSet, HashMap, HashSet, VecDeque};
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::storage::{Block, BlockStore};

use super::faults::{FaultKind, FaultState};
use super::graph::{Graph, TaskState};
use super::metrics::Metrics;
use super::task::{DataId, TaskBody, TaskId, TaskInput, TaskSubmit};
use super::wire::{self, Request, Response, WorkerStat};
use super::Executor;

/// How a task's missing inputs reach its placement worker.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransferMode {
    /// The placement worker pulls missing blocks from the peers holding
    /// them (worker-to-worker), leaving a replica behind for later tasks —
    /// block residency migrates toward use.
    #[default]
    Pull,
    /// The coordinator fetches each input from whichever worker holds it;
    /// no worker-to-worker traffic, no replication.
    Relay,
}

/// Configuration of a [`ClusterExecutor`].
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// Addresses of already-running workers to connect to.
    pub addrs: Vec<String>,
    /// Worker processes to spawn on loopback (in addition to `addrs`).
    pub spawn: usize,
    /// Binary used for spawning (`dsarray`); defaults to the current
    /// executable — pass explicitly from test harnesses, whose
    /// `current_exe` is the test binary.
    pub program: Option<PathBuf>,
    /// Coordinator executor threads running task closures.
    pub threads: usize,
    /// Missing-input transfer policy.
    pub transfer: TransferMode,
    /// Memory budget handed to each *spawned* worker
    /// (`--memory-budget-bytes`); over it, workers spill to disk.
    pub worker_budget_bytes: Option<u64>,
    /// Survive worker death by lineage replay (the default). When `false`
    /// (`--no-recovery`), a broken worker conversation poisons the runtime
    /// with the worker address and task name, the pre-recovery contract.
    pub recovery: bool,
    /// Workers holding a copy of each block (`--replicate-blocks k`);
    /// clamped to the live worker count. At `k >= 2` a single death usually
    /// costs a location-table lookup instead of a replay. Default 1.
    pub replicate: usize,
}

impl ClusterOptions {
    /// Connect to existing workers at `addrs`.
    pub fn connect(addrs: Vec<String>) -> Self {
        Self {
            addrs,
            spawn: 0,
            program: None,
            threads: 2,
            transfer: TransferMode::Pull,
            worker_budget_bytes: None,
            recovery: true,
            replicate: 1,
        }
    }

    /// Spawn `n` worker processes on loopback and connect to them; they are
    /// shut down when the executor drops.
    pub fn spawn(n: usize) -> Self {
        Self {
            addrs: Vec::new(),
            spawn: n,
            program: None,
            threads: 2,
            transfer: TransferMode::Pull,
            worker_budget_bytes: None,
            recovery: true,
            replicate: 1,
        }
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }

    pub fn with_transfer(mut self, m: TransferMode) -> Self {
        self.transfer = m;
        self
    }

    pub fn with_worker_budget(mut self, bytes: u64) -> Self {
        self.worker_budget_bytes = Some(bytes);
        self
    }

    pub fn with_program(mut self, p: PathBuf) -> Self {
        self.program = Some(p);
        self
    }

    /// Enable/disable lineage-replay recovery of dead workers (on by
    /// default; `false` restores the poison-on-death contract).
    pub fn with_recovery(mut self, on: bool) -> Self {
        self.recovery = on;
        self
    }

    /// Store each block on `k` distinct workers so losing one is a
    /// location-table lookup, not a replay. Clamped to the worker count.
    pub fn with_replication(mut self, k: usize) -> Self {
        self.replicate = k.max(1);
        self
    }
}

/// One coordinator→worker connection; the stream mutex keeps each
/// request/response pair atomic, so concurrent executor threads never
/// interleave frames.
struct WorkerConn {
    addr: String,
    stream: Mutex<TcpStream>,
}

impl WorkerConn {
    /// One request/response round trip; returns the response and the total
    /// wire bytes (both directions, frame headers included).
    fn call(&self, req: &Request) -> Result<(Response, u64)> {
        let mut s = self.stream.lock().unwrap();
        let sent = wire::write_request(&mut *s, req)
            .with_context(|| format!("sending to worker {}", self.addr))?;
        let (resp, recvd) = wire::read_response(&mut *s)
            .with_context(|| format!("reading from worker {}", self.addr))?;
        Ok((resp, sent + recvd))
    }
}

/// Central coordinator state (graph + scheduler), guarded by one mutex.
struct ClState {
    graph: Graph,
    /// Dependency-free tasks awaiting an executor thread.
    ready: VecDeque<TaskId>,
    running: usize,
    shutdown: bool,
    /// First failure; poisons the runtime (fail-fast), same as local mode.
    error: Option<String>,
    metrics: Metrics,
    /// Block-location table: bit `w` of `copies[id]` is set when worker `w`
    /// holds a replica of `id` (single-assignment makes replicas coherent).
    copies: Vec<u64>,
    /// Worker-to-worker pulls in flight, keyed `(block, destination)`:
    /// concurrent tasks read from a stable holder instead of re-pulling.
    pulling: HashSet<(DataId, usize)>,
    /// Round-robin pointer for blocks and tasks with no located inputs.
    rr: usize,
    /// Bit `w` set while worker `w` is reachable. Cleared (forever) on the
    /// first transport failure talking to it; placement, pulls, frees and
    /// shutdown all skip dead workers.
    alive: u64,
}

/// Why one worker interaction failed — the classification recovery hinges
/// on. A broken TCP conversation means the *worker* is gone (its blocks
/// died with it, lineage replay applies); an application-level error from a
/// live worker is a real failure and must poison.
enum ClusterFailure {
    /// The transport to worker `w` broke (or a peer reported it
    /// unreachable): presume the worker dead.
    WorkerDown { w: usize, msg: String },
    /// A live worker answered with an error, or the task itself failed.
    Protocol { msg: String },
}

impl ClusterFailure {
    fn msg(&self) -> &str {
        match self {
            ClusterFailure::WorkerDown { msg, .. } | ClusterFailure::Protocol { msg } => msg,
        }
    }
}

struct ClusterInner {
    state: Mutex<ClState>,
    cv: Condvar,
    conns: Vec<WorkerConn>,
    transfer: TransferMode,
    /// Lineage-replay recovery on worker death (vs poison).
    recovery: bool,
    /// Distinct workers holding each block (>= 1).
    replicate: usize,
    /// Journal of root blocks (`put_block`, no producing task) kept on the
    /// coordinator's own disk so a root whose every worker replica died can
    /// be re-loaded — the "re-loadable from the store tier" leaf of the
    /// lineage walk. `Some` iff recovery is enabled. Files are kept until
    /// teardown even if the block's refcount dies: a later replay of a
    /// completed consumer may still need them.
    root_store: Option<BlockStore>,
}

impl ClusterInner {
    /// Fetch one block's payload from worker `w`, classifying the failure.
    fn fetch_block(&self, w: usize, id: DataId) -> Result<(Block, u64), ClusterFailure> {
        match self.conns[w].call(&Request::Get { id }) {
            Ok((Response::Block(b), bytes)) => Ok((b, bytes)),
            Ok((Response::Err(m), _)) => Err(ClusterFailure::Protocol {
                msg: format!("worker {}: {m}", self.conns[w].addr),
            }),
            Ok((other, _)) => Err(ClusterFailure::Protocol {
                msg: format!(
                    "worker {}: unexpected response {other:?} to Get",
                    self.conns[w].addr
                ),
            }),
            Err(e) => Err(ClusterFailure::WorkerDown {
                w,
                msg: format!("worker {}: {e:#}", self.conns[w].addr),
            }),
        }
    }

    /// Send remote frees. Best-effort: a dead worker's memory died with the
    /// process, and worker death already surfaces through the task path.
    fn send_frees(&self, frees: Vec<(usize, Vec<u32>)>) {
        for (w, ids) in frees {
            let _ = self.conns[w].call(&Request::Free { ids });
        }
    }
}

fn ensure_copies(copies: &mut Vec<u64>, id: DataId) {
    let need = id as usize + 1;
    if copies.len() < need {
        copies.resize(need, 0);
    }
}

/// All-workers-alive bitmask for an `n`-worker cluster (`n <= 64`).
fn full_mask(n: usize) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Next *live* worker in round-robin order. The all-dead case poisons
/// before any caller gets here, so at least one alive bit is set.
fn next_rr(st: &mut ClState, n: usize) -> usize {
    for _ in 0..n {
        let w = st.rr % n;
        st.rr = st.rr.wrapping_add(1);
        if st.alive & (1u64 << w) != 0 {
            return w;
        }
    }
    st.rr % n
}

/// The placement policy, kept pure for unit testing: the *live* worker
/// holding the most input bytes wins (ties break toward the lowest index);
/// `None` when no input is located on any live worker (the caller
/// round-robins over survivors).
fn choose_placement(inputs: &[(u64, usize)], n_workers: usize, alive: u64) -> Option<usize> {
    let mut best: Option<(usize, usize)> = None;
    for w in 0..n_workers {
        if alive & (1u64 << w) == 0 {
            continue;
        }
        let held: usize = inputs
            .iter()
            .filter(|(mask, _)| mask & (1u64 << w) != 0)
            .map(|(_, bytes)| *bytes)
            .sum();
        if held > 0 && best.map_or(true, |(_, b)| held > b) {
            best = Some((w, held));
        }
    }
    best.map(|(w, _)| w)
}

/// Absorb a transport-level failure talking to worker `w` — the heart of
/// lineage recovery, run under the central lock.
///
/// Marks the worker dead, drops it from the location table, and for every
/// block that just lost its last replica walks the lineage: a `Done`
/// producer is re-armed for replay (its unavailable inputs recursively
/// likewise), a still-pending/running producer will re-produce the block on
/// its own, and a producer-less root is covered by the coordinator's root
/// journal. Re-armed tasks flow through the ordinary ready queue /
/// `complete()` path; their `pending_reads` re-increments keep replay
/// inputs from being refcount-freed mid-recovery.
///
/// Returns `Ok` when the death was absorbed (idempotently `Ok` for a
/// worker already marked dead); `Err` when the runtime must poison —
/// recovery disabled, no survivors, or an unrecoverable root.
fn handle_worker_death(st: &mut ClState, w: usize, inner: &ClusterInner) -> Result<()> {
    let bit = 1u64 << w;
    if st.alive & bit == 0 {
        return Ok(()); // already absorbed via another connection's failure
    }
    if !inner.recovery {
        bail!(
            "worker {} died and recovery is disabled",
            inner.conns[w].addr
        );
    }
    let t0 = Instant::now();
    st.alive &= !bit;
    if st.alive == 0 {
        // Nothing to replay onto. Count the loss, then poison.
        st.metrics.record_recovery(0, 0, 1);
        bail!(
            "worker {} died and no workers survive",
            inner.conns[w].addr
        );
    }
    // Drop the dead worker from the location table; blocks whose only
    // replica it held are lost (a replicated block shrugs the death off —
    // survivors still serve it).
    let mut lost: Vec<DataId> = Vec::new();
    for (id, mask) in st.copies.iter_mut().enumerate() {
        if *mask & bit != 0 {
            *mask &= !bit;
            if *mask == 0 {
                lost.push(id as DataId);
            }
        }
    }
    // Migrations onto the dead worker will never commit; clear the markers
    // so survivors re-pull instead of deferring to a doomed transfer.
    st.pulling.retain(|&(_, dest)| dest != w);

    // Lineage walk: find the completed producers to replay, transitively,
    // until every replay input is held by a survivor, resident on the
    // coordinator, or journaled in the root store.
    let live_lost: Vec<DataId> = lost
        .iter()
        .copied()
        .filter(|&id| !st.graph.data[id as usize].evicted)
        .collect();
    let mut queue: Vec<DataId> = live_lost.clone();
    let mut visited: HashSet<DataId> = queue.iter().copied().collect();
    // BTreeSet: ascending TaskId is topological order (tasks only read
    // earlier ids), which the re-arm pass below depends on.
    let mut replay: BTreeSet<TaskId> = BTreeSet::new();
    while let Some(id) = queue.pop() {
        let d = &st.graph.data[id as usize];
        if d.value.is_some() || st.copies.get(id as usize).copied().unwrap_or(0) != 0 {
            continue; // still available somewhere
        }
        match d.producer {
            None => {
                if inner.root_store.is_none() {
                    bail!(
                        "block {id} lost with worker {} has no producing task to replay",
                        inner.conns[w].addr
                    );
                }
                // Root: re-loadable from the coordinator's journal.
            }
            Some(p) => {
                if st.graph.tasks[p as usize].state == TaskState::Done && replay.insert(p) {
                    let reads: Vec<DataId> =
                        st.graph.tasks[p as usize].spec.reads.to_vec();
                    for r in reads {
                        if visited.insert(r) {
                            queue.push(r);
                        }
                    }
                }
                // A producer that is still pending/running/ready will
                // (re-)produce this block through the normal path.
            }
        }
    }

    // Re-arm the replay sub-graph in topological order: recompute each
    // task's readiness against the post-death world and re-register the
    // dependency edges `complete()` will re-consume. The `pending_reads`
    // increments are the deferred frees — replay inputs stay alive until
    // the replayed task completes again.
    for &tid in &replay {
        let reads: Vec<DataId> = st.graph.tasks[tid as usize].spec.reads.to_vec();
        let mut deps = 0u32;
        for &r in &reads {
            st.graph.data[r as usize].pending_reads += 1;
            let d = &st.graph.data[r as usize];
            let available = d.value.is_some()
                || st.copies.get(r as usize).copied().unwrap_or(0) != 0
                || (d.producer.is_none() && inner.root_store.is_some());
            if available {
                continue;
            }
            if let Some(p) = d.producer {
                if st.graph.tasks[p as usize].state != TaskState::Done {
                    deps += 1;
                    st.graph.tasks[p as usize].dependents.push(tid);
                }
            }
        }
        let node = &mut st.graph.tasks[tid as usize];
        node.deps_remaining = deps;
        if deps == 0 {
            node.state = TaskState::Ready;
            st.ready.push_back(tid);
        } else {
            node.state = TaskState::Pending;
        }
    }
    let ms = ((t0.elapsed().as_micros() as u64) + 999) / 1000;
    st.metrics
        .record_recovery(live_lost.len() as u64, replay.len() as u64, ms.max(1));
    Ok(())
}

/// Collect remote frees for every block the graph just declared dead,
/// clearing their location entries.
fn drain_frees(st: &mut ClState, n_workers: usize) -> Vec<(usize, Vec<u32>)> {
    if st.graph.dead_files.is_empty() {
        return Vec::new();
    }
    let dead = std::mem::take(&mut st.graph.dead_files);
    let mut per: Vec<Vec<u32>> = vec![Vec::new(); n_workers];
    for id in dead {
        let Some(m) = st.copies.get_mut(id as usize) else {
            continue;
        };
        let mask = std::mem::take(m);
        for (w, ids) in per.iter_mut().enumerate() {
            if mask & (1u64 << w) != 0 {
                ids.push(id);
            }
        }
    }
    per.into_iter()
        .enumerate()
        .filter(|(_, ids)| !ids.is_empty())
        .collect()
}

/// Where one task input comes from.
enum Source {
    /// Rare: a value still resident in the coordinator table.
    Local(Arc<Block>),
    /// Re-load a root block from the coordinator's journal (its every
    /// worker replica died).
    Root,
    /// Fetch from worker `serve`; `pull_from` first migrates the block
    /// worker-to-worker from that peer onto `serve`.
    Remote { serve: usize, pull_from: Option<usize> },
}

struct FetchPlan {
    id: DataId,
    source: Source,
}

/// A claimed task with its transfer plan, ready to execute off-lock.
struct ExecPlan {
    tid: TaskId,
    name: &'static str,
    body: TaskBody,
    reads: Vec<DataId>,
    out_ids: Vec<DataId>,
    placement: usize,
    /// Further live workers mirroring the outputs (k-way replication).
    replicas: Vec<usize>,
    fetches: Vec<FetchPlan>,
}

/// Claim-time planning under the central lock: verify every input is
/// resolvable, choose the placement worker among survivors, count locality
/// hits/misses, and register in-flight pulls. Returns `Ok(None)` when the
/// task must *park* — an input's every replica died and its producer is
/// mid-replay, so the task re-pends on that producer and re-readies
/// through the ordinary dependency path when the replay completes.
fn build_plan(
    st: &mut ClState,
    tid: TaskId,
    transfer: TransferMode,
    inner: &ClusterInner,
) -> Result<Option<ExecPlan>> {
    let n_workers = inner.conns.len();
    let spec = &st.graph.tasks[tid as usize].spec;
    let name = spec.name;
    let body = spec.body.clone();
    let reads: Vec<DataId> = spec.reads.to_vec();
    let out_ids: Vec<DataId> = spec.writes.to_vec();

    // First-occurrence-ordered dedup; linear, since this runs under the
    // scheduler lock and collection tasks read hundreds of blocks.
    let mut uniq: Vec<DataId> = Vec::with_capacity(reads.len());
    let mut seen: HashSet<DataId> = HashSet::with_capacity(reads.len());
    for &r in &reads {
        if seen.insert(r) {
            uniq.push(r);
        }
    }
    // Resolution per input. Readiness guarantees every input was
    // materialized *at some point*; a hole that neither a survivor, the
    // root journal, nor an in-flight replay covers is a real error and
    // must poison the runtime, not run with empty inputs.
    enum Resolve {
        Local(Arc<Block>),
        Root,
        Located { mask: u64, bytes: usize },
        Park,
    }
    let mut infos: Vec<Resolve> = Vec::with_capacity(uniq.len());
    let mut parked: Vec<TaskId> = Vec::new();
    for &r in &uniq {
        let d = &st.graph.data[r as usize];
        if let Some(v) = &d.value {
            infos.push(Resolve::Local(Arc::clone(v)));
            continue;
        }
        let mask = st.copies.get(r as usize).copied().unwrap_or(0);
        if mask != 0 {
            infos.push(Resolve::Located {
                mask,
                bytes: d.meta.bytes(),
            });
            continue;
        }
        // No replica anywhere: recoverable only via replay or the journal.
        match d.producer {
            Some(p)
                if inner.recovery
                    && st.graph.tasks[p as usize].state != TaskState::Done =>
            {
                parked.push(p);
                infos.push(Resolve::Park);
            }
            None if inner.recovery && inner.root_store.is_some() => {
                infos.push(Resolve::Root);
            }
            _ => bail!("input {r} unresolved for ready task (no worker holds it)"),
        }
    }
    if !parked.is_empty() {
        // Park: one dependency edge per lost input occurrence; each is
        // balanced by the producer's next `complete()`.
        let deps = parked.len() as u32;
        for p in parked {
            st.graph.tasks[p as usize].dependents.push(tid);
        }
        let node = &mut st.graph.tasks[tid as usize];
        node.deps_remaining = deps;
        node.state = TaskState::Pending;
        return Ok(None);
    }

    let weighted: Vec<(u64, usize)> = infos
        .iter()
        .filter_map(|r| match r {
            Resolve::Located { mask, bytes } => Some((*mask, *bytes)),
            _ => None,
        })
        .collect();
    let placement = match choose_placement(&weighted, n_workers, st.alive) {
        Some(w) => w,
        None => next_rr(st, n_workers),
    };
    let bit = 1u64 << placement;
    // k-way replication: the lowest-indexed other live workers mirror the
    // outputs (deterministic given the same survivor set).
    let k = inner.replicate.min(st.alive.count_ones() as usize).max(1);
    let mut replicas: Vec<usize> = Vec::new();
    for w in 0..n_workers {
        if replicas.len() + 1 >= k {
            break;
        }
        if w != placement && st.alive & (1u64 << w) != 0 {
            replicas.push(w);
        }
    }

    let mut hits = 0u64;
    let mut transfers = 0u64;
    let mut fetches = Vec::with_capacity(uniq.len());
    for (&id, info) in uniq.iter().zip(&infos) {
        let source = match info {
            Resolve::Local(v) => {
                hits += 1;
                Source::Local(Arc::clone(v))
            }
            // A journal reload costs disk I/O, not wire traffic.
            Resolve::Root => {
                hits += 1;
                Source::Root
            }
            Resolve::Park => unreachable!("parked plans returned above"),
            Resolve::Located { mask, .. } => {
                if mask & bit != 0 {
                    hits += 1;
                    Source::Remote {
                        serve: placement,
                        pull_from: None,
                    }
                } else {
                    transfers += 1;
                    let src = mask.trailing_zeros() as usize;
                    if transfer == TransferMode::Pull
                        && !st.pulling.contains(&(id, placement))
                    {
                        st.pulling.insert((id, placement));
                        Source::Remote {
                            serve: placement,
                            pull_from: Some(src),
                        }
                    } else {
                        // Relay mode, or the same migration is already in
                        // flight: read from a stable holder.
                        Source::Remote {
                            serve: src,
                            pull_from: None,
                        }
                    }
                }
            }
        };
        fetches.push(FetchPlan { id, source });
    }
    st.metrics.record_locality(hits, transfers);
    Ok(Some(ExecPlan {
        tid,
        name,
        body,
        reads,
        out_ids,
        placement,
        replicas,
        fetches,
    }))
}

/// Run one planned task off-lock: transfers, closure, output push, publish.
/// Transport failures classify as [`ClusterFailure::WorkerDown`] and route
/// through [`handle_worker_death`] + requeue instead of poisoning.
fn execute_plan(inner: &Arc<ClusterInner>, plan: ExecPlan) {
    let mut wire_bytes = 0u64;
    let mut pulled: Vec<(DataId, usize)> = Vec::new();
    let mut cache: HashMap<DataId, Arc<Block>> = HashMap::new();
    let mut failure: Option<ClusterFailure> = None;

    // ---- Input transfers ----
    for f in &plan.fetches {
        match &f.source {
            Source::Local(b) => {
                cache.insert(f.id, Arc::clone(b));
            }
            Source::Root => {
                // Every worker replica of this root died; re-load it from
                // the coordinator's journal (disk, not wire).
                let store = inner
                    .root_store
                    .as_ref()
                    .expect("Source::Root is only planned with a root store");
                match store.fault(f.id) {
                    Ok(b) => {
                        cache.insert(f.id, Arc::new(b));
                    }
                    Err(e) => {
                        failure = Some(ClusterFailure::Protocol {
                            msg: format!("root journal reload of block {}: {e:#}", f.id),
                        });
                    }
                }
                if failure.is_some() {
                    break;
                }
            }
            Source::Remote { serve, pull_from } => {
                if let Some(src) = pull_from {
                    let req = Request::Pull {
                        id: f.id,
                        from: inner.conns[*src].addr.clone(),
                    };
                    match inner.conns[*serve].call(&req) {
                        Ok((Response::Pulled { bytes }, io)) => {
                            wire_bytes += io + bytes;
                            pulled.push((f.id, *serve));
                        }
                        // The *peer* being pulled from is unreachable: the
                        // responding worker is healthy, its source is dead.
                        Ok((Response::PullPeerDown(m), io)) => {
                            wire_bytes += io;
                            failure = Some(ClusterFailure::WorkerDown {
                                w: *src,
                                msg: format!(
                                    "pull peer {}: {m}",
                                    inner.conns[*src].addr
                                ),
                            });
                        }
                        Ok((Response::Err(m), io)) => {
                            wire_bytes += io;
                            failure = Some(ClusterFailure::Protocol {
                                msg: format!("worker {}: {m}", inner.conns[*serve].addr),
                            });
                        }
                        Ok((other, io)) => {
                            wire_bytes += io;
                            failure = Some(ClusterFailure::Protocol {
                                msg: format!(
                                    "worker {}: unexpected response {other:?} to Pull",
                                    inner.conns[*serve].addr
                                ),
                            });
                        }
                        Err(e) => {
                            failure = Some(ClusterFailure::WorkerDown {
                                w: *serve,
                                msg: format!(
                                    "worker {}: {e:#}",
                                    inner.conns[*serve].addr
                                ),
                            });
                        }
                    }
                    if failure.is_some() {
                        break;
                    }
                }
                match inner.fetch_block(*serve, f.id) {
                    Ok((b, io)) => {
                        wire_bytes += io;
                        cache.insert(f.id, Arc::new(b));
                    }
                    Err(e) => failure = Some(e),
                }
                if failure.is_some() {
                    break;
                }
            }
        }
    }

    // ---- Run the closure, then push outputs to placement + replicas ----
    let outcome: Result<(), ClusterFailure> = match failure {
        Some(f) => Err(f),
        None => {
            let result: Result<Vec<Block>> = match &plan.body {
                TaskBody::Shared(func) => {
                    let ins: Vec<Arc<Block>> = plan
                        .reads
                        .iter()
                        .map(|r| Arc::clone(cache.get(r).expect("every read was fetched")))
                        .collect();
                    func(&ins)
                }
                // No exclusive grants on the cluster backend: the fetched
                // copy is already private to this task, and the
                // authoritative value lives on a worker.
                TaskBody::Owned(func) => {
                    let ins: Vec<TaskInput> = plan
                        .reads
                        .iter()
                        .map(|r| {
                            TaskInput::Shared(Arc::clone(
                                cache.get(r).expect("every read was fetched"),
                            ))
                        })
                        .collect();
                    func(ins)
                }
            };
            drop(cache);
            let mut targets = Vec::with_capacity(1 + plan.replicas.len());
            targets.push(plan.placement);
            targets.extend_from_slice(&plan.replicas);
            push_outputs(inner, &targets, &plan.out_ids, result, &mut wire_bytes)
        }
    };

    // ---- Publish under the central lock ----
    let frees = {
        let mut guard = inner.state.lock().unwrap();
        let st = &mut *guard;
        st.running -= 1;
        // Commit completed migrations to the location table (only onto
        // workers still alive — a concurrent death marking must not be
        // resurrected by a stale success) and clear every in-flight marker
        // this plan registered (performed or not).
        for &(id, w) in &pulled {
            if st.alive & (1u64 << w) != 0 {
                ensure_copies(&mut st.copies, id);
                st.copies[id as usize] |= 1u64 << w;
            }
        }
        for f in &plan.fetches {
            if let Source::Remote {
                serve,
                pull_from: Some(_),
            } = &f.source
            {
                st.pulling.remove(&(f.id, *serve));
            }
        }
        st.metrics.record_wire(wire_bytes);
        match outcome {
            // The placement worker died between our pushes and this
            // publish: the outputs went down with it, so requeue instead
            // of completing with phantom locations.
            Ok(()) if st.alive & (1u64 << plan.placement) == 0 => {
                st.graph.tasks[plan.tid as usize].state = TaskState::Ready;
                st.ready.push_back(plan.tid);
            }
            Ok(()) => {
                let mut bits = 1u64 << plan.placement;
                for &r in &plan.replicas {
                    if st.alive & (1u64 << r) != 0 {
                        bits |= 1u64 << r;
                    }
                }
                for &o in &plan.out_ids {
                    let d = &mut st.graph.data[o as usize];
                    d.spilled = true;
                    d.on_disk = true;
                    ensure_copies(&mut st.copies, o);
                    st.copies[o as usize] = bits;
                    st.graph.touch(o);
                }
                let done = st.graph.complete(plan.tid, None);
                for bytes in done.evicted {
                    st.metrics.record_evicted(bytes);
                }
                // Outputs whose every owner released before materialization
                // are dead on arrival: free them remotely right away.
                for &o in &plan.out_ids {
                    if let Some(bytes) = st.graph.try_evict(o) {
                        st.metrics.record_evicted(bytes);
                    }
                }
                for dep in done.now_ready {
                    st.ready.push_back(dep);
                }
            }
            Err(ClusterFailure::WorkerDown { w, msg }) => {
                match handle_worker_death(st, w, inner) {
                    // Recovery absorbed the death: the lost sub-graph is
                    // re-armed, so requeue this task — its inputs resolve
                    // against survivors (or park on the replay) next plan.
                    Ok(()) => {
                        st.graph.tasks[plan.tid as usize].state = TaskState::Ready;
                        st.ready.push_back(plan.tid);
                    }
                    Err(e) => {
                        st.graph.tasks[plan.tid as usize].state = TaskState::Failed;
                        st.error.get_or_insert(format!(
                            "task `{}` failed on cluster backend: {msg} ({e:#})",
                            plan.name
                        ));
                    }
                }
            }
            Err(ClusterFailure::Protocol { msg }) => {
                st.graph.tasks[plan.tid as usize].state = TaskState::Failed;
                st.error.get_or_insert(format!(
                    "task `{}` failed on cluster backend: {msg}",
                    plan.name
                ));
            }
        }
        drain_frees(st, inner.conns.len())
    };
    inner.send_frees(frees);
    inner.cv.notify_all();
}

/// Validate a task's result and `Put` each output on every target worker
/// (placement first, then replicas). Protocol errors carry the worker
/// address (the poison message the kill-a-worker contract requires);
/// transport errors classify the target as down so the caller can recover
/// and requeue.
fn push_outputs(
    inner: &ClusterInner,
    targets: &[usize],
    out_ids: &[DataId],
    result: Result<Vec<Block>>,
    wire_bytes: &mut u64,
) -> Result<(), ClusterFailure> {
    let outs = match result {
        Ok(o) => o,
        Err(e) => {
            return Err(ClusterFailure::Protocol {
                msg: format!("{e:#}"),
            })
        }
    };
    if outs.len() != out_ids.len() {
        return Err(ClusterFailure::Protocol {
            msg: format!("returned {} outputs, declared {}", outs.len(), out_ids.len()),
        });
    }
    for (&id, block) in out_ids.iter().zip(outs) {
        let mut block = Some(block);
        for (i, &t) in targets.iter().enumerate() {
            let conn = &inner.conns[t];
            // The last target consumes the block; earlier ones get clones.
            let payload = if i + 1 == targets.len() {
                block.take().expect("one consume per output")
            } else {
                block.as_ref().expect("clone precedes consume").clone()
            };
            match conn.call(&Request::Put { id, block: payload }) {
                Ok((Response::Ok, io)) => *wire_bytes += io,
                Ok((Response::Err(m), io)) => {
                    *wire_bytes += io;
                    return Err(ClusterFailure::Protocol {
                        msg: format!("worker {}: {m}", conn.addr),
                    });
                }
                Ok((other, io)) => {
                    *wire_bytes += io;
                    return Err(ClusterFailure::Protocol {
                        msg: format!(
                            "worker {}: unexpected response {other:?} to Put",
                            conn.addr
                        ),
                    });
                }
                Err(e) => {
                    return Err(ClusterFailure::WorkerDown {
                        w: t,
                        msg: format!("worker {}: {e:#}", conn.addr),
                    })
                }
            }
        }
    }
    Ok(())
}

fn cluster_exec_loop(inner: Arc<ClusterInner>) {
    loop {
        // ---- Acquire + claim + plan under one lock acquisition ----
        let plan = {
            let mut guard = inner.state.lock().unwrap();
            let tid = loop {
                if guard.shutdown {
                    return;
                }
                if let Some(t) = guard.ready.pop_front() {
                    break t;
                }
                // Timeout is a belt-and-braces rescan (pushes notify under
                // the same mutex), mirroring the local executor.
                let (g, _) = inner
                    .cv
                    .wait_timeout(guard, Duration::from_millis(10))
                    .unwrap();
                guard = g;
            };
            let st = &mut *guard;
            st.graph.tasks[tid as usize].state = TaskState::Running;
            st.running += 1;
            match build_plan(st, tid, inner.transfer, &inner) {
                Ok(Some(p)) => Ok(Some(p)),
                // Parked: the task re-pended on a replaying producer and
                // will re-ready through the dependency path.
                Ok(None) => {
                    st.running -= 1;
                    Ok(None)
                }
                Err(e) => {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.running -= 1;
                    st.error
                        .get_or_insert(format!("task `{name}` failed: {e:#}"));
                    Err(())
                }
            }
        };
        match plan {
            Ok(Some(p)) => execute_plan(&inner, p),
            Ok(None) | Err(()) => inner.cv.notify_all(),
        }
    }
}

/// The coordinator backend. Construct via [`ClusterOptions`] and wrap with
/// `Runtime::cluster`; every ds-array operation, estimator, lazy view and
/// fused expression then runs unmodified against remote block memory.
pub struct ClusterExecutor {
    inner: Arc<ClusterInner>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    children: Mutex<Vec<Child>>,
    /// Connection indices `>= owned_from` belong to workers we spawned (and
    /// shut down on drop); earlier ones are externally managed.
    owned_from: usize,
}

impl ClusterExecutor {
    pub fn new(opts: ClusterOptions) -> Result<Self> {
        let owned_from = opts.addrs.len();
        // Created before any worker spawns so a journal failure can't leak
        // child processes.
        let root_store = if opts.recovery {
            Some(BlockStore::in_temp().context("creating root-block journal")?)
        } else {
            None
        };
        let mut children = Vec::new();
        let conns = match Self::boot(&opts, &mut children) {
            Ok(c) => c,
            Err(e) => {
                // Never leak spawned processes on a failed boot.
                for mut child in children {
                    child.kill().ok();
                    child.wait().ok();
                }
                return Err(e);
            }
        };

        let alive = full_mask(conns.len());
        let inner = Arc::new(ClusterInner {
            state: Mutex::new(ClState {
                graph: Graph::default(),
                ready: VecDeque::new(),
                running: 0,
                shutdown: false,
                error: None,
                metrics: Metrics::default(),
                copies: Vec::new(),
                pulling: HashSet::new(),
                rr: 0,
                alive,
            }),
            cv: Condvar::new(),
            conns,
            transfer: opts.transfer,
            recovery: opts.recovery,
            replicate: opts.replicate.max(1),
            root_store,
        });
        let threads = (0..opts.threads.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || cluster_exec_loop(inner))
            })
            .collect();
        Ok(Self {
            inner,
            threads: Mutex::new(threads),
            children: Mutex::new(children),
            owned_from,
        })
    }

    /// Spawn requested workers, connect to every address, and ping each
    /// once. Spawned children accumulate in `children` so the caller can
    /// reap them if any later step fails.
    fn boot(opts: &ClusterOptions, children: &mut Vec<Child>) -> Result<Vec<WorkerConn>> {
        let mut addrs = opts.addrs.clone();
        if opts.spawn > 0 {
            let program = match &opts.program {
                Some(p) => p.clone(),
                None => std::env::current_exe().context("locating worker binary")?,
            };
            for _ in 0..opts.spawn {
                let (child, addr) = spawn_worker_process(&program, opts.worker_budget_bytes)?;
                children.push(child);
                addrs.push(addr);
            }
        }
        if addrs.is_empty() {
            bail!("cluster backend needs at least one worker (addrs or spawn)");
        }
        if addrs.len() > 64 {
            bail!(
                "cluster backend supports at most 64 workers, got {}",
                addrs.len()
            );
        }
        let mut conns = Vec::with_capacity(addrs.len());
        for a in &addrs {
            let stream =
                TcpStream::connect(a).with_context(|| format!("connecting to worker {a}"))?;
            stream.set_nodelay(true).ok();
            conns.push(WorkerConn {
                addr: a.clone(),
                stream: Mutex::new(stream),
            });
        }
        for c in &conns {
            match c.call(&Request::Ping)? {
                (Response::Ok, _) => {}
                (other, _) => bail!("worker {} answered ping with {other:?}", c.addr),
            }
        }
        Ok(conns)
    }

    /// Addresses of the connected workers, in location-table bit order.
    pub fn worker_addrs(&self) -> Vec<String> {
        self.inner.conns.iter().map(|c| c.addr.clone()).collect()
    }
}

impl Executor for ClusterExecutor {
    fn workers(&self) -> usize {
        self.inner.conns.len()
    }

    fn put_block(&self, block: Block) -> DataId {
        let meta = block.meta();
        let (id, targets) = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            let id = st.graph.put_block(meta, None);
            ensure_copies(&mut st.copies, id);
            // k distinct live targets, round-robin so roots stay spread.
            let k = self
                .inner
                .replicate
                .min(st.alive.count_ones() as usize)
                .max(1);
            let mut targets: Vec<usize> = Vec::with_capacity(k);
            while targets.len() < k {
                let w = next_rr(st, self.inner.conns.len());
                if !targets.contains(&w) {
                    targets.push(w);
                }
            }
            (id, targets)
        };
        // Roots have no producing task to replay, so journal them to the
        // coordinator's local store first — recovery's last line when every
        // worker replica dies. Journal files persist until teardown: a root
        // evicted from workers before a death may still anchor a later
        // replay.
        if let Some(store) = &self.inner.root_store {
            if let Err(e) = store.spill(id, &block) {
                let mut st = self.inner.state.lock().unwrap();
                st.error
                    .get_or_insert(format!("put_block({id}) root journal: {e:#}"));
                return id;
            }
        }
        // The id is not visible to any submitter until we return, so the
        // pushes can run outside the lock without racing a reader.
        let mut block = Some(block);
        let mut placed = 0u64;
        let mut wire = 0u64;
        for (i, &w) in targets.iter().enumerate() {
            let payload = if i + 1 == targets.len() {
                block.take().expect("one consume per put")
            } else {
                block.as_ref().expect("clone precedes consume").clone()
            };
            match self.inner.conns[w].call(&Request::Put { id, block: payload }) {
                Ok((Response::Ok, bytes)) => {
                    wire += bytes;
                    placed |= 1u64 << w;
                }
                Ok((other, _)) => {
                    let msg = match other {
                        Response::Err(m) => m,
                        o => format!("unexpected response {o:?} to Put"),
                    };
                    let mut st = self.inner.state.lock().unwrap();
                    st.error.get_or_insert(format!(
                        "put_block({id}) on worker {}: {msg}",
                        self.inner.conns[w].addr
                    ));
                    return id;
                }
                Err(e) => {
                    // Transport failure: the target died. With recovery the
                    // journal already covers this root, so absorb the death
                    // and move on; without it, poison with the old message.
                    let mut st = self.inner.state.lock().unwrap();
                    match handle_worker_death(&mut st, w, &self.inner) {
                        Ok(()) => continue,
                        Err(death) => {
                            st.error.get_or_insert(format!(
                                "put_block({id}) on worker {}: {e:#} ({death:#})",
                                self.inner.conns[w].addr
                            ));
                            return id;
                        }
                    }
                }
            }
        }
        {
            let mut st = self.inner.state.lock().unwrap();
            placed &= st.alive;
            let d = &mut st.graph.data[id as usize];
            if placed != 0 {
                d.spilled = true;
                d.on_disk = true;
            } else if self.inner.root_store.is_some() {
                // Every target died mid-put; the journal alone holds it.
                d.spilled = true;
                d.on_disk = true;
            }
            st.copies[id as usize] = placed;
            st.metrics.record_wire(wire);
        }
        id
    }

    fn submit_batch(&self, tasks: Vec<TaskSubmit>) -> Vec<Vec<DataId>> {
        self.submit_batch_releasing(tasks, &[])
    }

    fn submit_batch_releasing(
        &self,
        tasks: Vec<TaskSubmit>,
        release: &[DataId],
    ) -> Vec<Vec<DataId>> {
        let mut outs_all = Vec::with_capacity(tasks.len());
        let mut any_ready = false;
        let frees = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            for t in tasks {
                let (tid, outs, ready) = st.graph.submit_record(t, &mut st.metrics);
                if ready {
                    st.ready.push_back(tid);
                    any_ready = true;
                }
                outs_all.push(outs);
            }
            for &id in release {
                if let Some(bytes) = st.graph.release(id) {
                    st.metrics.record_evicted(bytes);
                }
            }
            drain_frees(st, self.inner.conns.len())
        };
        self.inner.send_frees(frees);
        if any_ready {
            self.inner.cv.notify_all();
        }
        outs_all
    }

    fn wait(&self, id: DataId) -> Result<Arc<Block>> {
        // What the off-lock half of each retry round does.
        enum Plan {
            Fetch(usize),
            Root,
        }
        // Find a holder under the lock; fetch outside it (fetch-on-demand:
        // the value is returned to the caller, never re-installed in the
        // coordinator table — collect() streams through bounded memory).
        // A fetch that hits a dying worker routes through recovery and
        // retries against the replayed locations instead of poisoning.
        loop {
            let plan = {
                let mut st = self.inner.state.lock().unwrap();
                loop {
                    if let Some(err) = &st.error {
                        bail!("runtime poisoned by task failure: {err}");
                    }
                    let d = &st.graph.data[id as usize];
                    if let Some(v) = &d.value {
                        let v = Arc::clone(v);
                        st.graph.touch(id);
                        return Ok(v);
                    }
                    if d.spilled {
                        let mask = st.copies.get(id as usize).copied().unwrap_or(0);
                        if mask != 0 {
                            break Plan::Fetch(mask.trailing_zeros() as usize);
                        }
                        // Every replica died. Roots reload from the
                        // journal; produced blocks wait for their replay
                        // (re-armed by the death handler) to land.
                        if self.inner.recovery {
                            match d.producer {
                                None if self.inner.root_store.is_some() => {
                                    break Plan::Root;
                                }
                                Some(p)
                                    if st.graph.tasks[p as usize].state
                                        != TaskState::Done =>
                                {
                                    if st.running == 0 && st.ready.is_empty() {
                                        bail!(
                                            "wait({id}) would deadlock: \
                                             replay producer stuck"
                                        );
                                    }
                                    st = self.inner.cv.wait(st).unwrap();
                                    continue;
                                }
                                _ => {}
                            }
                        }
                        bail!("wait({id}): no worker holds this block");
                    }
                    if d.evicted {
                        bail!(
                            "wait({id}): block was reclaimed (all handles released); \
                             pin it to keep it resident"
                        );
                    }
                    if st.running == 0 && st.ready.is_empty() {
                        bail!("wait({id}) would deadlock: no runnable producer");
                    }
                    st = self.inner.cv.wait(st).unwrap();
                }
            };
            match plan {
                Plan::Root => {
                    let store = self
                        .inner
                        .root_store
                        .as_ref()
                        .expect("Plan::Root only with a root store");
                    match store.fault(id) {
                        Ok(block) => return Ok(Arc::new(block)),
                        Err(e) => {
                            let mut st = self.inner.state.lock().unwrap();
                            st.error.get_or_insert(format!(
                                "wait({id}) root journal reload failed: {e:#}"
                            ));
                            drop(st);
                            self.inner.cv.notify_all();
                            bail!("wait({id}): root journal reload failed: {e:#}");
                        }
                    }
                }
                Plan::Fetch(serve) => match self.inner.fetch_block(serve, id) {
                    Ok((block, bytes)) => {
                        self.inner.state.lock().unwrap().metrics.record_wire(bytes);
                        return Ok(Arc::new(block));
                    }
                    Err(ClusterFailure::WorkerDown { w, msg }) => {
                        let recovered = {
                            let mut st = self.inner.state.lock().unwrap();
                            match handle_worker_death(&mut st, w, &self.inner) {
                                Ok(()) => true,
                                Err(e) => {
                                    st.error.get_or_insert(format!(
                                        "wait({id}) fetch failed: {msg} ({e:#})"
                                    ));
                                    false
                                }
                            }
                        };
                        self.inner.cv.notify_all();
                        if recovered {
                            continue; // retry against the recovered locations
                        }
                        bail!("wait({id}) fetch failed: {msg}");
                    }
                    Err(ClusterFailure::Protocol { msg }) => {
                        // An application-level failure from a live worker
                        // is real: poison so barriers and later waits
                        // surface it too.
                        {
                            let mut st = self.inner.state.lock().unwrap();
                            st.error
                                .get_or_insert(format!("wait({id}) fetch failed: {msg}"));
                        }
                        self.inner.cv.notify_all();
                        bail!("wait({id}) fetch failed: {msg}");
                    }
                },
            }
        }
    }

    fn barrier(&self) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            if st.running == 0 && st.ready.is_empty() {
                let stuck = st
                    .graph
                    .tasks
                    .iter()
                    .filter(|t| t.state == TaskState::Pending)
                    .count();
                if stuck > 0 {
                    bail!("barrier: {stuck} tasks stuck pending (malformed graph)");
                }
                return Ok(());
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    fn metrics(&self) -> Metrics {
        self.inner.state.lock().unwrap().metrics.clone()
    }

    fn retain(&self, ids: &[DataId]) {
        let mut st = self.inner.state.lock().unwrap();
        for &id in ids {
            st.graph.retain(id);
        }
    }

    fn release(&self, ids: &[DataId]) {
        let frees = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            for &id in ids {
                if let Some(bytes) = st.graph.release(id) {
                    st.metrics.record_evicted(bytes);
                }
            }
            drain_frees(st, self.inner.conns.len())
        };
        self.inner.send_frees(frees);
    }

    fn pin(&self, id: DataId) {
        let mut st = self.inner.state.lock().unwrap();
        st.graph.data[id as usize].pinned = true;
    }
}

impl Drop for ClusterExecutor {
    fn drop(&mut self) {
        let alive = {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            st.alive
        };
        self.inner.cv.notify_all();
        for h in self.threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // Gracefully stop the workers we spawned; externally-managed ones
        // (connected by address) stay up. Workers already marked dead get
        // no shutdown message — writing to a broken pipe is pointless and
        // their children are reaped below without the graceful wait.
        let mut children = self.children.lock().unwrap();
        if !children.is_empty() {
            for (i, conn) in self.inner.conns.iter().enumerate().skip(self.owned_from) {
                if alive & (1u64 << i) != 0 {
                    let _ = conn.call(&Request::Shutdown);
                }
            }
        }
        for (ci, child) in children.iter_mut().enumerate() {
            let w = self.owned_from + ci;
            let mut reaped = false;
            if alive & (1u64 << w) != 0 {
                for _ in 0..50 {
                    match child.try_wait() {
                        Ok(Some(_)) => {
                            reaped = true;
                            break;
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(20)),
                        Err(_) => break,
                    }
                }
            }
            if !reaped {
                // Dead or wedged workers: teardown must never hang.
                child.kill().ok();
                child.wait().ok();
            }
        }
    }
}

/// Spawn one `dsarray worker --listen 127.0.0.1:0` process and parse the
/// `LISTENING <addr>` line it prints once bound.
pub fn spawn_worker_process(
    program: &Path,
    memory_budget_bytes: Option<u64>,
) -> Result<(Child, String)> {
    spawn_worker_process_with(program, memory_budget_bytes, None)
}

/// [`spawn_worker_process`] with a deterministic fault schedule
/// (`--fault-plan`, see [`FaultPlan::spec_for`](super::faults::FaultPlan::spec_for))
/// — the chaos-test entry point.
pub fn spawn_worker_process_with(
    program: &Path,
    memory_budget_bytes: Option<u64>,
    fault_spec: Option<&str>,
) -> Result<(Child, String)> {
    let mut cmd = Command::new(program);
    cmd.arg("worker")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .stdout(Stdio::piped());
    if let Some(b) = memory_budget_bytes {
        cmd.arg("--memory-budget-bytes").arg(b.to_string());
    }
    if let Some(spec) = fault_spec.filter(|s| !s.is_empty()) {
        cmd.arg("--fault-plan").arg(spec);
    }
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawning worker process {}", program.display()))?;
    let stdout = child.stdout.take().expect("stdout piped");
    let mut line = String::new();
    let read = std::io::BufRead::read_line(&mut BufReader::new(stdout), &mut line);
    match read {
        Ok(_) => match line.trim().strip_prefix("LISTENING ") {
            Some(addr) if !addr.is_empty() => Ok((child, addr.to_string())),
            _ => {
                child.kill().ok();
                child.wait().ok();
                bail!("worker did not announce an address (got {line:?})");
            }
        },
        Err(e) => {
            child.kill().ok();
            child.wait().ok();
            Err(anyhow!(e).context("reading worker announcement"))
        }
    }
}

// ---------------------------------------------------------------------------
// Worker daemon
// ---------------------------------------------------------------------------

/// Configuration of a worker process (`dsarray worker`).
#[derive(Clone, Debug, Default)]
pub struct WorkerOptions {
    /// Resident high-water mark: past it, least-recently-used blocks spill
    /// to this worker's own [`BlockStore`] directory and fault back on
    /// `Get` — per-worker out-of-core, no coordinator involvement.
    pub memory_budget_bytes: Option<u64>,
    /// Deterministic fault schedule for this worker (`--fault-plan`), in
    /// [`FaultPlan::parse_spec`](super::faults::FaultPlan::parse_spec)
    /// syntax, e.g. `die@7` or `drop@3,die@9`. `None`/empty = fault-free.
    pub fault_spec: Option<String>,
    /// Whether a crash ([`Request::Crash`] or an injected
    /// [`FaultKind::Die`]) exits the whole process (real worker daemons) or
    /// only silences this worker forever (in-process test workers, which
    /// share the test binary's process).
    pub crash_exits: bool,
}

/// State shared by every connection thread of one worker: the block table,
/// the fault schedule, and the dead flag an in-process crash raises.
struct WorkerShared {
    blocks: Mutex<WorkerBlocks>,
    faults: Option<FaultState>,
    /// Set on crash when `crash_exits` is false: every connection goes
    /// silent and new requests are dropped, indistinguishable on the wire
    /// from a killed process.
    dead: AtomicBool,
    crash_exits: bool,
}

enum WorkerEntry {
    Mem {
        block: Arc<Block>,
        bytes: u64,
        last_use: u64,
    },
    Disk {
        bytes: u64,
    },
}

/// A worker's block table: in-memory values plus a disk tier under budget
/// pressure. All access is serialized through one mutex; per-request work
/// is small next to the wire time, with one known exception — faulting a
/// spilled block back in reads its file under the lock, stalling this
/// worker's other connections for the I/O. Accepted for now: the spill
/// tier only engages under an explicit budget, and lock-free faulting
/// needs per-entry in-flight states that aren't worth it yet.
struct WorkerBlocks {
    entries: HashMap<u32, WorkerEntry>,
    resident: u64,
    clock: u64,
    budget: Option<u64>,
    store: Option<BlockStore>,
    spilled: u64,
    pulled_bytes: u64,
}

impl WorkerBlocks {
    fn insert(&mut self, id: u32, block: Block) -> Result<()> {
        self.remove(id);
        let bytes = block.meta().bytes() as u64;
        self.clock += 1;
        self.entries.insert(
            id,
            WorkerEntry::Mem {
                block: Arc::new(block),
                bytes,
                last_use: self.clock,
            },
        );
        self.resident += bytes;
        self.enforce_budget()
    }

    /// Spill least-recently-used resident blocks until back under budget.
    fn enforce_budget(&mut self) -> Result<()> {
        let Some(budget) = self.budget else {
            return Ok(());
        };
        while self.resident > budget {
            let victim = self
                .entries
                .iter()
                .filter_map(|(&id, e)| match e {
                    WorkerEntry::Mem { last_use, .. } => Some((*last_use, id)),
                    WorkerEntry::Disk { .. } => None,
                })
                .min();
            let Some((_, id)) = victim else {
                break;
            };
            let spill_bytes = {
                let store = self.store.as_ref().expect("budget implies store");
                match self.entries.get(&id) {
                    Some(WorkerEntry::Mem { block, bytes, .. }) => {
                        store.spill(id, block.as_ref())?;
                        *bytes
                    }
                    _ => unreachable!("victim chosen from resident entries"),
                }
            };
            self.entries.insert(id, WorkerEntry::Disk { bytes: spill_bytes });
            self.resident -= spill_bytes;
            self.spilled += 1;
        }
        Ok(())
    }

    fn get(&mut self, id: u32) -> Result<Arc<Block>> {
        enum Kind {
            Missing,
            Mem,
            Disk(u64),
        }
        let kind = match self.entries.get(&id) {
            None => Kind::Missing,
            Some(WorkerEntry::Mem { .. }) => Kind::Mem,
            Some(WorkerEntry::Disk { bytes }) => Kind::Disk(*bytes),
        };
        match kind {
            Kind::Missing => bail!("block {id} not found on this worker"),
            Kind::Mem => {
                self.clock += 1;
                let clock = self.clock;
                let Some(WorkerEntry::Mem { block, last_use, .. }) =
                    self.entries.get_mut(&id)
                else {
                    unreachable!()
                };
                *last_use = clock;
                Ok(Arc::clone(block))
            }
            Kind::Disk(bytes) => {
                let block = {
                    let store = self.store.as_ref().expect("disk entry implies store");
                    let b = store.fault(id)?;
                    store.remove(id);
                    Arc::new(b)
                };
                self.clock += 1;
                self.entries.insert(
                    id,
                    WorkerEntry::Mem {
                        block: Arc::clone(&block),
                        bytes,
                        last_use: self.clock,
                    },
                );
                self.resident += bytes;
                self.enforce_budget()?;
                Ok(block)
            }
        }
    }

    fn remove(&mut self, id: u32) {
        match self.entries.remove(&id) {
            Some(WorkerEntry::Mem { bytes, .. }) => self.resident -= bytes,
            Some(WorkerEntry::Disk { .. }) => {
                if let Some(store) = &self.store {
                    store.remove(id);
                }
            }
            None => {}
        }
    }

    fn stat(&self) -> WorkerStat {
        WorkerStat {
            blocks: self.entries.len() as u64,
            resident_bytes: self.resident,
            blocks_spilled: self.spilled,
            pulled_bytes: self.pulled_bytes,
        }
    }
}

/// How a peer pull failed: the peer being unreachable is a different fact
/// (that worker is dead) than the peer answering with an error (this
/// conversation is broken).
enum PullError {
    PeerDown(String),
    Failed(String),
}

/// Fetch one block from a peer worker (the `Pull` data path).
fn pull_from_peer(addr: &str, id: u32) -> Result<(Block, u64), PullError> {
    let mut s = TcpStream::connect(addr)
        .map_err(|e| PullError::PeerDown(format!("connecting to peer {addr}: {e}")))?;
    s.set_nodelay(true).ok();
    wire::write_request(&mut s, &Request::Get { id })
        .map_err(|e| PullError::PeerDown(format!("peer {addr}: {e:#}")))?;
    let (resp, bytes) = wire::read_response(&mut s)
        .map_err(|e| PullError::PeerDown(format!("peer {addr}: {e:#}")))?;
    match resp {
        Response::Block(b) => Ok((b, bytes)),
        Response::Err(m) => Err(PullError::Failed(format!("peer {addr}: {m}"))),
        other => Err(PullError::Failed(format!(
            "peer {addr}: unexpected response {other:?} to Get"
        ))),
    }
}

/// Crash this worker: the injected-`Die` / [`Request::Crash`] path. Real
/// daemons exit the process SIGKILL-style (no response goes out, the spill
/// directory is dropped first since `process::exit` skips destructors);
/// in-process workers raise the shared dead flag and clear their blocks,
/// which silences every connection equivalently.
fn crash_worker(shared: &WorkerShared) {
    if shared.crash_exits {
        shared.blocks.lock().unwrap().store.take();
        std::process::exit(137);
    }
    shared.dead.store(true, Ordering::SeqCst);
    let mut blocks = shared.blocks.lock().unwrap();
    blocks.entries.clear();
    blocks.resident = 0;
    blocks.store.take();
}

fn worker_conn_loop(shared: Arc<WorkerShared>, mut stream: TcpStream) {
    loop {
        let req = match wire::read_request(&mut stream) {
            Ok(r) => r,
            Err(_) => return, // connection closed
        };
        // A crashed in-process worker answers nothing, ever.
        if shared.dead.load(Ordering::SeqCst) {
            return;
        }
        // The single fault-injection point: after decode, before handling,
        // so the served-request counter is exact for every request kind.
        match shared.faults.as_ref().and_then(|f| f.on_request()) {
            Some(FaultKind::Die) => {
                crash_worker(&shared);
                return;
            }
            Some(FaultKind::DropConn) => {
                // Cut the conversation mid-frame: a length header with no
                // payload, then close. The worker stays alive.
                let _ = stream.write_all(&1024u32.to_le_bytes());
                return;
            }
            None => {}
        }
        let mut exit = false;
        let resp = match req {
            Request::Ping => Response::Ok,
            Request::Put { id, block } => {
                match shared.blocks.lock().unwrap().insert(id, block) {
                    Ok(()) => Response::Ok,
                    Err(e) => Response::Err(format!("storing block {id}: {e:#}")),
                }
            }
            Request::Get { id } => {
                // Bind first so the state lock drops before the payload
                // clone — copying a multi-MB block must not stall every
                // other connection thread.
                let got = shared.blocks.lock().unwrap().get(id);
                match got {
                    Ok(b) => Response::Block((*b).clone()),
                    Err(e) => Response::Err(format!("{e:#}")),
                }
            }
            Request::Free { ids } => {
                let mut st = shared.blocks.lock().unwrap();
                for id in ids {
                    st.remove(id);
                }
                Response::Ok
            }
            Request::Pull { id, from } => match pull_from_peer(&from, id) {
                Ok((block, bytes)) => {
                    let mut st = shared.blocks.lock().unwrap();
                    st.pulled_bytes += bytes;
                    match st.insert(id, block) {
                        Ok(()) => Response::Pulled { bytes },
                        Err(e) => Response::Err(format!("storing pulled block {id}: {e:#}")),
                    }
                }
                // The peer is gone, *we* are fine: tell the coordinator
                // which of us to bury.
                Err(PullError::PeerDown(m)) => {
                    Response::PullPeerDown(format!("pull of block {id} failed: {m}"))
                }
                Err(PullError::Failed(m)) => {
                    Response::Err(format!("pull of block {id} from {from} failed: {m}"))
                }
            },
            Request::Stat => Response::Stat(shared.blocks.lock().unwrap().stat()),
            Request::Shutdown => {
                exit = true;
                Response::Ok
            }
            Request::Crash => {
                crash_worker(&shared);
                return;
            }
        };
        if wire::write_response(&mut stream, &resp).is_err() {
            return;
        }
        if exit {
            // Drop the spill store (removing its directory) explicitly:
            // `process::exit` skips destructors.
            shared.blocks.lock().unwrap().store.take();
            std::process::exit(0);
        }
    }
}

/// The worker daemon loop behind `dsarray worker --listen <addr>`: accept
/// coordinator and peer connections forever, one thread per connection.
/// A `Shutdown` request cleans up the spill directory and exits the
/// process, so call this only from a dedicated worker process (or from an
/// in-process test thread that never sends `Shutdown`). In-process workers
/// keep `crash_exits` false so [`Request::Crash`] and injected faults
/// silence the worker without taking the host process down.
pub fn serve_worker(listener: TcpListener, opts: WorkerOptions) -> Result<()> {
    let store = match opts.memory_budget_bytes {
        Some(_) => Some(BlockStore::in_temp()?),
        None => None,
    };
    let faults = match opts.fault_spec.as_deref() {
        Some(spec) if !spec.is_empty() => {
            Some(FaultState::from_spec(spec).context("parsing --fault-plan")?)
        }
        _ => None,
    };
    let shared = Arc::new(WorkerShared {
        blocks: Mutex::new(WorkerBlocks {
            entries: HashMap::new(),
            resident: 0,
            clock: 0,
            budget: opts.memory_budget_bytes,
            store,
            spilled: 0,
            pulled_bytes: 0,
        }),
        faults,
        dead: AtomicBool::new(false),
        crash_exits: opts.crash_exits,
    });
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        if shared.dead.load(Ordering::SeqCst) {
            // Crashed in-process worker: refuse everything, like a closed
            // port. Dropping the stream resets the coordinator's connect.
            continue;
        }
        stream.set_nodelay(true).ok();
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || worker_conn_loop(shared, stream));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{BlockMeta, DenseMatrix};
    use crate::tasking::task::CostHint;
    use crate::tasking::Runtime;

    /// Start an in-process worker (same wire protocol, same daemon loop,
    /// just not a separate OS process) and return its address.
    fn inproc_worker(budget: Option<u64>) -> String {
        inproc_worker_with(WorkerOptions {
            memory_budget_bytes: budget,
            ..Default::default()
        })
    }

    fn inproc_worker_with(opts: WorkerOptions) -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = serve_worker(l, opts);
        });
        addr
    }

    /// Crash an in-process worker over the wire; the EOF on the (absent)
    /// response confirms the dead flag is up before we return.
    fn crash_worker_at(addr: &str) {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_request(&mut s, &Request::Crash).unwrap();
        let _ = wire::read_response(&mut s);
    }

    fn cluster_rt(addrs: Vec<String>) -> Runtime {
        Runtime::cluster(ClusterOptions::connect(addrs).with_threads(2)).unwrap()
    }

    fn stat_of(addr: &str) -> WorkerStat {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_request(&mut s, &Request::Stat).unwrap();
        match wire::read_response(&mut s).unwrap().0 {
            Response::Stat(st) => st,
            other => panic!("got {other:?}"),
        }
    }

    fn dense(v: f32) -> Block {
        Block::Dense(DenseMatrix::full(2, 2, v))
    }

    #[test]
    fn placement_prefers_most_input_bytes() {
        let all2 = full_mask(2);
        // Worker 1 holds 3x the bytes: it wins.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 300)], 2, all2),
            Some(1)
        );
        // Ties break toward the lowest index.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 100)], 2, all2),
            Some(0)
        );
        // A replicated block counts for every holder.
        assert_eq!(
            choose_placement(&[(0b11, 100), (0b10, 1)], 2, all2),
            Some(1),
            "worker 1 holds 101 bytes vs worker 0's 100"
        );
        // No located inputs: the caller round-robins.
        assert_eq!(choose_placement(&[], 4, full_mask(4)), None);
        assert_eq!(choose_placement(&[(0, 100)], 4, full_mask(4)), None);
        // A dead worker never wins, no matter how much it used to hold.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 300)], 2, 0b01),
            Some(0)
        );
        // All holders dead: fall back to round-robin over survivors.
        assert_eq!(choose_placement(&[(0b10, 300)], 2, 0b01), None);
    }

    #[test]
    fn put_wait_round_trip_and_remote_free() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        let a = rt.put_block(dense(1.5));
        let b = rt.put_block(dense(2.5));
        // Round-robin distribution: one block per worker.
        assert_eq!(stat_of(&addrs[0]).blocks, 1);
        assert_eq!(stat_of(&addrs[1]).blocks, 1);
        assert_eq!(rt.wait(a).unwrap().as_dense().unwrap().get(0, 0), 1.5);
        assert_eq!(rt.wait(b).unwrap().as_dense().unwrap().get(0, 0), 2.5);
        assert!(rt.metrics().bytes_on_wire > 0);
        // Refcount death reaches across the wire: the worker's copy is
        // freed and the block is gone for later waits.
        rt.retain(&[a]);
        rt.release(&[a]);
        assert!(rt.wait(a).is_err());
        assert_eq!(stat_of(&addrs[0]).blocks + stat_of(&addrs[1]).blocks, 1);
        assert_eq!(rt.metrics().blocks_evicted, 1);
    }

    #[test]
    fn chain_executes_remotely_with_full_locality_on_one_worker() {
        let addrs = vec![inproc_worker(None)];
        let rt = cluster_rt(addrs);
        let mut cur = rt.put_block(dense(0.0));
        for _ in 0..8 {
            cur = rt.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                Arc::new(|ins: &[Arc<Block>]| {
                    let m = ins[0].as_dense()?;
                    Ok(vec![Block::Dense(m.map(|x| x + 1.0))])
                }),
            )[0];
        }
        assert_eq!(rt.wait(cur).unwrap().as_dense().unwrap().get(0, 0), 8.0);
        let m = rt.metrics();
        assert_eq!(m.total_tasks(), 8);
        // Single worker: every input is already at its placement.
        assert_eq!(m.locality_hits, 8);
        assert_eq!(m.remote_transfers, 0);
        assert!(m.bytes_on_wire > 0);
    }

    #[test]
    fn cross_worker_input_is_pulled_and_counted() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        // Round-robin: `a` lands on worker 0, `b` on worker 1.
        let a = rt.put_block(dense(1.0));
        let b = rt.put_block(dense(10.0));
        let sum = rt.submit(
            "sum2",
            &[a, b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| {
                let mut acc = ins[0].as_dense()?.clone();
                acc.axpy(1.0, ins[1].as_dense()?)?;
                Ok(vec![Block::Dense(acc)])
            }),
        );
        assert_eq!(rt.wait(sum[0]).unwrap().as_dense().unwrap().get(0, 0), 11.0);
        let m = rt.metrics();
        // Equal input bytes: placement ties to worker 0, so `a` is a hit
        // and `b` is pulled worker-to-worker.
        assert_eq!(m.locality_hits, 1);
        assert_eq!(m.remote_transfers, 1);
        // The pull left a replica of `b` on worker 0 and the output landed
        // there too: worker 0 now holds a, b, sum.
        assert_eq!(stat_of(&addrs[0]).blocks, 3);
        assert_eq!(stat_of(&addrs[1]).blocks, 1);
        assert!(stat_of(&addrs[0]).pulled_bytes > 0);
    }

    #[test]
    fn relay_mode_moves_bytes_without_replication() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions::connect(addrs.clone())
                .with_threads(1)
                .with_transfer(TransferMode::Relay),
        )
        .unwrap();
        let a = rt.put_block(dense(2.0));
        let b = rt.put_block(dense(3.0));
        let out = rt.submit(
            "mul2",
            &[a, b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| {
                let x = ins[0].as_dense()?.get(0, 0) * ins[1].as_dense()?.get(0, 0);
                Ok(vec![Block::Dense(DenseMatrix::full(2, 2, x))])
            }),
        );
        assert_eq!(rt.wait(out[0]).unwrap().as_dense().unwrap().get(0, 0), 6.0);
        let m = rt.metrics();
        assert_eq!(m.remote_transfers, 1);
        // No worker-to-worker replication in relay mode: worker 1 still
        // holds only `b`, and nothing was pulled.
        assert_eq!(stat_of(&addrs[1]).blocks, 1);
        assert_eq!(stat_of(&addrs[0]).pulled_bytes, 0);
        assert_eq!(stat_of(&addrs[1]).pulled_bytes, 0);
    }

    #[test]
    fn worker_budget_spills_and_faults_transparently() {
        // One worker, budget of one 16 B block; four blocks stored.
        let addr = inproc_worker(Some(16));
        let rt = cluster_rt(vec![addr.clone()]);
        let ids: Vec<_> = (0..4).map(|i| rt.put_block(dense(i as f32))).collect();
        let st = stat_of(&addr);
        assert_eq!(st.blocks, 4);
        assert!(st.blocks_spilled >= 3, "spilled {}", st.blocks_spilled);
        assert!(st.resident_bytes <= 16);
        // Every value still synchronizes — spilled ones fault on the worker.
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(rt.wait(id).unwrap().as_dense().unwrap().get(0, 0), i as f32);
        }
    }

    #[test]
    fn closure_error_poisons_with_task_name() {
        let rt = cluster_rt(vec![inproc_worker(None)]);
        let src = rt.put_block(dense(0.0));
        let bad = rt.submit(
            "explode",
            &[src],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|_: &[Arc<Block>]| anyhow::bail!("boom")),
        );
        let err = rt.wait(bad[0]).unwrap_err().to_string();
        assert!(err.contains("task `explode`"), "err: {err}");
        assert!(rt.barrier().is_err());
    }

    #[test]
    fn missing_worker_block_poisons_not_hangs() {
        // Free a block behind the coordinator's back, then read it through
        // a task: the failure must name the worker and poison the runtime.
        let addr = inproc_worker(None);
        let rt = cluster_rt(vec![addr.clone()]);
        let src = rt.put_block(dense(4.0));
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s, &Request::Free { ids: vec![src.id] }).unwrap();
        wire::read_response(&mut s).unwrap();
        let out = rt.submit(
            "read_gone",
            &[src],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| Ok(vec![(*ins[0]).clone()])),
        );
        let err = rt.wait(out[0]).unwrap_err().to_string();
        assert!(err.contains("task `read_gone`"), "err: {err}");
        assert!(err.contains(&addr), "err should name worker {addr}: {err}");
    }

    fn inc_body() -> Arc<dyn Fn(&[Arc<Block>]) -> Result<Vec<Block>> + Send + Sync> {
        Arc::new(|ins: &[Arc<Block>]| {
            let m = ins[0].as_dense()?;
            Ok(vec![Block::Dense(m.map(|x| x + 1.0))])
        })
    }

    #[test]
    fn worker_death_replays_lineage_bit_identically() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        // Root on worker 0 (round-robin), chain placed there by locality.
        let a = rt.put_block(dense(1.0));
        let mut cur = a;
        for _ in 0..3 {
            cur = rt.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                inc_body(),
            )[0];
        }
        rt.barrier().unwrap();
        // Kill the worker holding the whole chain, then synchronize: the
        // wait must route through recovery and return the exact value.
        crash_worker_at(&addrs[0]);
        let v = rt.wait(cur).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 4.0);
        let m = rt.metrics();
        assert_eq!(m.workers_lost, 1);
        assert!(m.tasks_replayed >= 3, "replayed {}", m.tasks_replayed);
        assert!(m.blocks_recovered >= 1, "recovered {}", m.blocks_recovered);
        assert!(m.recovery_ms >= 1);
        // The runtime is NOT poisoned: new work still runs on survivors.
        let more = rt.submit(
            "inc",
            &[cur],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            inc_body(),
        );
        assert_eq!(rt.wait(more[0]).unwrap().as_dense().unwrap().get(0, 0), 5.0);
    }

    #[test]
    fn replicated_blocks_survive_death_without_replay() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions::connect(addrs.clone())
                .with_threads(2)
                .with_replication(2),
        )
        .unwrap();
        let a = rt.put_block(dense(7.0));
        let out = rt.submit(
            "inc",
            &[a],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            inc_body(),
        )[0];
        rt.barrier().unwrap();
        crash_worker_at(&addrs[0]);
        // Every block has a copy on the survivor: recovery is a location
        // table fixup, no task re-runs.
        assert_eq!(rt.wait(out).unwrap().as_dense().unwrap().get(0, 0), 8.0);
        let m = rt.metrics();
        assert_eq!(m.workers_lost, 1);
        assert_eq!(m.tasks_replayed, 0);
        assert_eq!(m.blocks_recovered, 0);
    }

    #[test]
    fn disabled_recovery_poisons_with_worker_address() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions::connect(addrs.clone())
                .with_threads(2)
                .with_recovery(false),
        )
        .unwrap();
        let a = rt.put_block(dense(3.0));
        rt.barrier().unwrap();
        crash_worker_at(&addrs[0]);
        let err = rt.wait(a).unwrap_err().to_string();
        assert!(err.contains(&addrs[0]), "err should name {}: {err}", addrs[0]);
        assert!(err.contains("recovery is disabled"), "err: {err}");
        assert!(rt.barrier().is_err(), "runtime must be poisoned");
    }

    #[test]
    fn injected_die_fault_silences_worker_at_scheduled_request() {
        let addr = inproc_worker_with(WorkerOptions {
            fault_spec: Some("die@2".into()),
            ..Default::default()
        });
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(matches!(wire::read_response(&mut s).unwrap().0, Response::Ok));
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(
            wire::read_response(&mut s).is_err(),
            "request 2 must hit die@2 and get silence"
        );
        // The worker stays dead for later conversations too.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        let _ = wire::write_request(&mut s2, &Request::Ping);
        assert!(wire::read_response(&mut s2).is_err());
    }

    #[test]
    fn injected_conn_drop_cuts_one_conversation_but_worker_survives() {
        let addr = inproc_worker_with(WorkerOptions {
            fault_spec: Some("drop@1".into()),
            ..Default::default()
        });
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(
            wire::read_response(&mut s).is_err(),
            "request 1 must get a truncated frame"
        );
        // A fresh conversation with the same worker succeeds.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s2, &Request::Ping).unwrap();
        assert!(matches!(
            wire::read_response(&mut s2).unwrap().0,
            Response::Ok
        ));
    }
}
