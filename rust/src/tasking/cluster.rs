//! Multi-process cluster executor: a coordinator that distributes block
//! residency across N worker **processes** over TCP, with locality-aware
//! task placement — the third [`Executor`] backend the PR-1 trait refactor
//! was built for.
//!
//! ## Model
//!
//! Task bodies are Rust closures and cannot cross a process boundary, so
//! the split of work follows the data, not the code:
//!
//! * **Workers** (`dsarray worker --listen <addr>`) are block daemons: they
//!   hold block payloads, serve `Put`/`Get`/`Free`, pull blocks from peer
//!   workers on command, and spill to their own [`BlockStore`] directory
//!   when a per-worker memory budget is exceeded.
//! * **The coordinator** (this executor) keeps the dependency [`Graph`],
//!   a **block-location table** (which workers hold which block), and a
//!   pool of executor threads that run task closures against blocks fetched
//!   over the wire, then push outputs back out — so the coordinator's own
//!   resident set stays flat no matter how large the arrays are.
//!
//! ## Locality-aware scheduling
//!
//! Each ready task is *placed* on the worker already holding the most input
//! bytes; its outputs land there, so chains over the same blocks keep
//! reading and writing one worker. Inputs held elsewhere are **pulled
//! worker-to-worker** to the placement worker ([`TransferMode::Pull`],
//! the default) or relayed through the coordinator from wherever they live
//! ([`TransferMode::Relay`]). Blocks are single-assignment (SSA), so a
//! pulled replica can never go stale — replication needs no coherence
//! protocol at all. [`Metrics`] counts `locality_hits` (inputs already at
//! the placement worker), `remote_transfers` (inputs that crossed workers)
//! and `bytes_on_wire` (every payload byte moved).
//!
//! ## Reclamation and fault recovery
//!
//! Refcount reclamation extends across the wire: when the graph proves a
//! block dead it queues the id (the same `dead_files` channel the
//! out-of-core store uses) and the coordinator sends `Free` to every worker
//! holding a copy.
//!
//! A worker whose TCP conversation breaks is presumed **dead** and, by
//! default, *recovered from* rather than fatal: the single-assignment task
//! graph doubles as a lineage log, so the coordinator marks the dead
//! worker's resident blocks lost, walks producers transitively until every
//! replay input is held by a survivor or re-loadable from the coordinator's
//! root journal, flips that sub-graph back to runnable, and re-queues the
//! in-flight task — results stay bit-identical because the replayed
//! closures are deterministic over bit-identical inputs. `wait` fetches
//! retry against recovered locations instead of poisoning, and the replay's
//! `pending_reads` re-increments defer refcount frees for blocks a replay
//! may still need. Opt-in k-way replication
//! ([`ClusterOptions::with_replication`]) turns recovery of replicated
//! blocks into a location-table lookup. With recovery disabled
//! ([`ClusterOptions::with_recovery`]`(false)` / `--no-recovery`), a death
//! poisons the runtime with the worker address and the task name ("task
//! \`x\` failed on cluster backend: worker 127.0.0.1:…") — never a hang —
//! which is also the fate of genuinely unrecoverable losses (every worker
//! dead). An application-level worker *error* (a live worker answering
//! `Err`) is never treated as a death and always poisons.
//!
//! ## Elasticity
//!
//! The fleet is not frozen at startup. The coordinator binds a **control
//! listener** ([`ClusterExecutor::coordinator_addr`]) accepting
//! `Join`/`Drain` frames from workers: `dsarray worker --join <addr>`
//! enrolls a fresh worker mid-run (it starts receiving tasks immediately —
//! an empty worker has zero outstanding-bytes load, so the load-aware
//! placement below naturally rebalances onto it), and
//! [`ClusterExecutor::drain`] decommissions one gracefully — mark it
//! read-only, migrate its sole-copy blocks to survivors over the existing
//! Pull path, then drop it from the fleet with **zero tasks replayed**.
//! An optional **heartbeat** thread ([`ClusterOptions::with_heartbeat_ms`])
//! pings every worker on a dedicated connection so dead *and* stalled
//! workers are detected proactively instead of when an in-flight call
//! finally errors; a worker missing [`HEARTBEAT_MISS_THRESHOLD`]
//! consecutive beats (reconnect attempts back off exponentially in between)
//! is declared dead, its blocked calls are severed, and lineage recovery
//! absorbs it. An optional **straggler monitor**
//! ([`ClusterOptions::with_straggler_factor`]) tracks per-task-name running
//! -time EWMAs and speculatively re-arms any task exceeding
//! `straggler_factor ×` its estimate on another worker;
//! first-completion-wins under the central lock, the loser's outputs are
//! freed, and single-assignment over deterministic closures keeps results
//! bit-identical no matter which copy wins.
//!
//! See `docs/CLUSTER.md` (rustdoc: `crate::cluster_guide`) for the frame
//! format and placement policy, and `docs/FAULT_TOLERANCE.md` (rustdoc:
//! `crate::fault_tolerance_guide`) for the failure model, the lineage walk
//! and the deterministic fault-injection harness behind its tests.

use std::collections::{BTreeSet, HashMap, HashSet, VecDeque};
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::storage::{Block, BlockStore};

use super::faults::{FaultKind, FaultState};
use super::graph::{Graph, TaskState};
use super::metrics::Metrics;
use super::task::{DataId, TaskBody, TaskId, TaskInput, TaskSubmit};
use super::wire::{self, Request, Response, WorkerStat};
use super::Executor;

/// How a task's missing inputs reach its placement worker.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransferMode {
    /// The placement worker pulls missing blocks from the peers holding
    /// them (worker-to-worker), leaving a replica behind for later tasks —
    /// block residency migrates toward use.
    #[default]
    Pull,
    /// The coordinator fetches each input from whichever worker holds it;
    /// no worker-to-worker traffic, no replication.
    Relay,
}

/// Configuration of a [`ClusterExecutor`].
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// Addresses of already-running workers to connect to.
    pub addrs: Vec<String>,
    /// Worker processes to spawn on loopback (in addition to `addrs`).
    pub spawn: usize,
    /// Binary used for spawning (`dsarray`); defaults to the current
    /// executable — pass explicitly from test harnesses, whose
    /// `current_exe` is the test binary.
    pub program: Option<PathBuf>,
    /// Coordinator executor threads running task closures.
    pub threads: usize,
    /// Missing-input transfer policy.
    pub transfer: TransferMode,
    /// Memory budget handed to each *spawned* worker
    /// (`--memory-budget-bytes`); over it, workers spill to disk.
    pub worker_budget_bytes: Option<u64>,
    /// Survive worker death by lineage replay (the default). When `false`
    /// (`--no-recovery`), a broken worker conversation poisons the runtime
    /// with the worker address and task name, the pre-recovery contract.
    pub recovery: bool,
    /// Workers holding a copy of each block (`--replicate-blocks k`);
    /// clamped to the live worker count. At `k >= 2` a single death usually
    /// costs a location-table lookup instead of a replay. Default 1.
    pub replicate: usize,
    /// Heartbeat interval in milliseconds (`--heartbeat-ms`); 0 (the
    /// default) disables proactive liveness checks and worker loss is only
    /// noticed when an in-flight call errors. When on, each worker is
    /// pinged on a dedicated connection every interval; after
    /// [`HEARTBEAT_MISS_THRESHOLD`] consecutive misses (with exponential
    /// backoff between reconnect attempts) the worker is declared dead and
    /// lineage recovery absorbs it.
    pub heartbeat_ms: u64,
    /// Straggler speculation threshold (`--straggler-factor`); 0.0 (the
    /// default) disables speculation. When positive, a running task whose
    /// elapsed time exceeds `straggler_factor ×` the EWMA of its task
    /// name's past running times is speculatively re-armed on another
    /// worker; the first completed copy wins under the central lock and the
    /// loser's outputs are freed.
    pub straggler_factor: f64,
}

impl Default for ClusterOptions {
    /// Connect-to-nothing baseline: no addresses, no spawns, two executor
    /// threads, pull transfers, recovery on, no replication. Fill in
    /// `addrs` or `spawn` with a struct literal, or go through
    /// [`crate::tasking::Runtime::builder`].
    fn default() -> Self {
        Self {
            addrs: Vec::new(),
            spawn: 0,
            program: None,
            threads: 2,
            transfer: TransferMode::Pull,
            worker_budget_bytes: None,
            recovery: true,
            replicate: 1,
            heartbeat_ms: 0,
            straggler_factor: 0.0,
        }
    }
}

impl ClusterOptions {
    /// Connect to existing workers at `addrs`.
    #[deprecated(
        since = "0.11.0",
        note = "use `Runtime::builder().backend(Backend::Cluster).cluster_addrs(addrs)` \
                or a struct literal with `..Default::default()`"
    )]
    pub fn connect(addrs: Vec<String>) -> Self {
        Self {
            addrs,
            ..Self::default()
        }
    }

    /// Spawn `n` worker processes on loopback and connect to them; they are
    /// shut down when the executor drops.
    #[deprecated(
        since = "0.11.0",
        note = "use `Runtime::builder().backend(Backend::Cluster).cluster_workers(n)` \
                or a struct literal with `..Default::default()`"
    )]
    pub fn spawn(n: usize) -> Self {
        Self {
            spawn: n,
            ..Self::default()
        }
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }

    pub fn with_transfer(mut self, m: TransferMode) -> Self {
        self.transfer = m;
        self
    }

    pub fn with_worker_budget(mut self, bytes: u64) -> Self {
        self.worker_budget_bytes = Some(bytes);
        self
    }

    pub fn with_program(mut self, p: PathBuf) -> Self {
        self.program = Some(p);
        self
    }

    /// Enable/disable lineage-replay recovery of dead workers (on by
    /// default; `false` restores the poison-on-death contract).
    pub fn with_recovery(mut self, on: bool) -> Self {
        self.recovery = on;
        self
    }

    /// Store each block on `k` distinct workers so losing one is a
    /// location-table lookup, not a replay. Clamped to the worker count.
    pub fn with_replication(mut self, k: usize) -> Self {
        self.replicate = k.max(1);
        self
    }

    /// Ping each worker every `ms` milliseconds on a dedicated connection
    /// and declare it dead after [`HEARTBEAT_MISS_THRESHOLD`] consecutive
    /// misses. 0 disables the heartbeat (the default).
    pub fn with_heartbeat_ms(mut self, ms: u64) -> Self {
        self.heartbeat_ms = ms;
        self
    }

    /// Speculatively re-execute a task elsewhere once it runs longer than
    /// `factor ×` the EWMA estimate for its task name. 0.0 disables
    /// speculation (the default); useful values start around 2–4.
    pub fn with_straggler_factor(mut self, factor: f64) -> Self {
        self.straggler_factor = if factor > 0.0 { factor } else { 0.0 };
        self
    }
}

/// Consecutive missed heartbeats after which a worker is declared dead.
pub const HEARTBEAT_MISS_THRESHOLD: u32 = 3;

/// One coordinator→worker connection; the stream mutex keeps each
/// request/response pair atomic, so concurrent executor threads never
/// interleave frames.
struct WorkerConn {
    addr: String,
    stream: Mutex<TcpStream>,
    /// Unsynchronized clone of the same socket so liveness code can sever
    /// the connection while a call is blocked inside the stream mutex —
    /// the blocked call then errors promptly instead of hanging on a
    /// stalled worker.
    raw: Option<TcpStream>,
}

impl WorkerConn {
    fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to worker {addr}"))?;
        stream.set_nodelay(true).ok();
        let raw = stream.try_clone().ok();
        Ok(Self {
            addr: addr.to_string(),
            stream: Mutex::new(stream),
            raw,
        })
    }

    /// One request/response round trip; returns the response and the total
    /// wire bytes (both directions, frame headers included).
    fn call(&self, req: &Request) -> Result<(Response, u64)> {
        let mut s = self.stream.lock().unwrap();
        let sent = wire::write_request(&mut *s, req)
            .with_context(|| format!("sending to worker {}", self.addr))?;
        let (resp, recvd) = wire::read_response(&mut *s)
            .with_context(|| format!("reading from worker {}", self.addr))?;
        Ok((resp, sent + recvd))
    }

    /// Shut the socket down in both directions; any call blocked on it
    /// errors out. Used when the heartbeat declares the worker dead.
    fn sever(&self) {
        if let Some(raw) = &self.raw {
            let _ = raw.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// One running task copy the straggler monitor watches.
struct Inflight {
    name: &'static str,
    placement: usize,
    started: Instant,
    /// A speculative twin has already been armed; never arm a second.
    speculated: bool,
}

/// Central coordinator state (graph + scheduler), guarded by one mutex.
struct ClState {
    graph: Graph,
    /// Dependency-free tasks awaiting an executor thread.
    ready: VecDeque<TaskId>,
    running: usize,
    shutdown: bool,
    /// First failure; poisons the runtime (fail-fast), same as local mode.
    error: Option<String>,
    metrics: Metrics,
    /// Block-location table: bit `w` of `copies[id]` is set when worker `w`
    /// holds a replica of `id` (single-assignment makes replicas coherent).
    copies: Vec<u64>,
    /// Worker-to-worker pulls in flight, keyed `(block, destination)`:
    /// concurrent tasks read from a stable holder instead of re-pulling.
    pulling: HashSet<(DataId, usize)>,
    /// Round-robin pointer for blocks and tasks with no located inputs.
    rr: usize,
    /// Bit `w` set while worker `w` is reachable. Cleared (forever) on the
    /// first transport failure talking to it — or on a graceful drain;
    /// placement, pulls, frees and shutdown all skip dead workers.
    alive: u64,
    /// Bit `w` set while worker `w` is draining: still alive and readable,
    /// but read-only — no new placements, replicas or puts land on it.
    draining: u64,
    /// Outstanding scheduled bytes per worker (declared output bytes plus
    /// inputs being pulled toward it); the load signal placement and
    /// replica spreading use. Grows when workers join.
    load: Vec<u64>,
    /// Per-task-name EWMA of running time in seconds — the straggler
    /// monitor's estimate of "how long should this take".
    ewma: HashMap<&'static str, f64>,
    /// Running task copies, keyed by task id (the original copy; a
    /// speculative twin reuses the entry's `speculated` flag).
    inflight: HashMap<TaskId, Inflight>,
    /// Workers a speculative re-arm must avoid (the straggler's slot),
    /// consumed by the next claim of that task id.
    speculate_avoid: HashMap<TaskId, u64>,
}

impl ClState {
    /// Workers eligible for new writes: alive and not draining.
    fn eligible(&self) -> u64 {
        self.alive & !self.draining
    }

    fn load_of(&self, w: usize) -> u64 {
        self.load.get(w).copied().unwrap_or(0)
    }

    fn add_load(&mut self, w: usize, bytes: u64) {
        if self.load.len() <= w {
            self.load.resize(w + 1, 0);
        }
        self.load[w] += bytes;
    }

    fn sub_load(&mut self, w: usize, bytes: u64) {
        if let Some(l) = self.load.get_mut(w) {
            *l = l.saturating_sub(bytes);
        }
    }
}

/// Why one worker interaction failed — the classification recovery hinges
/// on. A broken TCP conversation means the *worker* is gone (its blocks
/// died with it, lineage replay applies); an application-level error from a
/// live worker is a real failure and must poison.
enum ClusterFailure {
    /// The transport to worker `w` broke (or a peer reported it
    /// unreachable): presume the worker dead.
    WorkerDown { w: usize, msg: String },
    /// A live worker answered with an error, or the task itself failed.
    Protocol { msg: String },
}

impl ClusterFailure {
    fn msg(&self) -> &str {
        match self {
            ClusterFailure::WorkerDown { msg, .. } | ClusterFailure::Protocol { msg } => msg,
        }
    }
}

struct ClusterInner {
    state: Mutex<ClState>,
    cv: Condvar,
    /// The membership table: one connection per enrolled worker, append-only
    /// so bit positions in `copies`/`alive` stay stable for the lifetime of
    /// the cluster (drained and dead workers keep their slot, masked out of
    /// `alive`). Guarded by its own lock so workers can join mid-run; never
    /// acquire `state` while holding this lock — membership writers take
    /// them strictly in the order conns-then-release-then-state.
    conns: RwLock<Vec<Arc<WorkerConn>>>,
    transfer: TransferMode,
    /// Heartbeat interval (0 = off) and straggler threshold (0.0 = off).
    heartbeat_ms: u64,
    straggler_factor: f64,
    /// Lineage-replay recovery on worker death (vs poison).
    recovery: bool,
    /// Distinct workers holding each block (>= 1).
    replicate: usize,
    /// Journal of root blocks (`put_block`, no producing task) kept on the
    /// coordinator's own disk so a root whose every worker replica died can
    /// be re-loaded — the "re-loadable from the store tier" leaf of the
    /// lineage walk. `Some` iff recovery is enabled. Files are kept until
    /// teardown even if the block's refcount dies: a later replay of a
    /// completed consumer may still need them.
    root_store: Option<BlockStore>,
}

impl ClusterInner {
    /// Workers ever enrolled (live, draining, drained and dead alike).
    fn n_workers(&self) -> usize {
        self.conns.read().unwrap().len()
    }

    /// Clone worker `w`'s connection handle out of the membership table.
    fn conn(&self, w: usize) -> Arc<WorkerConn> {
        Arc::clone(&self.conns.read().unwrap()[w])
    }

    fn addr_of(&self, w: usize) -> String {
        self.conns.read().unwrap()[w].addr.clone()
    }

    /// Fetch one block's payload from worker `w`, classifying the failure.
    fn fetch_block(&self, w: usize, id: DataId) -> Result<(Block, u64), ClusterFailure> {
        let conn = self.conn(w);
        match conn.call(&Request::Get { id }) {
            Ok((Response::Block(b), bytes)) => Ok((b, bytes)),
            Ok((Response::Err(m), _)) => Err(ClusterFailure::Protocol {
                msg: format!("worker {}: {m}", conn.addr),
            }),
            Ok((other, _)) => Err(ClusterFailure::Protocol {
                msg: format!("worker {}: unexpected response {other:?} to Get", conn.addr),
            }),
            Err(e) => Err(ClusterFailure::WorkerDown {
                w,
                msg: format!("worker {}: {e:#}", conn.addr),
            }),
        }
    }

    /// Send remote frees. Best-effort: a dead worker's memory died with the
    /// process, and worker death already surfaces through the task path.
    fn send_frees(&self, frees: Vec<(usize, Vec<u32>)>) {
        for (w, ids) in frees {
            let _ = self.conn(w).call(&Request::Free { ids });
        }
    }

    /// Enroll the worker at `addr` into the running fleet: connect, ping,
    /// append to the membership table (slots are append-only so existing
    /// location bits stay valid) and mark it alive. Lock order matters —
    /// the membership write lock is released before the state lock is
    /// taken, because hot paths read membership while holding state.
    fn enroll(&self, addr: &str) -> Result<usize> {
        let already_alive = {
            let st = self.state.lock().unwrap();
            st.alive
        };
        {
            let conns = self.conns.read().unwrap();
            if let Some(w) = conns.iter().position(|c| c.addr == addr) {
                if already_alive & (1u64 << w) != 0 {
                    bail!("worker {addr} is already a live member (slot {w})");
                }
                // A drained/dead slot's address can never be re-armed in
                // place (its copies bits are gone); the worker must come
                // back on a fresh address.
                bail!("worker {addr} previously left the fleet; rejoin on a new address");
            }
        }
        let conn = WorkerConn::connect(addr)?;
        match conn.call(&Request::Ping)? {
            (Response::Ok, _) => {}
            (other, _) => bail!("worker {addr} answered ping with {other:?}"),
        }
        let w = {
            let mut conns = self.conns.write().unwrap();
            if conns.len() >= 64 {
                bail!("cluster is at the 64-worker location-table limit");
            }
            conns.push(Arc::new(conn));
            conns.len() - 1
        };
        {
            let mut st = self.state.lock().unwrap();
            st.alive |= 1u64 << w;
            if st.load.len() <= w {
                st.load.resize(w + 1, 0);
            }
            st.metrics.record_join();
        }
        self.cv.notify_all();
        Ok(w)
    }

    /// Decommission worker `w` gracefully, with **zero tasks replayed**:
    ///
    /// 1. mark it draining (read-only): no new placements, replicas or
    ///    puts land on it, but its blocks stay readable;
    /// 2. wait for in-flight work already placed on it to publish;
    /// 3. migrate every block whose *only* copy it holds to the
    ///    least-loaded eligible survivor over the existing Pull path;
    /// 4. drop it from the fleet (clear its location bits and alive bit)
    ///    and count `workers_drained`.
    ///
    /// The worker process is left running; it simply stops being a member.
    fn drain_worker(&self, w: usize) -> Result<()> {
        if w >= self.n_workers() {
            bail!("drain: no worker slot {w}");
        }
        let addr = self.addr_of(w);
        let bit = 1u64 << w;
        // Phase 1: read-only.
        {
            let mut st = self.state.lock().unwrap();
            if st.alive & bit == 0 {
                bail!("worker {addr} is not alive (already drained or dead)");
            }
            if st.draining & bit != 0 {
                bail!("worker {addr} is already draining");
            }
            if st.eligible() & !bit == 0 {
                bail!("cannot drain {addr}: it is the last eligible worker");
            }
            st.draining |= bit;
        }
        self.cv.notify_all();

        // Phase 2: wait out work already placed on it. New claims can no
        // longer choose it, so this drains monotonically.
        {
            let mut st = self.state.lock().unwrap();
            loop {
                if st.alive & bit == 0 {
                    st.draining &= !bit;
                    bail!("worker {addr} died mid-drain");
                }
                if let Some(e) = &st.error {
                    let e = e.clone();
                    st.draining &= !bit;
                    bail!("drain of {addr} aborted, runtime poisoned: {e}");
                }
                let busy = st.inflight.values().any(|i| i.placement == w)
                    || st.pulling.iter().any(|&(_, dest)| dest == w);
                if !busy {
                    break;
                }
                st = self.cv.wait(st).unwrap();
            }
        }

        // Phase 3: migrate sole-copy blocks. Placement excludes the
        // draining worker, so no *new* sole copies can appear behind this
        // enumeration; concurrent refcount frees can only shrink it.
        let sole: Vec<DataId> = {
            let st = self.state.lock().unwrap();
            st.copies
                .iter()
                .enumerate()
                .filter(|&(id, &mask)| {
                    mask == bit
                        && !st.graph.data[id].evicted
                        && st.graph.data[id].value.is_none()
                })
                .map(|(id, _)| id as DataId)
                .collect()
        };
        for id in sole {
            loop {
                // Re-check each round: the block may have been freed, and
                // the survivor set may have changed.
                let target = {
                    let st = self.state.lock().unwrap();
                    if st.copies.get(id as usize).copied().unwrap_or(0) != bit {
                        break; // freed (or already migrated) meanwhile
                    }
                    let mut best: Option<(u64, usize)> = None;
                    for t in 0..self.n_workers() {
                        if t == w || st.eligible() & (1u64 << t) == 0 {
                            continue;
                        }
                        let l = st.load_of(t);
                        if best.map_or(true, |(bl, _)| l < bl) {
                            best = Some((l, t));
                        }
                    }
                    match best {
                        Some((_, t)) => t,
                        None => {
                            drop(st);
                            let mut st = self.state.lock().unwrap();
                            st.draining &= !bit;
                            bail!("drain of {addr}: no eligible survivor for block {id}");
                        }
                    }
                };
                match self.conn(target).call(&Request::Pull {
                    id,
                    from: addr.clone(),
                }) {
                    Ok((Response::Pulled { bytes }, io)) => {
                        let mut st = self.state.lock().unwrap();
                        ensure_copies(&mut st.copies, id);
                        st.copies[id as usize] |= 1u64 << target;
                        st.metrics.record_wire(io + bytes);
                        st.metrics.record_locality(0, 1);
                        break;
                    }
                    Ok((Response::PullPeerDown(m), _)) => {
                        // The draining worker itself is unreachable: the
                        // drain becomes a death, lineage recovery applies.
                        let mut st = self.state.lock().unwrap();
                        st.draining &= !bit;
                        handle_worker_death(&mut st, w, self)?;
                        drop(st);
                        self.cv.notify_all();
                        bail!("worker {addr} died mid-drain: {m}");
                    }
                    Ok((other, _)) => {
                        let mut st = self.state.lock().unwrap();
                        st.draining &= !bit;
                        let m = match other {
                            Response::Err(m) => m,
                            o => format!("unexpected response {o:?} to Pull"),
                        };
                        bail!("drain of {addr}: migrating block {id}: {m}");
                    }
                    Err(_) => {
                        // The *target* died; absorb and retry elsewhere.
                        let mut st = self.state.lock().unwrap();
                        handle_worker_death(&mut st, target, self)?;
                        drop(st);
                        self.cv.notify_all();
                    }
                }
            }
        }

        // Phase 4: decommission. Every sole copy now has a survivor
        // replica, so clearing the drained worker's bits never zeroes a
        // live block's mask.
        {
            let mut st = self.state.lock().unwrap();
            for mask in st.copies.iter_mut() {
                *mask &= !bit;
            }
            st.pulling.retain(|&(_, dest)| dest != w);
            st.alive &= !bit;
            st.draining &= !bit;
            st.metrics.record_drain();
        }
        self.cv.notify_all();
        Ok(())
    }
}

fn ensure_copies(copies: &mut Vec<u64>, id: DataId) {
    let need = id as usize + 1;
    if copies.len() < need {
        copies.resize(need, 0);
    }
}

/// All-workers-alive bitmask for an `n`-worker cluster (`n <= 64`).
fn full_mask(n: usize) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Next *eligible* worker in round-robin order (eligible = alive and not
/// draining; callers may further restrict the mask). The all-dead case
/// poisons before any caller gets here, so at least one bit is set.
fn next_rr(st: &mut ClState, n: usize, eligible: u64) -> usize {
    for _ in 0..n {
        let w = st.rr % n;
        st.rr = st.rr.wrapping_add(1);
        if eligible & (1u64 << w) != 0 {
            return w;
        }
    }
    st.rr % n
}

/// The placement policy, kept pure for unit testing: the *eligible* worker
/// holding the most input bytes wins; ties break toward the least
/// outstanding-bytes `load` (so a freshly joined, empty worker picks up
/// replicated work immediately), then toward the lowest index. `None` when
/// no input is located on any eligible worker (the caller round-robins).
fn choose_placement(
    inputs: &[(u64, usize)],
    n_workers: usize,
    eligible: u64,
    load: &[u64],
) -> Option<usize> {
    let mut best: Option<(usize, usize, u64)> = None;
    for w in 0..n_workers {
        if eligible & (1u64 << w) == 0 {
            continue;
        }
        let held: usize = inputs
            .iter()
            .filter(|(mask, _)| mask & (1u64 << w) != 0)
            .map(|(_, bytes)| *bytes)
            .sum();
        let l = load.get(w).copied().unwrap_or(0);
        if held > 0 && best.map_or(true, |(_, b, bl)| held > b || (held == b && l < bl)) {
            best = Some((w, held, l));
        }
    }
    best.map(|(w, _, _)| w)
}

/// Replica targets for a block placed on `placement`: up to `k - 1` other
/// eligible workers, least-loaded first (lowest index on ties) — load-aware
/// spreading instead of the old lowest-index rule.
fn choose_replicas(placement: usize, k: usize, n_workers: usize, eligible: u64, load: &[u64]) -> Vec<usize> {
    if k <= 1 {
        return Vec::new();
    }
    let mut others: Vec<usize> = (0..n_workers)
        .filter(|&w| w != placement && eligible & (1u64 << w) != 0)
        .collect();
    others.sort_by_key(|&w| (load.get(w).copied().unwrap_or(0), w));
    others.truncate(k - 1);
    others
}

/// Absorb a transport-level failure talking to worker `w` — the heart of
/// lineage recovery, run under the central lock.
///
/// Marks the worker dead, drops it from the location table, and for every
/// block that just lost its last replica walks the lineage: a `Done`
/// producer is re-armed for replay (its unavailable inputs recursively
/// likewise), a still-pending/running producer will re-produce the block on
/// its own, and a producer-less root is covered by the coordinator's root
/// journal. Re-armed tasks flow through the ordinary ready queue /
/// `complete()` path; their `pending_reads` re-increments keep replay
/// inputs from being refcount-freed mid-recovery.
///
/// Returns `Ok` when the death was absorbed (idempotently `Ok` for a
/// worker already marked dead); `Err` when the runtime must poison —
/// recovery disabled, no survivors, or an unrecoverable root.
fn handle_worker_death(st: &mut ClState, w: usize, inner: &ClusterInner) -> Result<()> {
    let bit = 1u64 << w;
    if st.alive & bit == 0 {
        return Ok(()); // already absorbed via another connection's failure
    }
    if !inner.recovery {
        bail!(
            "worker {} died and recovery is disabled",
            inner.addr_of(w)
        );
    }
    let t0 = Instant::now();
    st.alive &= !bit;
    st.draining &= !bit; // a death trumps any drain in progress
    if st.alive == 0 {
        // Nothing to replay onto. Count the loss, then poison.
        st.metrics.record_recovery(0, 0, 1);
        bail!(
            "worker {} died and no workers survive",
            inner.addr_of(w)
        );
    }
    // Drop the dead worker from the location table; blocks whose only
    // replica it held are lost (a replicated block shrugs the death off —
    // survivors still serve it).
    let mut lost: Vec<DataId> = Vec::new();
    for (id, mask) in st.copies.iter_mut().enumerate() {
        if *mask & bit != 0 {
            *mask &= !bit;
            if *mask == 0 {
                lost.push(id as DataId);
            }
        }
    }
    // Migrations onto the dead worker will never commit; clear the markers
    // so survivors re-pull instead of deferring to a doomed transfer.
    st.pulling.retain(|&(_, dest)| dest != w);

    // Lineage walk: find the completed producers to replay, transitively,
    // until every replay input is held by a survivor, resident on the
    // coordinator, or journaled in the root store.
    let live_lost: Vec<DataId> = lost
        .iter()
        .copied()
        .filter(|&id| !st.graph.data[id as usize].evicted)
        .collect();
    let mut queue: Vec<DataId> = live_lost.clone();
    let mut visited: HashSet<DataId> = queue.iter().copied().collect();
    // BTreeSet: ascending TaskId is topological order (tasks only read
    // earlier ids), which the re-arm pass below depends on.
    let mut replay: BTreeSet<TaskId> = BTreeSet::new();
    while let Some(id) = queue.pop() {
        let d = &st.graph.data[id as usize];
        if d.value.is_some() || st.copies.get(id as usize).copied().unwrap_or(0) != 0 {
            continue; // still available somewhere
        }
        match d.producer {
            None => {
                if inner.root_store.is_none() {
                    bail!(
                        "block {id} lost with worker {} has no producing task to replay",
                        inner.addr_of(w)
                    );
                }
                // Root: re-loadable from the coordinator's journal.
            }
            Some(p) => {
                if st.graph.tasks[p as usize].state == TaskState::Done && replay.insert(p) {
                    let reads: Vec<DataId> =
                        st.graph.tasks[p as usize].spec.reads.to_vec();
                    for r in reads {
                        if visited.insert(r) {
                            queue.push(r);
                        }
                    }
                }
                // A producer that is still pending/running/ready will
                // (re-)produce this block through the normal path.
            }
        }
    }

    // Re-arm the replay sub-graph in topological order: recompute each
    // task's readiness against the post-death world and re-register the
    // dependency edges `complete()` will re-consume. The `pending_reads`
    // increments are the deferred frees — replay inputs stay alive until
    // the replayed task completes again.
    for &tid in &replay {
        let reads: Vec<DataId> = st.graph.tasks[tid as usize].spec.reads.to_vec();
        let mut deps = 0u32;
        for &r in &reads {
            st.graph.data[r as usize].pending_reads += 1;
            let d = &st.graph.data[r as usize];
            let available = d.value.is_some()
                || st.copies.get(r as usize).copied().unwrap_or(0) != 0
                || (d.producer.is_none() && inner.root_store.is_some());
            if available {
                continue;
            }
            if let Some(p) = d.producer {
                if st.graph.tasks[p as usize].state != TaskState::Done {
                    deps += 1;
                    st.graph.tasks[p as usize].dependents.push(tid);
                }
            }
        }
        let node = &mut st.graph.tasks[tid as usize];
        node.deps_remaining = deps;
        if deps == 0 {
            node.state = TaskState::Ready;
            st.ready.push_back(tid);
        } else {
            node.state = TaskState::Pending;
        }
    }
    let ms = ((t0.elapsed().as_micros() as u64) + 999) / 1000;
    st.metrics
        .record_recovery(live_lost.len() as u64, replay.len() as u64, ms.max(1));
    Ok(())
}

/// Collect remote frees for every block the graph just declared dead,
/// clearing their location entries.
fn drain_frees(st: &mut ClState, n_workers: usize) -> Vec<(usize, Vec<u32>)> {
    if st.graph.dead_files.is_empty() {
        return Vec::new();
    }
    let dead = std::mem::take(&mut st.graph.dead_files);
    let mut per: Vec<Vec<u32>> = vec![Vec::new(); n_workers];
    for id in dead {
        let Some(m) = st.copies.get_mut(id as usize) else {
            continue;
        };
        let mask = std::mem::take(m);
        for (w, ids) in per.iter_mut().enumerate() {
            if mask & (1u64 << w) != 0 {
                ids.push(id);
            }
        }
    }
    per.into_iter()
        .enumerate()
        .filter(|(_, ids)| !ids.is_empty())
        .collect()
}

/// Where one task input comes from.
enum Source {
    /// Rare: a value still resident in the coordinator table.
    Local(Arc<Block>),
    /// Re-load a root block from the coordinator's journal (its every
    /// worker replica died).
    Root,
    /// Fetch from worker `serve`; `pull_from` first migrates the block
    /// worker-to-worker from that peer onto `serve`.
    Remote { serve: usize, pull_from: Option<usize> },
}

struct FetchPlan {
    id: DataId,
    source: Source,
}

/// A claimed task with its transfer plan, ready to execute off-lock.
struct ExecPlan {
    tid: TaskId,
    name: &'static str,
    body: TaskBody,
    reads: Vec<DataId>,
    out_ids: Vec<DataId>,
    placement: usize,
    /// Further live workers mirroring the outputs (k-way replication).
    replicas: Vec<usize>,
    fetches: Vec<FetchPlan>,
    /// Claim time, for the per-task-name running-time EWMA.
    started: Instant,
    /// Outstanding bytes charged to `placement` at claim; released when
    /// this copy publishes.
    load_bytes: u64,
}

/// Claim-time planning under the central lock: verify every input is
/// resolvable, choose the placement worker among survivors, count locality
/// hits/misses, and register in-flight pulls. Returns `Ok(None)` when the
/// task must *park* — an input's every replica died and its producer is
/// mid-replay, so the task re-pends on that producer and re-readies
/// through the ordinary dependency path when the replay completes.
fn build_plan(
    st: &mut ClState,
    tid: TaskId,
    transfer: TransferMode,
    inner: &ClusterInner,
) -> Result<Option<ExecPlan>> {
    let n_workers = inner.n_workers();
    let spec = &st.graph.tasks[tid as usize].spec;
    let name = spec.name;
    let body = spec.body.clone();
    let reads: Vec<DataId> = spec.reads.to_vec();
    let out_ids: Vec<DataId> = spec.writes.to_vec();

    // First-occurrence-ordered dedup; linear, since this runs under the
    // scheduler lock and collection tasks read hundreds of blocks.
    let mut uniq: Vec<DataId> = Vec::with_capacity(reads.len());
    let mut seen: HashSet<DataId> = HashSet::with_capacity(reads.len());
    for &r in &reads {
        if seen.insert(r) {
            uniq.push(r);
        }
    }
    // Resolution per input. Readiness guarantees every input was
    // materialized *at some point*; a hole that neither a survivor, the
    // root journal, nor an in-flight replay covers is a real error and
    // must poison the runtime, not run with empty inputs.
    enum Resolve {
        Local(Arc<Block>),
        Root,
        Located { mask: u64, bytes: usize },
        Park,
    }
    let mut infos: Vec<Resolve> = Vec::with_capacity(uniq.len());
    let mut parked: Vec<TaskId> = Vec::new();
    for &r in &uniq {
        let d = &st.graph.data[r as usize];
        if let Some(v) = &d.value {
            infos.push(Resolve::Local(Arc::clone(v)));
            continue;
        }
        let mask = st.copies.get(r as usize).copied().unwrap_or(0);
        if mask != 0 {
            infos.push(Resolve::Located {
                mask,
                bytes: d.meta.bytes(),
            });
            continue;
        }
        // No replica anywhere: recoverable only via replay or the journal.
        match d.producer {
            Some(p)
                if inner.recovery
                    && st.graph.tasks[p as usize].state != TaskState::Done =>
            {
                parked.push(p);
                infos.push(Resolve::Park);
            }
            None if inner.recovery && inner.root_store.is_some() => {
                infos.push(Resolve::Root);
            }
            _ => bail!("input {r} unresolved for ready task (no worker holds it)"),
        }
    }
    if !parked.is_empty() {
        // Park: one dependency edge per lost input occurrence; each is
        // balanced by the producer's next `complete()`.
        let deps = parked.len() as u32;
        for p in parked {
            st.graph.tasks[p as usize].dependents.push(tid);
        }
        let node = &mut st.graph.tasks[tid as usize];
        node.deps_remaining = deps;
        node.state = TaskState::Pending;
        return Ok(None);
    }

    let weighted: Vec<(u64, usize)> = infos
        .iter()
        .filter_map(|r| match r {
            Resolve::Located { mask, bytes } => Some((*mask, *bytes)),
            _ => None,
        })
        .collect();
    // A speculative re-arm must land away from the straggler it doubles;
    // degrade gracefully when no other worker is eligible.
    let avoid = st.speculate_avoid.remove(&tid).unwrap_or(0);
    let mut eligible = st.eligible() & !avoid;
    if eligible == 0 {
        eligible = st.eligible();
    }
    if eligible == 0 {
        eligible = st.alive;
    }
    let placement = match choose_placement(&weighted, n_workers, eligible, &st.load) {
        Some(w) => w,
        None => next_rr(st, n_workers, eligible),
    };
    let bit = 1u64 << placement;
    // k-way replication: spread mirrors across the least-loaded other
    // eligible workers (load-aware, so a freshly joined worker absorbs
    // replicas first).
    let k = inner.replicate.min(eligible.count_ones() as usize).max(1);
    let replicas = choose_replicas(placement, k, n_workers, eligible, &st.load);

    let mut hits = 0u64;
    let mut transfers = 0u64;
    let mut fetches = Vec::with_capacity(uniq.len());
    for (&id, info) in uniq.iter().zip(&infos) {
        let source = match info {
            Resolve::Local(v) => {
                hits += 1;
                Source::Local(Arc::clone(v))
            }
            // A journal reload costs disk I/O, not wire traffic.
            Resolve::Root => {
                hits += 1;
                Source::Root
            }
            Resolve::Park => unreachable!("parked plans returned above"),
            Resolve::Located { mask, .. } => {
                if mask & bit != 0 {
                    hits += 1;
                    Source::Remote {
                        serve: placement,
                        pull_from: None,
                    }
                } else {
                    transfers += 1;
                    let src = mask.trailing_zeros() as usize;
                    if transfer == TransferMode::Pull
                        && !st.pulling.contains(&(id, placement))
                    {
                        st.pulling.insert((id, placement));
                        Source::Remote {
                            serve: placement,
                            pull_from: Some(src),
                        }
                    } else {
                        // Relay mode, or the same migration is already in
                        // flight: read from a stable holder.
                        Source::Remote {
                            serve: src,
                            pull_from: None,
                        }
                    }
                }
            }
        };
        fetches.push(FetchPlan { id, source });
    }
    st.metrics.record_locality(hits, transfers);
    st.metrics.record_task_on_worker(placement);
    // Charge the placement worker for this task's declared output bytes —
    // the outstanding-bytes signal load-aware placement reads. Released at
    // publish.
    let load_bytes: u64 = out_ids
        .iter()
        .map(|&o| st.graph.data[o as usize].meta.bytes() as u64)
        .sum();
    st.add_load(placement, load_bytes);
    // Register the copy for the straggler monitor. A speculative twin
    // reuses the original's entry (`or_insert` keeps the first claim), so
    // each task is speculated at most once.
    st.inflight.entry(tid).or_insert(Inflight {
        name,
        placement,
        started: Instant::now(),
        speculated: false,
    });
    Ok(Some(ExecPlan {
        tid,
        name,
        body,
        reads,
        out_ids,
        placement,
        replicas,
        fetches,
        started: Instant::now(),
        load_bytes,
    }))
}

/// Run one planned task off-lock: transfers, closure, output push, publish.
/// Transport failures classify as [`ClusterFailure::WorkerDown`] and route
/// through [`handle_worker_death`] + requeue instead of poisoning.
fn execute_plan(inner: &Arc<ClusterInner>, plan: ExecPlan) {
    let mut wire_bytes = 0u64;
    let mut pulled: Vec<(DataId, usize)> = Vec::new();
    let mut cache: HashMap<DataId, Arc<Block>> = HashMap::new();
    let mut failure: Option<ClusterFailure> = None;

    // ---- Input transfers ----
    for f in &plan.fetches {
        match &f.source {
            Source::Local(b) => {
                cache.insert(f.id, Arc::clone(b));
            }
            Source::Root => {
                // Every worker replica of this root died; re-load it from
                // the coordinator's journal (disk, not wire).
                let store = inner
                    .root_store
                    .as_ref()
                    .expect("Source::Root is only planned with a root store");
                match store.fault(f.id) {
                    Ok(b) => {
                        cache.insert(f.id, Arc::new(b));
                    }
                    Err(e) => {
                        failure = Some(ClusterFailure::Protocol {
                            msg: format!("root journal reload of block {}: {e:#}", f.id),
                        });
                    }
                }
                if failure.is_some() {
                    break;
                }
            }
            Source::Remote { serve, pull_from } => {
                if let Some(src) = pull_from {
                    let src_addr = inner.addr_of(*src);
                    let serve_conn = inner.conn(*serve);
                    let req = Request::Pull {
                        id: f.id,
                        from: src_addr.clone(),
                    };
                    match serve_conn.call(&req) {
                        Ok((Response::Pulled { bytes }, io)) => {
                            wire_bytes += io + bytes;
                            pulled.push((f.id, *serve));
                        }
                        // The *peer* being pulled from is unreachable: the
                        // responding worker is healthy, its source is dead.
                        Ok((Response::PullPeerDown(m), io)) => {
                            wire_bytes += io;
                            failure = Some(ClusterFailure::WorkerDown {
                                w: *src,
                                msg: format!("pull peer {src_addr}: {m}"),
                            });
                        }
                        Ok((Response::Err(m), io)) => {
                            wire_bytes += io;
                            failure = Some(ClusterFailure::Protocol {
                                msg: format!("worker {}: {m}", serve_conn.addr),
                            });
                        }
                        Ok((other, io)) => {
                            wire_bytes += io;
                            failure = Some(ClusterFailure::Protocol {
                                msg: format!(
                                    "worker {}: unexpected response {other:?} to Pull",
                                    serve_conn.addr
                                ),
                            });
                        }
                        Err(e) => {
                            failure = Some(ClusterFailure::WorkerDown {
                                w: *serve,
                                msg: format!("worker {}: {e:#}", serve_conn.addr),
                            });
                        }
                    }
                    if failure.is_some() {
                        break;
                    }
                }
                match inner.fetch_block(*serve, f.id) {
                    Ok((b, io)) => {
                        wire_bytes += io;
                        cache.insert(f.id, Arc::new(b));
                    }
                    Err(e) => failure = Some(e),
                }
                if failure.is_some() {
                    break;
                }
            }
        }
    }

    // ---- Run the closure, then push outputs to placement + replicas ----
    let outcome: Result<(), ClusterFailure> = match failure {
        Some(f) => Err(f),
        None => {
            let result: Result<Vec<Block>> = match &plan.body {
                TaskBody::Shared(func) => {
                    let ins: Vec<Arc<Block>> = plan
                        .reads
                        .iter()
                        .map(|r| Arc::clone(cache.get(r).expect("every read was fetched")))
                        .collect();
                    func(&ins)
                }
                // No exclusive grants on the cluster backend: the fetched
                // copy is already private to this task, and the
                // authoritative value lives on a worker.
                TaskBody::Owned(func) => {
                    let ins: Vec<TaskInput> = plan
                        .reads
                        .iter()
                        .map(|r| {
                            TaskInput::Shared(Arc::clone(
                                cache.get(r).expect("every read was fetched"),
                            ))
                        })
                        .collect();
                    func(ins)
                }
            };
            drop(cache);
            let mut targets = Vec::with_capacity(1 + plan.replicas.len());
            targets.push(plan.placement);
            targets.extend_from_slice(&plan.replicas);
            push_outputs(inner, &targets, &plan.out_ids, result, &mut wire_bytes)
        }
    };

    // ---- Publish under the central lock ----
    let frees = {
        let mut guard = inner.state.lock().unwrap();
        let st = &mut *guard;
        st.running -= 1;
        // Commit completed migrations to the location table (only onto
        // workers still alive — a concurrent death marking must not be
        // resurrected by a stale success) and clear every in-flight marker
        // this plan registered (performed or not).
        for &(id, w) in &pulled {
            if st.alive & (1u64 << w) != 0 {
                ensure_copies(&mut st.copies, id);
                st.copies[id as usize] |= 1u64 << w;
            }
        }
        for f in &plan.fetches {
            if let Source::Remote {
                serve,
                pull_from: Some(_),
            } = &f.source
            {
                st.pulling.remove(&(f.id, *serve));
            }
        }
        st.metrics.record_wire(wire_bytes);
        st.sub_load(plan.placement, plan.load_bytes);
        // First-completion-wins: if this task is no longer `Running`, a
        // speculative twin already published (or a recovery path requeued
        // it). Deterministic closures over single-assignment inputs make
        // both copies bit-identical, so the loser is simply discarded —
        // its outputs freed wherever they landed outside the winner's
        // committed location set.
        let task_state = st.graph.tasks[plan.tid as usize].state;
        let mut loser_frees: Vec<(usize, Vec<u32>)> = Vec::new();
        match outcome {
            Ok(()) if task_state != TaskState::Running => {
                let mut targets = Vec::with_capacity(1 + plan.replicas.len());
                targets.push(plan.placement);
                targets.extend_from_slice(&plan.replicas);
                for &t in &targets {
                    if st.alive & (1u64 << t) == 0 {
                        continue;
                    }
                    let ids: Vec<u32> = plan
                        .out_ids
                        .iter()
                        .copied()
                        .filter(|&o| {
                            st.copies.get(o as usize).copied().unwrap_or(0) & (1u64 << t)
                                == 0
                        })
                        .collect();
                    if !ids.is_empty() {
                        loser_frees.push((t, ids));
                    }
                }
            }
            // The placement worker died between our pushes and this
            // publish: the outputs went down with it, so requeue instead
            // of completing with phantom locations.
            Ok(()) if st.alive & (1u64 << plan.placement) == 0 => {
                st.graph.tasks[plan.tid as usize].state = TaskState::Ready;
                st.ready.push_back(plan.tid);
                st.inflight.remove(&plan.tid);
            }
            Ok(()) => {
                let mut bits = 1u64 << plan.placement;
                for &r in &plan.replicas {
                    if st.alive & (1u64 << r) != 0 {
                        bits |= 1u64 << r;
                    }
                }
                for &o in &plan.out_ids {
                    let d = &mut st.graph.data[o as usize];
                    d.spilled = true;
                    d.on_disk = true;
                    ensure_copies(&mut st.copies, o);
                    st.copies[o as usize] = bits;
                    st.graph.touch(o);
                }
                let done = st.graph.complete(plan.tid, None);
                for bytes in done.evicted {
                    st.metrics.record_evicted(bytes);
                }
                // Outputs whose every owner released before materialization
                // are dead on arrival: free them remotely right away.
                for &o in &plan.out_ids {
                    if let Some(bytes) = st.graph.try_evict(o) {
                        st.metrics.record_evicted(bytes);
                    }
                }
                for dep in done.now_ready {
                    st.ready.push_back(dep);
                }
                // Feed the winner's running time into the straggler
                // estimate and retire the inflight entry.
                let sample = plan.started.elapsed().as_secs_f64();
                let est = match st.ewma.get(plan.name) {
                    Some(&prev) => 0.7 * prev + 0.3 * sample,
                    None => sample,
                };
                st.ewma.insert(plan.name, est);
                st.inflight.remove(&plan.tid);
            }
            Err(ClusterFailure::WorkerDown { w, msg }) => {
                match handle_worker_death(st, w, inner) {
                    // Recovery absorbed the death: the lost sub-graph is
                    // re-armed, so requeue this task — its inputs resolve
                    // against survivors (or park on the replay) next plan —
                    // unless a speculative twin already completed it.
                    Ok(()) => {
                        if task_state == TaskState::Running
                            && st.graph.tasks[plan.tid as usize].state
                                == TaskState::Running
                        {
                            st.graph.tasks[plan.tid as usize].state = TaskState::Ready;
                            st.ready.push_back(plan.tid);
                        }
                        st.inflight.remove(&plan.tid);
                    }
                    Err(e) => {
                        st.graph.tasks[plan.tid as usize].state = TaskState::Failed;
                        st.error.get_or_insert(format!(
                            "task `{}` failed on cluster backend: {msg} ({e:#})",
                            plan.name
                        ));
                    }
                }
            }
            // A loser's protocol error is masked: determinism means the
            // winning copy saw the same closure result, and it already
            // published the authoritative outcome.
            Err(ClusterFailure::Protocol { .. }) if task_state != TaskState::Running => {}
            Err(ClusterFailure::Protocol { msg }) => {
                st.graph.tasks[plan.tid as usize].state = TaskState::Failed;
                st.error.get_or_insert(format!(
                    "task `{}` failed on cluster backend: {msg}",
                    plan.name
                ));
            }
        }
        let mut frees = drain_frees(st, inner.n_workers());
        frees.extend(loser_frees);
        frees
    };
    inner.send_frees(frees);
    inner.cv.notify_all();
}

/// Validate a task's result and `Put` each output on every target worker
/// (placement first, then replicas). Protocol errors carry the worker
/// address (the poison message the kill-a-worker contract requires);
/// transport errors classify the target as down so the caller can recover
/// and requeue.
fn push_outputs(
    inner: &ClusterInner,
    targets: &[usize],
    out_ids: &[DataId],
    result: Result<Vec<Block>>,
    wire_bytes: &mut u64,
) -> Result<(), ClusterFailure> {
    let outs = match result {
        Ok(o) => o,
        Err(e) => {
            return Err(ClusterFailure::Protocol {
                msg: format!("{e:#}"),
            })
        }
    };
    if outs.len() != out_ids.len() {
        return Err(ClusterFailure::Protocol {
            msg: format!("returned {} outputs, declared {}", outs.len(), out_ids.len()),
        });
    }
    for (&id, block) in out_ids.iter().zip(outs) {
        let mut block = Some(block);
        for (i, &t) in targets.iter().enumerate() {
            let conn = inner.conn(t);
            // The last target consumes the block; earlier ones get clones.
            let payload = if i + 1 == targets.len() {
                block.take().expect("one consume per output")
            } else {
                block.as_ref().expect("clone precedes consume").clone()
            };
            match conn.call(&Request::Put { id, block: payload }) {
                Ok((Response::Ok, io)) => *wire_bytes += io,
                Ok((Response::Err(m), io)) => {
                    *wire_bytes += io;
                    return Err(ClusterFailure::Protocol {
                        msg: format!("worker {}: {m}", conn.addr),
                    });
                }
                Ok((other, io)) => {
                    *wire_bytes += io;
                    return Err(ClusterFailure::Protocol {
                        msg: format!(
                            "worker {}: unexpected response {other:?} to Put",
                            conn.addr
                        ),
                    });
                }
                Err(e) => {
                    return Err(ClusterFailure::WorkerDown {
                        w: t,
                        msg: format!("worker {}: {e:#}", conn.addr),
                    })
                }
            }
        }
    }
    Ok(())
}

fn cluster_exec_loop(inner: Arc<ClusterInner>) {
    // Idle waits are condvar-signaled: block arrival, worker death, replay
    // re-arms, submissions, joins and shutdown all notify under the same
    // mutex. The timed wake is belt-and-braces only, so it backs off
    // exponentially to a cap instead of burning a rescan every 10ms.
    const IDLE_MIN: Duration = Duration::from_millis(10);
    const IDLE_MAX: Duration = Duration::from_millis(500);
    let mut idle = IDLE_MIN;
    loop {
        // ---- Acquire + claim + plan under one lock acquisition ----
        let plan = {
            let mut guard = inner.state.lock().unwrap();
            let tid = loop {
                if guard.shutdown {
                    return;
                }
                if let Some(t) = guard.ready.pop_front() {
                    // Drop stale entries: a speculative re-arm whose twin
                    // already finished leaves a Done task in the queue.
                    let s = guard.graph.tasks[t as usize].state;
                    if s == TaskState::Done || s == TaskState::Failed {
                        guard.speculate_avoid.remove(&t);
                        continue;
                    }
                    break t;
                }
                let (g, timeout) = inner.cv.wait_timeout(guard, idle).unwrap();
                guard = g;
                idle = if timeout.timed_out() {
                    (idle * 2).min(IDLE_MAX)
                } else {
                    IDLE_MIN
                };
            };
            idle = IDLE_MIN;
            let st = &mut *guard;
            st.graph.tasks[tid as usize].state = TaskState::Running;
            st.running += 1;
            match build_plan(st, tid, inner.transfer, &inner) {
                Ok(Some(p)) => Ok(Some(p)),
                // Parked: the task re-pended on a replaying producer and
                // will re-ready through the dependency path.
                Ok(None) => {
                    st.running -= 1;
                    Ok(None)
                }
                Err(e) => {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.running -= 1;
                    st.error
                        .get_or_insert(format!("task `{name}` failed: {e:#}"));
                    Err(())
                }
            }
        };
        match plan {
            Ok(Some(p)) => execute_plan(&inner, p),
            Ok(None) | Err(()) => inner.cv.notify_all(),
        }
    }
}

/// The coordinator backend. Construct via [`ClusterOptions`] and wrap with
/// `Runtime::cluster`; every ds-array operation, estimator, lazy view and
/// fused expression then runs unmodified against remote block memory.
pub struct ClusterExecutor {
    inner: Arc<ClusterInner>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    children: Mutex<Vec<Child>>,
    /// Connection indices `>= owned_from` belong to workers we spawned (and
    /// shut down on drop); earlier ones are externally managed. Workers
    /// joining mid-run land past the spawned range and are never owned.
    owned_from: usize,
    /// How many workers were enrolled at boot; `owned_from..owned_children`
    /// spans exactly the spawned children.
    owned_children: usize,
    /// The control listener's address (`Join`/`Drain` frames).
    control_addr: String,
    /// Heartbeat / straggler-monitor and control-listener threads, joined
    /// at drop alongside the executor pool.
    aux_threads: Mutex<Vec<JoinHandle<()>>>,
}

impl ClusterExecutor {
    pub fn new(opts: ClusterOptions) -> Result<Self> {
        let owned_from = opts.addrs.len();
        // Created before any worker spawns so a journal failure can't leak
        // child processes.
        let root_store = if opts.recovery {
            Some(BlockStore::in_temp().context("creating root-block journal")?)
        } else {
            None
        };
        let mut children = Vec::new();
        let conns = match Self::boot(&opts, &mut children) {
            Ok(c) => c,
            Err(e) => {
                // Never leak spawned processes on a failed boot.
                for mut child in children {
                    child.kill().ok();
                    child.wait().ok();
                }
                return Err(e);
            }
        };

        let n_boot = conns.len();
        let alive = full_mask(n_boot);
        let inner = Arc::new(ClusterInner {
            state: Mutex::new(ClState {
                graph: Graph::default(),
                ready: VecDeque::new(),
                running: 0,
                shutdown: false,
                error: None,
                metrics: Metrics::default(),
                copies: Vec::new(),
                pulling: HashSet::new(),
                rr: 0,
                alive,
                draining: 0,
                load: vec![0; n_boot],
                ewma: HashMap::new(),
                inflight: HashMap::new(),
                speculate_avoid: HashMap::new(),
            }),
            cv: Condvar::new(),
            conns: RwLock::new(conns.into_iter().map(Arc::new).collect()),
            transfer: opts.transfer,
            heartbeat_ms: opts.heartbeat_ms,
            straggler_factor: opts.straggler_factor,
            recovery: opts.recovery,
            replicate: opts.replicate.max(1),
            root_store,
        });
        let threads = (0..opts.threads.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || cluster_exec_loop(inner))
            })
            .collect();
        let mut aux_threads = Vec::new();
        // Control listener: workers join (and request drains) mid-run here.
        let listener = TcpListener::bind("127.0.0.1:0").context("binding control listener")?;
        let control_addr = listener
            .local_addr()
            .context("control listener address")?
            .to_string();
        {
            let inner = Arc::clone(&inner);
            aux_threads.push(std::thread::spawn(move || control_loop(inner, listener)));
        }
        // Liveness + straggler monitor, only when either knob is on.
        if opts.heartbeat_ms > 0 || opts.straggler_factor > 0.0 {
            let inner = Arc::clone(&inner);
            aux_threads.push(std::thread::spawn(move || monitor_loop(inner)));
        }
        Ok(Self {
            inner,
            threads: Mutex::new(threads),
            children: Mutex::new(children),
            owned_from,
            owned_children: n_boot,
            control_addr,
            aux_threads: Mutex::new(aux_threads),
        })
    }

    /// Spawn requested workers, connect to every address, and ping each
    /// once. Spawned children accumulate in `children` so the caller can
    /// reap them if any later step fails.
    fn boot(opts: &ClusterOptions, children: &mut Vec<Child>) -> Result<Vec<WorkerConn>> {
        let mut addrs = opts.addrs.clone();
        if opts.spawn > 0 {
            let program = match &opts.program {
                Some(p) => p.clone(),
                None => std::env::current_exe().context("locating worker binary")?,
            };
            for _ in 0..opts.spawn {
                let (child, addr) = spawn_worker_process(&program, opts.worker_budget_bytes)?;
                children.push(child);
                addrs.push(addr);
            }
        }
        if addrs.is_empty() {
            bail!("cluster backend needs at least one worker (addrs or spawn)");
        }
        if addrs.len() > 64 {
            bail!(
                "cluster backend supports at most 64 workers, got {}",
                addrs.len()
            );
        }
        let mut conns = Vec::with_capacity(addrs.len());
        for a in &addrs {
            conns.push(WorkerConn::connect(a)?);
        }
        for c in &conns {
            match c.call(&Request::Ping)? {
                (Response::Ok, _) => {}
                (other, _) => bail!("worker {} answered ping with {other:?}", c.addr),
            }
        }
        Ok(conns)
    }

    /// Addresses of the enrolled workers, in location-table bit order
    /// (drained and dead workers keep their slot).
    pub fn worker_addrs(&self) -> Vec<String> {
        self.inner
            .conns
            .read()
            .unwrap()
            .iter()
            .map(|c| c.addr.clone())
            .collect()
    }

    /// Address of the coordinator's control listener. A worker started with
    /// `dsarray worker --join <this-addr>` enrolls itself here mid-run;
    /// `Drain` frames arrive here too.
    pub fn coordinator_addr(&self) -> String {
        self.control_addr.clone()
    }

    /// Enroll the worker listening at `addr` into the running fleet and
    /// return its location-table slot. It starts receiving tasks on the
    /// next scheduling decision (an empty worker has zero outstanding-bytes
    /// load, so load-aware placement rebalances onto it naturally).
    pub fn join_worker(&self, addr: &str) -> Result<usize> {
        self.inner.enroll(addr)
    }

    /// Gracefully decommission worker `w`: mark it read-only, migrate its
    /// sole-copy blocks to survivors over the Pull path, then drop it from
    /// the fleet — zero tasks replayed. The worker process itself is left
    /// running (it merely stops being a member).
    pub fn drain(&self, w: usize) -> Result<()> {
        self.inner.drain_worker(w)
    }
}

/// Accept loop for the coordinator's control listener: each connection may
/// carry any number of `Join`/`Drain` (and `Ping`) frames. Connections are
/// handled on their own threads so a long drain never blocks a join.
fn control_loop(inner: Arc<ClusterInner>, listener: TcpListener) {
    for stream in listener.incoming() {
        if inner.state.lock().unwrap().shutdown {
            return;
        }
        let Ok(stream) = stream else { continue };
        stream.set_nodelay(true).ok();
        let inner = Arc::clone(&inner);
        std::thread::spawn(move || control_conn_loop(&inner, stream));
    }
}

fn control_conn_loop(inner: &ClusterInner, mut stream: TcpStream) {
    loop {
        let req = match wire::read_request(&mut stream) {
            Ok(r) => r,
            Err(_) => return, // peer closed (or broke) the control stream
        };
        let resp = match req {
            Request::Ping => Response::Ok,
            Request::Join { addr } => match inner.enroll(&addr) {
                Ok(_) => Response::Ok,
                Err(e) => Response::Err(format!("join {addr}: {e:#}")),
            },
            Request::Drain { addr } => {
                let w = inner
                    .conns
                    .read()
                    .unwrap()
                    .iter()
                    .position(|c| c.addr == addr);
                match w {
                    None => Response::Err(format!("drain {addr}: no such worker")),
                    Some(w) => match inner.drain_worker(w) {
                        Ok(()) => Response::Ok,
                        Err(e) => Response::Err(format!("drain {addr}: {e:#}")),
                    },
                }
            }
            other => Response::Err(format!(
                "{other:?} is not a control request (want Join/Drain/Ping)"
            )),
        };
        if wire::write_response(&mut stream, &resp).is_err() {
            return;
        }
    }
}

/// One heartbeat probe on a dedicated, timeout-bounded connection. The
/// shared [`WorkerConn`] stream is useless for liveness — a probe there
/// would queue behind whatever stalled call currently holds its mutex.
fn heartbeat_probe(addr: &str, timeout: Duration) -> bool {
    use std::net::ToSocketAddrs;
    let Ok(mut resolved) = addr.to_socket_addrs() else {
        return false;
    };
    let Some(sock) = resolved.next() else {
        return false;
    };
    let Ok(mut s) = TcpStream::connect_timeout(&sock, timeout) else {
        return false;
    };
    s.set_read_timeout(Some(timeout)).ok();
    s.set_write_timeout(Some(timeout)).ok();
    if wire::write_request(&mut s, &Request::Ping).is_err() {
        return false;
    }
    matches!(wire::read_response(&mut s), Ok((Response::Ok, _)))
}

/// Liveness + straggler monitor. Each tick (the heartbeat interval, or
/// 50ms when only speculation is on) it:
///
/// * pings every live worker on a dedicated connection; a worker missing
///   [`HEARTBEAT_MISS_THRESHOLD`] consecutive beats — reconnect attempts
///   back off exponentially in between — is declared dead: lineage
///   recovery absorbs it under the central lock and its main connection is
///   severed so calls blocked on a stalled worker error promptly;
/// * scans in-flight tasks and speculatively re-arms any copy running
///   longer than `straggler_factor ×` its task name's EWMA estimate on
///   another worker (at most one twin per task, first completion wins).
fn monitor_loop(inner: Arc<ClusterInner>) {
    let tick = Duration::from_millis(if inner.heartbeat_ms > 0 {
        inner.heartbeat_ms
    } else {
        50
    });
    let probe_timeout = tick.max(Duration::from_millis(50));
    // Per-slot consecutive-miss counts and backoff skip budgets.
    let mut misses: Vec<u32> = Vec::new();
    let mut skip: Vec<u32> = Vec::new();
    let mut last_beat = Instant::now().checked_sub(tick).unwrap_or_else(Instant::now);
    loop {
        // Tick = condvar wait with timeout, so shutdown wakes us promptly.
        {
            let guard = inner.state.lock().unwrap();
            if guard.shutdown {
                return;
            }
            let (guard, _) = inner.cv.wait_timeout(guard, tick).unwrap();
            if guard.shutdown {
                return;
            }
        }

        // ---- Straggler speculation ----
        if inner.straggler_factor > 0.0 {
            let mut notify = false;
            {
                let mut st = inner.state.lock().unwrap();
                let now = Instant::now();
                let mut arm: Vec<(TaskId, usize)> = Vec::new();
                for (&tid, inf) in st.inflight.iter() {
                    if inf.speculated {
                        continue;
                    }
                    let Some(&est) = st.ewma.get(inf.name) else {
                        continue; // no estimate yet for this task name
                    };
                    let est = est.max(0.010); // 10ms floor against noise
                    let elapsed = now.duration_since(inf.started).as_secs_f64();
                    if elapsed <= inner.straggler_factor * est {
                        continue;
                    }
                    // Only worth doubling when another worker is eligible.
                    if st.eligible() & !(1u64 << inf.placement) != 0 {
                        arm.push((tid, inf.placement));
                    }
                }
                for (tid, placement) in arm {
                    if let Some(inf) = st.inflight.get_mut(&tid) {
                        inf.speculated = true;
                    }
                    st.speculate_avoid.insert(tid, 1u64 << placement);
                    st.ready.push_back(tid);
                    st.metrics.record_speculated();
                    notify = true;
                }
            }
            if notify {
                inner.cv.notify_all();
            }
        }

        // ---- Heartbeat liveness ----
        if inner.heartbeat_ms == 0 {
            continue;
        }
        // The condvar wakes early on every notify; probes pace themselves
        // on a real deadline so busy runtimes don't ping at notify rate.
        if last_beat.elapsed() < tick {
            continue;
        }
        last_beat = Instant::now();
        let (alive, n) = {
            let st = inner.state.lock().unwrap();
            (st.alive, inner.n_workers())
        };
        misses.resize(n.max(misses.len()), 0);
        skip.resize(n.max(skip.len()), 0);
        for w in 0..n {
            if alive & (1u64 << w) == 0 {
                continue;
            }
            if skip[w] > 0 {
                skip[w] -= 1; // exponential backoff between reconnects
                continue;
            }
            let addr = inner.addr_of(w);
            if heartbeat_probe(&addr, probe_timeout) {
                misses[w] = 0;
                continue;
            }
            misses[w] += 1;
            if misses[w] < HEARTBEAT_MISS_THRESHOLD {
                // Back off 2^misses - 1 ticks before the next attempt.
                skip[w] = (1u32 << misses[w].min(4)) - 1;
                continue;
            }
            misses[w] = 0;
            // Declared dead: absorb through lineage recovery, then sever
            // the main connection so blocked in-flight calls error instead
            // of hanging on a stalled worker.
            {
                let mut st = inner.state.lock().unwrap();
                if let Err(e) = handle_worker_death(&mut st, w, &inner) {
                    st.error
                        .get_or_insert(format!("heartbeat lost worker {addr}: {e:#}"));
                }
            }
            inner.conn(w).sever();
            inner.cv.notify_all();
        }
    }
}

impl Executor for ClusterExecutor {
    fn workers(&self) -> usize {
        self.inner.n_workers()
    }

    fn put_block(&self, block: Block) -> DataId {
        let meta = block.meta();
        let (id, targets) = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            let id = st.graph.put_block(meta, None);
            ensure_copies(&mut st.copies, id);
            // k distinct eligible targets, round-robin so roots stay
            // spread; draining workers are read-only and never targeted.
            let k = self
                .inner
                .replicate
                .min(st.eligible().count_ones() as usize)
                .max(1);
            let n = self.inner.n_workers();
            let eligible = if st.eligible() != 0 { st.eligible() } else { st.alive };
            let mut targets: Vec<usize> = Vec::with_capacity(k);
            while targets.len() < k {
                let w = next_rr(st, n, eligible);
                if !targets.contains(&w) {
                    targets.push(w);
                }
            }
            (id, targets)
        };
        // Roots have no producing task to replay, so journal them to the
        // coordinator's local store first — recovery's last line when every
        // worker replica dies. Journal files persist until teardown: a root
        // evicted from workers before a death may still anchor a later
        // replay.
        if let Some(store) = &self.inner.root_store {
            if let Err(e) = store.spill(id, &block) {
                let mut st = self.inner.state.lock().unwrap();
                st.error
                    .get_or_insert(format!("put_block({id}) root journal: {e:#}"));
                return id;
            }
        }
        // The id is not visible to any submitter until we return, so the
        // pushes can run outside the lock without racing a reader.
        let mut block = Some(block);
        let mut placed = 0u64;
        let mut wire = 0u64;
        for (i, &w) in targets.iter().enumerate() {
            let payload = if i + 1 == targets.len() {
                block.take().expect("one consume per put")
            } else {
                block.as_ref().expect("clone precedes consume").clone()
            };
            match self.inner.conn(w).call(&Request::Put { id, block: payload }) {
                Ok((Response::Ok, bytes)) => {
                    wire += bytes;
                    placed |= 1u64 << w;
                }
                Ok((other, _)) => {
                    let msg = match other {
                        Response::Err(m) => m,
                        o => format!("unexpected response {o:?} to Put"),
                    };
                    let mut st = self.inner.state.lock().unwrap();
                    st.error.get_or_insert(format!(
                        "put_block({id}) on worker {}: {msg}",
                        self.inner.addr_of(w)
                    ));
                    return id;
                }
                Err(e) => {
                    // Transport failure: the target died. With recovery the
                    // journal already covers this root, so absorb the death
                    // and move on; without it, poison with the old message.
                    let mut st = self.inner.state.lock().unwrap();
                    match handle_worker_death(&mut st, w, &self.inner) {
                        Ok(()) => {
                            drop(st);
                            self.inner.cv.notify_all();
                            continue;
                        }
                        Err(death) => {
                            st.error.get_or_insert(format!(
                                "put_block({id}) on worker {}: {e:#} ({death:#})",
                                self.inner.addr_of(w)
                            ));
                            return id;
                        }
                    }
                }
            }
        }
        {
            let mut st = self.inner.state.lock().unwrap();
            placed &= st.alive;
            let d = &mut st.graph.data[id as usize];
            if placed != 0 {
                d.spilled = true;
                d.on_disk = true;
            } else if self.inner.root_store.is_some() {
                // Every target died mid-put; the journal alone holds it.
                d.spilled = true;
                d.on_disk = true;
            }
            st.copies[id as usize] = placed;
            st.metrics.record_wire(wire);
        }
        id
    }

    fn submit_batch(&self, tasks: Vec<TaskSubmit>) -> Vec<Vec<DataId>> {
        self.submit_batch_releasing(tasks, &[])
    }

    fn submit_batch_releasing(
        &self,
        tasks: Vec<TaskSubmit>,
        release: &[DataId],
    ) -> Vec<Vec<DataId>> {
        let mut outs_all = Vec::with_capacity(tasks.len());
        let mut any_ready = false;
        let frees = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            for t in tasks {
                let (tid, outs, ready) = st.graph.submit_record(t, &mut st.metrics);
                if ready {
                    st.ready.push_back(tid);
                    any_ready = true;
                }
                outs_all.push(outs);
            }
            for &id in release {
                if let Some(bytes) = st.graph.release(id) {
                    st.metrics.record_evicted(bytes);
                }
            }
            drain_frees(st, self.inner.n_workers())
        };
        self.inner.send_frees(frees);
        if any_ready {
            self.inner.cv.notify_all();
        }
        outs_all
    }

    fn wait(&self, id: DataId) -> Result<Arc<Block>> {
        // What the off-lock half of each retry round does.
        enum Plan {
            Fetch(usize),
            Root,
        }
        // Find a holder under the lock; fetch outside it (fetch-on-demand:
        // the value is returned to the caller, never re-installed in the
        // coordinator table — collect() streams through bounded memory).
        // A fetch that hits a dying worker routes through recovery and
        // retries against the replayed locations instead of poisoning.
        loop {
            let plan = {
                let mut st = self.inner.state.lock().unwrap();
                loop {
                    if let Some(err) = &st.error {
                        bail!("runtime poisoned by task failure: {err}");
                    }
                    let d = &st.graph.data[id as usize];
                    if let Some(v) = &d.value {
                        let v = Arc::clone(v);
                        st.graph.touch(id);
                        return Ok(v);
                    }
                    if d.spilled {
                        let mask = st.copies.get(id as usize).copied().unwrap_or(0);
                        if mask != 0 {
                            break Plan::Fetch(mask.trailing_zeros() as usize);
                        }
                        // Every replica died. Roots reload from the
                        // journal; produced blocks wait for their replay
                        // (re-armed by the death handler) to land.
                        if self.inner.recovery {
                            match d.producer {
                                None if self.inner.root_store.is_some() => {
                                    break Plan::Root;
                                }
                                Some(p)
                                    if st.graph.tasks[p as usize].state
                                        != TaskState::Done =>
                                {
                                    if st.running == 0 && st.ready.is_empty() {
                                        bail!(
                                            "wait({id}) would deadlock: \
                                             replay producer stuck"
                                        );
                                    }
                                    st = self.inner.cv.wait(st).unwrap();
                                    continue;
                                }
                                _ => {}
                            }
                        }
                        bail!("wait({id}): no worker holds this block");
                    }
                    if d.evicted {
                        bail!(
                            "wait({id}): block was reclaimed (all handles released); \
                             pin it to keep it resident"
                        );
                    }
                    if st.running == 0 && st.ready.is_empty() {
                        bail!("wait({id}) would deadlock: no runnable producer");
                    }
                    st = self.inner.cv.wait(st).unwrap();
                }
            };
            match plan {
                Plan::Root => {
                    let store = self
                        .inner
                        .root_store
                        .as_ref()
                        .expect("Plan::Root only with a root store");
                    match store.fault(id) {
                        Ok(block) => return Ok(Arc::new(block)),
                        Err(e) => {
                            let mut st = self.inner.state.lock().unwrap();
                            st.error.get_or_insert(format!(
                                "wait({id}) root journal reload failed: {e:#}"
                            ));
                            drop(st);
                            self.inner.cv.notify_all();
                            bail!("wait({id}): root journal reload failed: {e:#}");
                        }
                    }
                }
                Plan::Fetch(serve) => match self.inner.fetch_block(serve, id) {
                    Ok((block, bytes)) => {
                        self.inner.state.lock().unwrap().metrics.record_wire(bytes);
                        return Ok(Arc::new(block));
                    }
                    Err(ClusterFailure::WorkerDown { w, msg }) => {
                        let recovered = {
                            let mut st = self.inner.state.lock().unwrap();
                            match handle_worker_death(&mut st, w, &self.inner) {
                                Ok(()) => true,
                                Err(e) => {
                                    st.error.get_or_insert(format!(
                                        "wait({id}) fetch failed: {msg} ({e:#})"
                                    ));
                                    false
                                }
                            }
                        };
                        self.inner.cv.notify_all();
                        if recovered {
                            continue; // retry against the recovered locations
                        }
                        bail!("wait({id}) fetch failed: {msg}");
                    }
                    Err(ClusterFailure::Protocol { msg }) => {
                        // An application-level failure from a live worker
                        // is real: poison so barriers and later waits
                        // surface it too.
                        {
                            let mut st = self.inner.state.lock().unwrap();
                            st.error
                                .get_or_insert(format!("wait({id}) fetch failed: {msg}"));
                        }
                        self.inner.cv.notify_all();
                        bail!("wait({id}) fetch failed: {msg}");
                    }
                },
            }
        }
    }

    fn barrier(&self) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            if st.running == 0 && st.ready.is_empty() {
                let stuck = st
                    .graph
                    .tasks
                    .iter()
                    .filter(|t| t.state == TaskState::Pending)
                    .count();
                if stuck > 0 {
                    bail!("barrier: {stuck} tasks stuck pending (malformed graph)");
                }
                return Ok(());
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    fn metrics(&self) -> Metrics {
        self.inner.state.lock().unwrap().metrics.clone()
    }

    fn retain(&self, ids: &[DataId]) {
        let mut st = self.inner.state.lock().unwrap();
        for &id in ids {
            st.graph.retain(id);
        }
    }

    fn release(&self, ids: &[DataId]) {
        let frees = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            for &id in ids {
                if let Some(bytes) = st.graph.release(id) {
                    st.metrics.record_evicted(bytes);
                }
            }
            drain_frees(st, self.inner.n_workers())
        };
        self.inner.send_frees(frees);
    }

    fn pin(&self, id: DataId) {
        let mut st = self.inner.state.lock().unwrap();
        st.graph.data[id as usize].pinned = true;
    }

    fn join_worker(&self, addr: &str) -> Result<usize> {
        ClusterExecutor::join_worker(self, addr)
    }

    fn drain_worker(&self, w: usize) -> Result<()> {
        self.drain(w)
    }

    fn control_addr(&self) -> Option<String> {
        Some(self.coordinator_addr())
    }
}

impl Drop for ClusterExecutor {
    fn drop(&mut self) {
        let alive = {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            st.alive
        };
        self.inner.cv.notify_all();
        for h in self.threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // The control accept loop blocks in `accept`; a dummy connection
        // wakes it so it observes the shutdown flag and exits.
        let _ = TcpStream::connect(&self.control_addr);
        for h in self.aux_threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // Gracefully stop the workers we spawned; externally-managed ones
        // (connected by address or joined mid-run) stay up. Workers already
        // marked dead get no shutdown message — writing to a broken pipe is
        // pointless and their children are reaped below without the
        // graceful wait. Shutdowns go out **concurrently**, each bounded by
        // a socket timeout, so one hung worker cannot stall the teardown of
        // the rest.
        let mut children = self.children.lock().unwrap();
        if !children.is_empty() {
            let conns: Vec<Arc<WorkerConn>> = self.inner.conns.read().unwrap().clone();
            let mut goodbyes = Vec::new();
            for (i, conn) in conns
                .iter()
                .enumerate()
                .take(self.owned_children)
                .skip(self.owned_from)
            {
                if alive & (1u64 << i) != 0 {
                    let conn = Arc::clone(conn);
                    goodbyes.push(std::thread::spawn(move || {
                        {
                            let s = conn.stream.lock().unwrap();
                            s.set_read_timeout(Some(Duration::from_secs(2))).ok();
                            s.set_write_timeout(Some(Duration::from_secs(2))).ok();
                        }
                        let _ = conn.call(&Request::Shutdown);
                    }));
                }
            }
            for h in goodbyes {
                let _ = h.join();
            }
        }
        for (ci, child) in children.iter_mut().enumerate() {
            let w = self.owned_from + ci;
            let mut reaped = false;
            if alive & (1u64 << w) != 0 {
                for _ in 0..50 {
                    match child.try_wait() {
                        Ok(Some(_)) => {
                            reaped = true;
                            break;
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(20)),
                        Err(_) => break,
                    }
                }
            }
            if !reaped {
                // Dead or wedged workers: teardown must never hang.
                child.kill().ok();
                child.wait().ok();
            }
        }
    }
}

/// Spawn one `dsarray worker --listen 127.0.0.1:0` process and parse the
/// `LISTENING <addr>` line it prints once bound.
pub fn spawn_worker_process(
    program: &Path,
    memory_budget_bytes: Option<u64>,
) -> Result<(Child, String)> {
    spawn_worker_process_with(program, memory_budget_bytes, None)
}

/// [`spawn_worker_process`] with a deterministic fault schedule
/// (`--fault-plan`, see [`FaultPlan::spec_for`](super::faults::FaultPlan::spec_for))
/// — the chaos-test entry point.
pub fn spawn_worker_process_with(
    program: &Path,
    memory_budget_bytes: Option<u64>,
    fault_spec: Option<&str>,
) -> Result<(Child, String)> {
    let mut cmd = Command::new(program);
    cmd.arg("worker")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .stdout(Stdio::piped());
    if let Some(b) = memory_budget_bytes {
        cmd.arg("--memory-budget-bytes").arg(b.to_string());
    }
    if let Some(spec) = fault_spec.filter(|s| !s.is_empty()) {
        cmd.arg("--fault-plan").arg(spec);
    }
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawning worker process {}", program.display()))?;
    let stdout = child.stdout.take().expect("stdout piped");
    let mut line = String::new();
    let read = std::io::BufRead::read_line(&mut BufReader::new(stdout), &mut line);
    match read {
        Ok(_) => match line.trim().strip_prefix("LISTENING ") {
            Some(addr) if !addr.is_empty() => Ok((child, addr.to_string())),
            _ => {
                child.kill().ok();
                child.wait().ok();
                bail!("worker did not announce an address (got {line:?})");
            }
        },
        Err(e) => {
            child.kill().ok();
            child.wait().ok();
            Err(anyhow!(e).context("reading worker announcement"))
        }
    }
}

// ---------------------------------------------------------------------------
// Worker daemon
// ---------------------------------------------------------------------------

/// Configuration of a worker process (`dsarray worker`).
#[derive(Clone, Debug, Default)]
pub struct WorkerOptions {
    /// Resident high-water mark: past it, least-recently-used blocks spill
    /// to this worker's own [`BlockStore`] directory and fault back on
    /// `Get` — per-worker out-of-core, no coordinator involvement.
    pub memory_budget_bytes: Option<u64>,
    /// Deterministic fault schedule for this worker (`--fault-plan`), in
    /// [`FaultPlan::parse_spec`](super::faults::FaultPlan::parse_spec)
    /// syntax, e.g. `die@7` or `drop@3,die@9`. `None`/empty = fault-free.
    pub fault_spec: Option<String>,
    /// Whether a crash ([`Request::Crash`] or an injected
    /// [`FaultKind::Die`]) exits the whole process (real worker daemons) or
    /// only silences this worker forever (in-process test workers, which
    /// share the test binary's process).
    pub crash_exits: bool,
}

/// State shared by every connection thread of one worker: the block table,
/// the fault schedule, and the dead flag an in-process crash raises.
struct WorkerShared {
    blocks: Mutex<WorkerBlocks>,
    faults: Option<FaultState>,
    /// Set on crash when `crash_exits` is false: every connection goes
    /// silent and new requests are dropped, indistinguishable on the wire
    /// from a killed process.
    dead: AtomicBool,
    crash_exits: bool,
}

enum WorkerEntry {
    Mem {
        block: Arc<Block>,
        bytes: u64,
        last_use: u64,
    },
    Disk {
        bytes: u64,
    },
}

/// A worker's block table: in-memory values plus a disk tier under budget
/// pressure. All access is serialized through one mutex; per-request work
/// is small next to the wire time, with one known exception — faulting a
/// spilled block back in reads its file under the lock, stalling this
/// worker's other connections for the I/O. Accepted for now: the spill
/// tier only engages under an explicit budget, and lock-free faulting
/// needs per-entry in-flight states that aren't worth it yet.
struct WorkerBlocks {
    entries: HashMap<u32, WorkerEntry>,
    resident: u64,
    clock: u64,
    budget: Option<u64>,
    store: Option<BlockStore>,
    spilled: u64,
    pulled_bytes: u64,
}

impl WorkerBlocks {
    fn insert(&mut self, id: u32, block: Block) -> Result<()> {
        self.remove(id);
        let bytes = block.meta().bytes() as u64;
        self.clock += 1;
        self.entries.insert(
            id,
            WorkerEntry::Mem {
                block: Arc::new(block),
                bytes,
                last_use: self.clock,
            },
        );
        self.resident += bytes;
        self.enforce_budget()
    }

    /// Spill least-recently-used resident blocks until back under budget.
    fn enforce_budget(&mut self) -> Result<()> {
        let Some(budget) = self.budget else {
            return Ok(());
        };
        while self.resident > budget {
            let victim = self
                .entries
                .iter()
                .filter_map(|(&id, e)| match e {
                    WorkerEntry::Mem { last_use, .. } => Some((*last_use, id)),
                    WorkerEntry::Disk { .. } => None,
                })
                .min();
            let Some((_, id)) = victim else {
                break;
            };
            let spill_bytes = {
                let store = self.store.as_ref().expect("budget implies store");
                match self.entries.get(&id) {
                    Some(WorkerEntry::Mem { block, bytes, .. }) => {
                        store.spill(id, block.as_ref())?;
                        *bytes
                    }
                    _ => unreachable!("victim chosen from resident entries"),
                }
            };
            self.entries.insert(id, WorkerEntry::Disk { bytes: spill_bytes });
            self.resident -= spill_bytes;
            self.spilled += 1;
        }
        Ok(())
    }

    fn get(&mut self, id: u32) -> Result<Arc<Block>> {
        enum Kind {
            Missing,
            Mem,
            Disk(u64),
        }
        let kind = match self.entries.get(&id) {
            None => Kind::Missing,
            Some(WorkerEntry::Mem { .. }) => Kind::Mem,
            Some(WorkerEntry::Disk { bytes }) => Kind::Disk(*bytes),
        };
        match kind {
            Kind::Missing => bail!("block {id} not found on this worker"),
            Kind::Mem => {
                self.clock += 1;
                let clock = self.clock;
                let Some(WorkerEntry::Mem { block, last_use, .. }) =
                    self.entries.get_mut(&id)
                else {
                    unreachable!()
                };
                *last_use = clock;
                Ok(Arc::clone(block))
            }
            Kind::Disk(bytes) => {
                let block = {
                    let store = self.store.as_ref().expect("disk entry implies store");
                    let b = store.fault(id)?;
                    store.remove(id);
                    Arc::new(b)
                };
                self.clock += 1;
                self.entries.insert(
                    id,
                    WorkerEntry::Mem {
                        block: Arc::clone(&block),
                        bytes,
                        last_use: self.clock,
                    },
                );
                self.resident += bytes;
                self.enforce_budget()?;
                Ok(block)
            }
        }
    }

    fn remove(&mut self, id: u32) {
        match self.entries.remove(&id) {
            Some(WorkerEntry::Mem { bytes, .. }) => self.resident -= bytes,
            Some(WorkerEntry::Disk { .. }) => {
                if let Some(store) = &self.store {
                    store.remove(id);
                }
            }
            None => {}
        }
    }

    fn stat(&self) -> WorkerStat {
        WorkerStat {
            blocks: self.entries.len() as u64,
            resident_bytes: self.resident,
            blocks_spilled: self.spilled,
            pulled_bytes: self.pulled_bytes,
        }
    }
}

/// How a peer pull failed: the peer being unreachable is a different fact
/// (that worker is dead) than the peer answering with an error (this
/// conversation is broken).
enum PullError {
    PeerDown(String),
    Failed(String),
}

/// Fetch one block from a peer worker (the `Pull` data path).
fn pull_from_peer(addr: &str, id: u32) -> Result<(Block, u64), PullError> {
    let mut s = TcpStream::connect(addr)
        .map_err(|e| PullError::PeerDown(format!("connecting to peer {addr}: {e}")))?;
    s.set_nodelay(true).ok();
    wire::write_request(&mut s, &Request::Get { id })
        .map_err(|e| PullError::PeerDown(format!("peer {addr}: {e:#}")))?;
    let (resp, bytes) = wire::read_response(&mut s)
        .map_err(|e| PullError::PeerDown(format!("peer {addr}: {e:#}")))?;
    match resp {
        Response::Block(b) => Ok((b, bytes)),
        Response::Err(m) => Err(PullError::Failed(format!("peer {addr}: {m}"))),
        other => Err(PullError::Failed(format!(
            "peer {addr}: unexpected response {other:?} to Get"
        ))),
    }
}

/// Crash this worker: the injected-`Die` / [`Request::Crash`] path. Real
/// daemons exit the process SIGKILL-style (no response goes out, the spill
/// directory is dropped first since `process::exit` skips destructors);
/// in-process workers raise the shared dead flag and clear their blocks,
/// which silences every connection equivalently.
fn crash_worker(shared: &WorkerShared) {
    if shared.crash_exits {
        shared.blocks.lock().unwrap().store.take();
        std::process::exit(137);
    }
    shared.dead.store(true, Ordering::SeqCst);
    let mut blocks = shared.blocks.lock().unwrap();
    blocks.entries.clear();
    blocks.resident = 0;
    blocks.store.take();
}

fn worker_conn_loop(shared: Arc<WorkerShared>, mut stream: TcpStream) {
    loop {
        let req = match wire::read_request(&mut stream) {
            Ok(r) => r,
            Err(_) => return, // connection closed
        };
        // A crashed in-process worker answers nothing, ever.
        if shared.dead.load(Ordering::SeqCst) {
            return;
        }
        // The single fault-injection point: after decode, before handling,
        // so the served-request counter is exact for every request kind.
        match shared.faults.as_ref().and_then(|f| f.on_request()) {
            Some(FaultKind::Die) => {
                crash_worker(&shared);
                return;
            }
            Some(FaultKind::DropConn) => {
                // Cut the conversation mid-frame: a length header with no
                // payload, then close. The worker stays alive.
                let _ = stream.write_all(&1024u32.to_le_bytes());
                return;
            }
            Some(FaultKind::Slow) => {
                // Straggle: stall, then answer correctly. Nothing errors —
                // only a heartbeat probe (which also stalls here and times
                // out) or the straggler monitor can tell.
                std::thread::sleep(Duration::from_millis(super::faults::SLOW_STALL_MS));
            }
            None => {}
        }
        let mut exit = false;
        let resp = match req {
            Request::Ping => Response::Ok,
            Request::Put { id, block } => {
                match shared.blocks.lock().unwrap().insert(id, block) {
                    Ok(()) => Response::Ok,
                    Err(e) => Response::Err(format!("storing block {id}: {e:#}")),
                }
            }
            Request::Get { id } => {
                // Bind first so the state lock drops before the payload
                // clone — copying a multi-MB block must not stall every
                // other connection thread.
                let got = shared.blocks.lock().unwrap().get(id);
                match got {
                    Ok(b) => Response::Block((*b).clone()),
                    Err(e) => Response::Err(format!("{e:#}")),
                }
            }
            Request::Free { ids } => {
                let mut st = shared.blocks.lock().unwrap();
                for id in ids {
                    st.remove(id);
                }
                Response::Ok
            }
            Request::Pull { id, from } => match pull_from_peer(&from, id) {
                Ok((block, bytes)) => {
                    let mut st = shared.blocks.lock().unwrap();
                    st.pulled_bytes += bytes;
                    match st.insert(id, block) {
                        Ok(()) => Response::Pulled { bytes },
                        Err(e) => Response::Err(format!("storing pulled block {id}: {e:#}")),
                    }
                }
                // The peer is gone, *we* are fine: tell the coordinator
                // which of us to bury.
                Err(PullError::PeerDown(m)) => {
                    Response::PullPeerDown(format!("pull of block {id} failed: {m}"))
                }
                Err(PullError::Failed(m)) => {
                    Response::Err(format!("pull of block {id} from {from} failed: {m}"))
                }
            },
            Request::Stat => Response::Stat(shared.blocks.lock().unwrap().stat()),
            Request::Shutdown => {
                exit = true;
                Response::Ok
            }
            Request::Crash => {
                crash_worker(&shared);
                return;
            }
            // Membership frames flow worker → coordinator (its control
            // listener), never to a block daemon.
            req @ (Request::Join { .. } | Request::Drain { .. }) => Response::Err(format!(
                "{req:?} is a coordinator control request, not a worker request"
            )),
        };
        if wire::write_response(&mut stream, &resp).is_err() {
            return;
        }
        if exit {
            // Drop the spill store (removing its directory) explicitly:
            // `process::exit` skips destructors.
            shared.blocks.lock().unwrap().store.take();
            std::process::exit(0);
        }
    }
}

/// Ask the coordinator whose control listener is at `coordinator` to enroll
/// the worker listening at `worker_addr` — what `dsarray worker --join`
/// calls right after binding. The coordinator connects back and pings the
/// worker before answering, so the worker must already be accepting.
pub fn request_join(coordinator: &str, worker_addr: &str) -> Result<()> {
    control_request(
        coordinator,
        &Request::Join {
            addr: worker_addr.to_string(),
        },
    )
}

/// Ask the coordinator at `coordinator` to gracefully drain the member
/// worker at `worker_addr` (migrate its sole copies, then decommission it).
pub fn request_drain(coordinator: &str, worker_addr: &str) -> Result<()> {
    control_request(
        coordinator,
        &Request::Drain {
            addr: worker_addr.to_string(),
        },
    )
}

fn control_request(coordinator: &str, req: &Request) -> Result<()> {
    let mut s = TcpStream::connect(coordinator)
        .with_context(|| format!("connecting to coordinator {coordinator}"))?;
    s.set_nodelay(true).ok();
    wire::write_request(&mut s, req)?;
    match wire::read_response(&mut s)?.0 {
        Response::Ok => Ok(()),
        Response::Err(m) => bail!("coordinator {coordinator}: {m}"),
        other => bail!("coordinator {coordinator}: unexpected response {other:?}"),
    }
}

/// The worker daemon loop behind `dsarray worker --listen <addr>`: accept
/// coordinator and peer connections forever, one thread per connection.
/// A `Shutdown` request cleans up the spill directory and exits the
/// process, so call this only from a dedicated worker process (or from an
/// in-process test thread that never sends `Shutdown`). In-process workers
/// keep `crash_exits` false so [`Request::Crash`] and injected faults
/// silence the worker without taking the host process down.
pub fn serve_worker(listener: TcpListener, opts: WorkerOptions) -> Result<()> {
    let store = match opts.memory_budget_bytes {
        Some(_) => Some(BlockStore::in_temp()?),
        None => None,
    };
    let faults = match opts.fault_spec.as_deref() {
        Some(spec) if !spec.is_empty() => {
            Some(FaultState::from_spec(spec).context("parsing --fault-plan")?)
        }
        _ => None,
    };
    let shared = Arc::new(WorkerShared {
        blocks: Mutex::new(WorkerBlocks {
            entries: HashMap::new(),
            resident: 0,
            clock: 0,
            budget: opts.memory_budget_bytes,
            store,
            spilled: 0,
            pulled_bytes: 0,
        }),
        faults,
        dead: AtomicBool::new(false),
        crash_exits: opts.crash_exits,
    });
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        if shared.dead.load(Ordering::SeqCst) {
            // Crashed in-process worker: refuse everything, like a closed
            // port. Dropping the stream resets the coordinator's connect.
            continue;
        }
        stream.set_nodelay(true).ok();
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || worker_conn_loop(shared, stream));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{BlockMeta, DenseMatrix};
    use crate::tasking::task::CostHint;
    use crate::tasking::Runtime;

    /// Start an in-process worker (same wire protocol, same daemon loop,
    /// just not a separate OS process) and return its address.
    fn inproc_worker(budget: Option<u64>) -> String {
        inproc_worker_with(WorkerOptions {
            memory_budget_bytes: budget,
            ..Default::default()
        })
    }

    fn inproc_worker_with(opts: WorkerOptions) -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = serve_worker(l, opts);
        });
        addr
    }

    /// Crash an in-process worker over the wire; the EOF on the (absent)
    /// response confirms the dead flag is up before we return.
    fn crash_worker_at(addr: &str) {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_request(&mut s, &Request::Crash).unwrap();
        let _ = wire::read_response(&mut s);
    }

    fn cluster_rt(addrs: Vec<String>) -> Runtime {
        Runtime::cluster(ClusterOptions {
            addrs,
            ..Default::default()
        })
        .unwrap()
    }

    fn stat_of(addr: &str) -> WorkerStat {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_request(&mut s, &Request::Stat).unwrap();
        match wire::read_response(&mut s).unwrap().0 {
            Response::Stat(st) => st,
            other => panic!("got {other:?}"),
        }
    }

    fn dense(v: f32) -> Block {
        Block::Dense(DenseMatrix::full(2, 2, v))
    }

    #[test]
    fn placement_prefers_most_input_bytes() {
        let all2 = full_mask(2);
        let idle = vec![0u64; 4];
        // Worker 1 holds 3x the bytes: it wins.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 300)], 2, all2, &idle),
            Some(1)
        );
        // Ties break toward the lowest index.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 100)], 2, all2, &idle),
            Some(0)
        );
        // A replicated block counts for every holder.
        assert_eq!(
            choose_placement(&[(0b11, 100), (0b10, 1)], 2, all2, &idle),
            Some(1),
            "worker 1 holds 101 bytes vs worker 0's 100"
        );
        // No located inputs: the caller round-robins.
        assert_eq!(choose_placement(&[], 4, full_mask(4), &idle), None);
        assert_eq!(choose_placement(&[(0, 100)], 4, full_mask(4), &idle), None);
        // A dead worker never wins, no matter how much it used to hold.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 300)], 2, 0b01, &idle),
            Some(0)
        );
        // All holders dead: fall back to round-robin over survivors.
        assert_eq!(choose_placement(&[(0b10, 300)], 2, 0b01, &idle), None);
    }

    #[test]
    fn placement_breaks_byte_ties_toward_the_least_loaded_worker() {
        let all2 = full_mask(2);
        // Equal input bytes, but worker 0 has a queue of outstanding work:
        // the tie now goes to idle worker 1 instead of the lowest index.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 100)], 2, all2, &[5000, 0]),
            Some(1)
        );
        // Locality still dominates load: a worker holding more input bytes
        // wins even when it is busier.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 300)], 2, all2, &[0, 9000]),
            Some(1)
        );
        // A load vector shorter than the fleet reads as zero load.
        assert_eq!(
            choose_placement(&[(0b01, 100), (0b10, 100)], 2, all2, &[7]),
            Some(1)
        );
    }

    #[test]
    fn replicas_spread_to_least_loaded_eligible_workers() {
        // Placement 0, want 3 copies out of 4 workers: the two least-loaded
        // *other* workers are picked (worker 2 then worker 3, not busy 1).
        assert_eq!(
            choose_replicas(0, 3, 4, full_mask(4), &[0, 900, 10, 20]),
            vec![2, 3]
        );
        // Ineligible (draining/dead) workers never receive replicas.
        assert_eq!(
            choose_replicas(0, 3, 4, 0b1011, &[0, 900, 10, 20]),
            vec![3, 1]
        );
        // k = 1 means no extra copies.
        assert!(choose_replicas(0, 1, 4, full_mask(4), &[0; 4]).is_empty());
        // Not enough eligible peers: return as many as exist.
        assert_eq!(choose_replicas(0, 3, 2, 0b11, &[0, 0]), vec![1]);
    }

    #[test]
    fn put_wait_round_trip_and_remote_free() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        let a = rt.put_block(dense(1.5));
        let b = rt.put_block(dense(2.5));
        // Round-robin distribution: one block per worker.
        assert_eq!(stat_of(&addrs[0]).blocks, 1);
        assert_eq!(stat_of(&addrs[1]).blocks, 1);
        assert_eq!(rt.wait(a).unwrap().as_dense().unwrap().get(0, 0), 1.5);
        assert_eq!(rt.wait(b).unwrap().as_dense().unwrap().get(0, 0), 2.5);
        assert!(rt.metrics().bytes_on_wire > 0);
        // Refcount death reaches across the wire: the worker's copy is
        // freed and the block is gone for later waits.
        rt.retain(&[a]);
        rt.release(&[a]);
        assert!(rt.wait(a).is_err());
        assert_eq!(stat_of(&addrs[0]).blocks + stat_of(&addrs[1]).blocks, 1);
        assert_eq!(rt.metrics().blocks_evicted, 1);
    }

    #[test]
    fn chain_executes_remotely_with_full_locality_on_one_worker() {
        let addrs = vec![inproc_worker(None)];
        let rt = cluster_rt(addrs);
        let mut cur = rt.put_block(dense(0.0));
        for _ in 0..8 {
            cur = rt.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                Arc::new(|ins: &[Arc<Block>]| {
                    let m = ins[0].as_dense()?;
                    Ok(vec![Block::Dense(m.map(|x| x + 1.0))])
                }),
            )[0];
        }
        assert_eq!(rt.wait(cur).unwrap().as_dense().unwrap().get(0, 0), 8.0);
        let m = rt.metrics();
        assert_eq!(m.total_tasks(), 8);
        // Single worker: every input is already at its placement.
        assert_eq!(m.locality_hits, 8);
        assert_eq!(m.remote_transfers, 0);
        assert!(m.bytes_on_wire > 0);
    }

    #[test]
    fn cross_worker_input_is_pulled_and_counted() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        // Round-robin: `a` lands on worker 0, `b` on worker 1.
        let a = rt.put_block(dense(1.0));
        let b = rt.put_block(dense(10.0));
        let sum = rt.submit(
            "sum2",
            &[a, b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| {
                let mut acc = ins[0].as_dense()?.clone();
                acc.axpy(1.0, ins[1].as_dense()?)?;
                Ok(vec![Block::Dense(acc)])
            }),
        );
        assert_eq!(rt.wait(sum[0]).unwrap().as_dense().unwrap().get(0, 0), 11.0);
        let m = rt.metrics();
        // Equal input bytes: placement ties to worker 0, so `a` is a hit
        // and `b` is pulled worker-to-worker.
        assert_eq!(m.locality_hits, 1);
        assert_eq!(m.remote_transfers, 1);
        // The pull left a replica of `b` on worker 0 and the output landed
        // there too: worker 0 now holds a, b, sum.
        assert_eq!(stat_of(&addrs[0]).blocks, 3);
        assert_eq!(stat_of(&addrs[1]).blocks, 1);
        assert!(stat_of(&addrs[0]).pulled_bytes > 0);
    }

    #[test]
    fn relay_mode_moves_bytes_without_replication() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions {
                addrs: addrs.clone(),
                threads: 1,
                transfer: TransferMode::Relay,
                ..Default::default()
            },
        )
        .unwrap();
        let a = rt.put_block(dense(2.0));
        let b = rt.put_block(dense(3.0));
        let out = rt.submit(
            "mul2",
            &[a, b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| {
                let x = ins[0].as_dense()?.get(0, 0) * ins[1].as_dense()?.get(0, 0);
                Ok(vec![Block::Dense(DenseMatrix::full(2, 2, x))])
            }),
        );
        assert_eq!(rt.wait(out[0]).unwrap().as_dense().unwrap().get(0, 0), 6.0);
        let m = rt.metrics();
        assert_eq!(m.remote_transfers, 1);
        // No worker-to-worker replication in relay mode: worker 1 still
        // holds only `b`, and nothing was pulled.
        assert_eq!(stat_of(&addrs[1]).blocks, 1);
        assert_eq!(stat_of(&addrs[0]).pulled_bytes, 0);
        assert_eq!(stat_of(&addrs[1]).pulled_bytes, 0);
    }

    #[test]
    fn worker_budget_spills_and_faults_transparently() {
        // One worker, budget of one 16 B block; four blocks stored.
        let addr = inproc_worker(Some(16));
        let rt = cluster_rt(vec![addr.clone()]);
        let ids: Vec<_> = (0..4).map(|i| rt.put_block(dense(i as f32))).collect();
        let st = stat_of(&addr);
        assert_eq!(st.blocks, 4);
        assert!(st.blocks_spilled >= 3, "spilled {}", st.blocks_spilled);
        assert!(st.resident_bytes <= 16);
        // Every value still synchronizes — spilled ones fault on the worker.
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(rt.wait(id).unwrap().as_dense().unwrap().get(0, 0), i as f32);
        }
    }

    #[test]
    fn closure_error_poisons_with_task_name() {
        let rt = cluster_rt(vec![inproc_worker(None)]);
        let src = rt.put_block(dense(0.0));
        let bad = rt.submit(
            "explode",
            &[src],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|_: &[Arc<Block>]| anyhow::bail!("boom")),
        );
        let err = rt.wait(bad[0]).unwrap_err().to_string();
        assert!(err.contains("task `explode`"), "err: {err}");
        assert!(rt.barrier().is_err());
    }

    #[test]
    fn missing_worker_block_poisons_not_hangs() {
        // Free a block behind the coordinator's back, then read it through
        // a task: the failure must name the worker and poison the runtime.
        let addr = inproc_worker(None);
        let rt = cluster_rt(vec![addr.clone()]);
        let src = rt.put_block(dense(4.0));
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s, &Request::Free { ids: vec![src.id] }).unwrap();
        wire::read_response(&mut s).unwrap();
        let out = rt.submit(
            "read_gone",
            &[src],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| Ok(vec![(*ins[0]).clone()])),
        );
        let err = rt.wait(out[0]).unwrap_err().to_string();
        assert!(err.contains("task `read_gone`"), "err: {err}");
        assert!(err.contains(&addr), "err should name worker {addr}: {err}");
    }

    fn inc_body() -> Arc<dyn Fn(&[Arc<Block>]) -> Result<Vec<Block>> + Send + Sync> {
        Arc::new(|ins: &[Arc<Block>]| {
            let m = ins[0].as_dense()?;
            Ok(vec![Block::Dense(m.map(|x| x + 1.0))])
        })
    }

    #[test]
    fn worker_death_replays_lineage_bit_identically() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        // Root on worker 0 (round-robin), chain placed there by locality.
        let a = rt.put_block(dense(1.0));
        let mut cur = a;
        for _ in 0..3 {
            cur = rt.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                inc_body(),
            )[0];
        }
        rt.barrier().unwrap();
        // Kill the worker holding the whole chain, then synchronize: the
        // wait must route through recovery and return the exact value.
        crash_worker_at(&addrs[0]);
        let v = rt.wait(cur).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 4.0);
        let m = rt.metrics();
        assert_eq!(m.workers_lost, 1);
        assert!(m.tasks_replayed >= 3, "replayed {}", m.tasks_replayed);
        assert!(m.blocks_recovered >= 1, "recovered {}", m.blocks_recovered);
        assert!(m.recovery_ms >= 1);
        // The runtime is NOT poisoned: new work still runs on survivors.
        let more = rt.submit(
            "inc",
            &[cur],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            inc_body(),
        );
        assert_eq!(rt.wait(more[0]).unwrap().as_dense().unwrap().get(0, 0), 5.0);
    }

    #[test]
    fn replicated_blocks_survive_death_without_replay() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions {
                addrs: addrs.clone(),
                replicate: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let a = rt.put_block(dense(7.0));
        let out = rt.submit(
            "inc",
            &[a],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            inc_body(),
        )[0];
        rt.barrier().unwrap();
        crash_worker_at(&addrs[0]);
        // Every block has a copy on the survivor: recovery is a location
        // table fixup, no task re-runs.
        assert_eq!(rt.wait(out).unwrap().as_dense().unwrap().get(0, 0), 8.0);
        let m = rt.metrics();
        assert_eq!(m.workers_lost, 1);
        assert_eq!(m.tasks_replayed, 0);
        assert_eq!(m.blocks_recovered, 0);
    }

    #[test]
    fn disabled_recovery_poisons_with_worker_address() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions {
                addrs: addrs.clone(),
                recovery: false,
                ..Default::default()
            },
        )
        .unwrap();
        let a = rt.put_block(dense(3.0));
        rt.barrier().unwrap();
        crash_worker_at(&addrs[0]);
        let err = rt.wait(a).unwrap_err().to_string();
        assert!(err.contains(&addrs[0]), "err should name {}: {err}", addrs[0]);
        assert!(err.contains("recovery is disabled"), "err: {err}");
        assert!(rt.barrier().is_err(), "runtime must be poisoned");
    }

    #[test]
    fn injected_die_fault_silences_worker_at_scheduled_request() {
        let addr = inproc_worker_with(WorkerOptions {
            fault_spec: Some("die@2".into()),
            ..Default::default()
        });
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(matches!(wire::read_response(&mut s).unwrap().0, Response::Ok));
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(
            wire::read_response(&mut s).is_err(),
            "request 2 must hit die@2 and get silence"
        );
        // The worker stays dead for later conversations too.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        let _ = wire::write_request(&mut s2, &Request::Ping);
        assert!(wire::read_response(&mut s2).is_err());
    }

    #[test]
    fn injected_conn_drop_cuts_one_conversation_but_worker_survives() {
        let addr = inproc_worker_with(WorkerOptions {
            fault_spec: Some("drop@1".into()),
            ..Default::default()
        });
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(
            wire::read_response(&mut s).is_err(),
            "request 1 must get a truncated frame"
        );
        // A fresh conversation with the same worker succeeds.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        wire::write_request(&mut s2, &Request::Ping).unwrap();
        assert!(matches!(
            wire::read_response(&mut s2).unwrap().0,
            Response::Ok
        ));
    }

    #[test]
    fn joined_worker_receives_tasks_and_rebalances_new_work() {
        let rt = cluster_rt(vec![inproc_worker(None)]);
        let joined = inproc_worker(None);
        assert_eq!(rt.cluster_join(&joined).unwrap(), 1);
        // New puts round-robin across both members, and tasks over blocks
        // that landed on the joined worker place there by locality.
        let blocks: Vec<_> = (0..4).map(|i| rt.put_block(dense(i as f32))).collect();
        let outs: Vec<_> = blocks
            .iter()
            .map(|&b| {
                rt.submit(
                    "inc",
                    &[b],
                    vec![BlockMeta::dense(2, 2)],
                    CostHint::default(),
                    inc_body(),
                )[0]
            })
            .collect();
        for (i, &o) in outs.iter().enumerate() {
            assert_eq!(
                rt.wait(o).unwrap().as_dense().unwrap().get(0, 0),
                i as f32 + 1.0
            );
        }
        let m = rt.metrics();
        assert_eq!(m.workers_joined, 1);
        assert!(
            m.tasks_by_worker.get(1).copied().unwrap_or(0) > 0,
            "joined worker ran no tasks: {:?}",
            m.tasks_by_worker
        );
        assert!(stat_of(&joined).blocks > 0, "joined worker holds no blocks");
        // The same address cannot enroll twice.
        assert!(rt.cluster_join(&joined).is_err());
    }

    #[test]
    fn drain_migrates_sole_copies_with_zero_replay() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = cluster_rt(addrs.clone());
        let blocks: Vec<_> = (0..4).map(|i| rt.put_block(dense(i as f32))).collect();
        rt.barrier().unwrap();
        rt.cluster_drain(0).unwrap();
        // Every value survives, served by the survivor, with no replay.
        for (i, &b) in blocks.iter().enumerate() {
            assert_eq!(rt.wait(b).unwrap().as_dense().unwrap().get(0, 0), i as f32);
        }
        let m = rt.metrics();
        assert_eq!(m.workers_drained, 1);
        assert_eq!(m.tasks_replayed, 0, "a drain must not replay lineage");
        assert_eq!(m.workers_lost, 0, "a drain is not a death");
        // Worker 0's two sole copies were pulled over: the survivor now
        // holds all four blocks.
        assert_eq!(stat_of(&addrs[1]).blocks, 4);
        // New work runs on the survivor alone.
        let out = rt.submit(
            "inc",
            &[blocks[0]],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            inc_body(),
        )[0];
        assert_eq!(rt.wait(out).unwrap().as_dense().unwrap().get(0, 0), 1.0);
        assert_eq!(
            rt.metrics().tasks_by_worker.first().copied().unwrap_or(0),
            0,
            "drained worker must never be scheduled again"
        );
        // The last eligible member cannot be drained.
        assert!(rt.cluster_drain(1).is_err());
        // And a departed slot cannot be drained twice.
        assert!(rt.cluster_drain(0).is_err());
    }

    #[test]
    fn heartbeat_declares_a_silent_worker_dead() {
        let addrs = vec![inproc_worker(None), inproc_worker(None)];
        let rt = Runtime::cluster(
            ClusterOptions {
                addrs: addrs.clone(),
                heartbeat_ms: 20,
                ..Default::default()
            },
        )
        .unwrap();
        let a = rt.put_block(dense(5.0)); // round-robin: lands on worker 0
        rt.barrier().unwrap();
        // Crash worker 1 without telling anyone. No request will ever
        // touch it again, so only the heartbeat can notice.
        crash_worker_at(&addrs[1]);
        let deadline = Instant::now() + Duration::from_secs(10);
        while rt.metrics().workers_lost == 0 {
            assert!(
                Instant::now() < deadline,
                "heartbeat never declared the silent worker dead"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        // The fleet keeps working on the survivor.
        assert_eq!(rt.wait(a).unwrap().as_dense().unwrap().get(0, 0), 5.0);
    }

    #[test]
    fn straggler_speculation_keeps_results_bit_identical() {
        let fast = inproc_worker(None);
        let slow = inproc_worker_with(WorkerOptions {
            fault_spec: Some("slow@3".into()),
            ..Default::default()
        });
        let rt = Runtime::cluster(
            ClusterOptions {
                addrs: vec![fast, slow],
                straggler_factor: 3.0,
                ..Default::default()
            },
        )
        .unwrap();
        // Establish a fast EWMA for `inc` on the healthy worker.
        let a = rt.put_block(dense(0.0)); // -> worker 0
        let mut cur = a;
        for _ in 0..3 {
            cur = rt.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                inc_body(),
            )[0];
        }
        rt.barrier().unwrap();
        // `b` lands on the straggler (round-robin), whose served-request
        // counter then sits at 2 (boot ping + put): the very next fetch
        // stalls for `SLOW_STALL_MS`, far past 3x the EWMA estimate.
        let b = rt.put_block(dense(10.0)); // -> worker 1
        let out = rt.submit(
            "inc",
            &[b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            inc_body(),
        )[0];
        // The monitor re-arms the task away from the straggler; whichever
        // copy publishes first, single-assignment keeps the value exact.
        assert_eq!(rt.wait(out).unwrap().as_dense().unwrap().get(0, 0), 11.0);
        let m = rt.metrics();
        assert!(m.tasks_speculated >= 1, "no task was speculated");
        assert!(
            rt.barrier().is_ok(),
            "a losing twin must never poison the runtime"
        );
    }

    #[test]
    fn control_listener_serves_join_and_drain_frames() {
        let rt = cluster_rt(vec![inproc_worker(None), inproc_worker(None)]);
        let control = rt
            .cluster_control_addr()
            .expect("cluster runtimes expose a control listener");
        let mut s = TcpStream::connect(&control).unwrap();
        wire::write_request(&mut s, &Request::Ping).unwrap();
        assert!(matches!(wire::read_response(&mut s).unwrap().0, Response::Ok));
        // A fresh worker joins over the wire, exactly like
        // `dsarray worker --join` does.
        let joined = inproc_worker(None);
        wire::write_request(
            &mut s,
            &Request::Join {
                addr: joined.clone(),
            },
        )
        .unwrap();
        assert!(matches!(wire::read_response(&mut s).unwrap().0, Response::Ok));
        assert_eq!(rt.metrics().workers_joined, 1);
        // And a drain frame decommissions it again, with zero replay.
        wire::write_request(
            &mut s,
            &Request::Drain {
                addr: joined.clone(),
            },
        )
        .unwrap();
        assert!(matches!(wire::read_response(&mut s).unwrap().0, Response::Ok));
        let m = rt.metrics();
        assert_eq!(m.workers_drained, 1);
        assert_eq!(m.tasks_replayed, 0);
        // Unknown addresses are refused, not fatal.
        wire::write_request(
            &mut s,
            &Request::Drain {
                addr: "127.0.0.1:1".into(),
            },
        )
        .unwrap();
        match wire::read_response(&mut s).unwrap().0 {
            Response::Err(m) => assert!(m.contains("no such worker"), "{m}"),
            other => panic!("got {other:?}"),
        }
        // Data frames on the control socket are rejected cleanly.
        wire::write_request(&mut s, &Request::Stat).unwrap();
        assert!(matches!(
            wire::read_response(&mut s).unwrap().0,
            Response::Err(_)
        ));
    }
}
