//! Dependency-graph bookkeeping shared by the local executor and the
//! discrete-event simulator.
//!
//! The master inserts every submitted task into this graph and tracks
//! readiness (paper §3.1.2): a task becomes dependency-free when all of its
//! read ids are produced. Because ids are single-assignment (SSA ≡ PyCOMPSs
//! data renaming), the only dependency kind is reader-after-writer.

use std::sync::Arc;

use crate::storage::{Block, BlockMeta};

use super::task::{DataId, DataState, TaskId, TaskSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting on `deps_remaining` producers.
    Pending,
    /// Dependency-free, queued for dispatch.
    Ready,
    Running,
    Done,
    Failed,
}

pub struct TaskNode {
    pub spec: TaskSpec,
    pub state: TaskState,
    pub deps_remaining: u32,
    /// Tasks to notify on completion. May contain duplicates when a
    /// dependent reads several of our outputs — each entry balances one
    /// increment of that dependent's `deps_remaining`.
    pub dependents: Vec<TaskId>,
}

#[derive(Default)]
pub struct Graph {
    pub tasks: Vec<TaskNode>,
    pub data: Vec<DataState>,
}

impl Graph {
    /// Register a block that exists from the start (no producing task).
    pub fn put_block(&mut self, meta: BlockMeta, value: Option<Arc<Block>>) -> DataId {
        let id = self.data.len() as DataId;
        self.data.push(DataState {
            meta,
            value,
            producer: None,
        });
        id
    }

    /// Insert a task; allocates its output ids, wires dependencies, and
    /// returns (task id, output ids, ready-now?).
    pub fn submit(
        &mut self,
        name: &'static str,
        reads: &[DataId],
        out_metas: Vec<BlockMeta>,
        hint: super::task::CostHint,
        read_bytes: f64,
        func: super::task::TaskFn,
    ) -> (TaskId, Vec<DataId>, bool) {
        let tid = self.tasks.len() as TaskId;
        let mut write_ids = Vec::with_capacity(out_metas.len());
        let mut write_bytes = 0.0;
        for meta in out_metas {
            write_bytes += meta.bytes() as f64;
            let id = self.data.len() as DataId;
            self.data.push(DataState {
                meta,
                value: None,
                producer: Some(tid),
            });
            write_ids.push(id);
        }

        let mut deps = 0u32;
        for &r in reads {
            let d = &self.data[r as usize];
            if d.value.is_some() {
                continue; // already materialized
            }
            match d.producer {
                Some(p) if self.tasks[p as usize].state != TaskState::Done => {
                    deps += 1;
                    self.tasks[p as usize].dependents.push(tid);
                }
                _ => {}
            }
        }

        let ready = deps == 0;
        self.tasks.push(TaskNode {
            spec: TaskSpec {
                name,
                reads: reads.to_vec().into_boxed_slice(),
                writes: write_ids.clone().into_boxed_slice(),
                hint,
                read_bytes,
                write_bytes,
                func,
            },
            state: if ready { TaskState::Ready } else { TaskState::Pending },
            deps_remaining: deps,
            dependents: Vec::new(),
        });
        (tid, write_ids, ready)
    }

    /// Mark a task done, store its outputs (if any — the simulator passes
    /// `None`s), and return the dependents that became ready.
    pub fn complete(&mut self, tid: TaskId, outputs: Option<Vec<Block>>) -> Vec<TaskId> {
        if let Some(outs) = outputs {
            let writes: Vec<DataId> = self.tasks[tid as usize].spec.writes.to_vec();
            debug_assert_eq!(outs.len(), writes.len(), "task output arity mismatch");
            for (id, block) in writes.into_iter().zip(outs) {
                self.data[id as usize].value = Some(Arc::new(block));
            }
        }
        self.tasks[tid as usize].state = TaskState::Done;
        let dependents = std::mem::take(&mut self.tasks[tid as usize].dependents);
        let mut now_ready = Vec::new();
        for dep in dependents {
            let node = &mut self.tasks[dep as usize];
            debug_assert!(node.deps_remaining > 0);
            node.deps_remaining -= 1;
            if node.deps_remaining == 0 && node.state == TaskState::Pending {
                node.state = TaskState::Ready;
                now_ready.push(dep);
            }
        }
        now_ready
    }

    /// Longest path through the graph in task count — a lower bound used by
    /// property tests (the simulated makespan can never beat the critical
    /// path). O(V + E); valid because task ids are topologically ordered by
    /// construction (a task can only depend on earlier submissions).
    pub fn critical_path_len(&self) -> usize {
        let mut depth = vec![0usize; self.tasks.len()];
        let mut best = 0;
        for (i, node) in self.tasks.iter().enumerate() {
            let d = node
                .spec
                .reads
                .iter()
                .filter_map(|&r| self.data[r as usize].producer)
                .map(|p| depth[p as usize] + 1)
                .max()
                .unwrap_or(1)
                .max(1);
            depth[i] = d;
            best = best.max(d);
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;
    use crate::tasking::task::CostHint;
    use std::sync::Arc;

    fn noop() -> super::super::task::TaskFn {
        Arc::new(|_| Ok(vec![]))
    }

    fn meta() -> BlockMeta {
        BlockMeta::dense(1, 1)
    }

    #[test]
    fn diamond_dependencies_resolve_in_order() {
        let mut g = Graph::default();
        let src = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        let (a, a_out, ready_a) = g.submit("a", &[src], vec![meta()], CostHint::default(), 0.0, noop());
        assert!(ready_a);
        let (b, b_out, ready_b) =
            g.submit("b", &[a_out[0]], vec![meta()], CostHint::default(), 0.0, noop());
        let (c, c_out, ready_c) =
            g.submit("c", &[a_out[0]], vec![meta()], CostHint::default(), 0.0, noop());
        assert!(!ready_b && !ready_c);
        let (d, _, ready_d) = g.submit(
            "d",
            &[b_out[0], c_out[0]],
            vec![meta()],
            CostHint::default(),
            0.0,
            noop(),
        );
        assert!(!ready_d);

        let ready = g.complete(a, None);
        assert_eq!(ready, vec![b, c]);
        assert!(g.complete(b, None).is_empty());
        assert_eq!(g.complete(c, None), vec![d]);
        assert_eq!(g.critical_path_len(), 3);
        let _ = d;
    }

    #[test]
    fn reading_materialized_data_needs_no_dep() {
        let mut g = Graph::default();
        let x = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        let (_, _, ready) = g.submit("t", &[x, x], vec![meta()], CostHint::default(), 0.0, noop());
        assert!(ready);
    }

    #[test]
    fn duplicate_reads_from_same_producer_balance() {
        let mut g = Graph::default();
        let (a, outs, _) = g.submit("a", &[], vec![meta(), meta()], CostHint::default(), 0.0, noop());
        let (b, _, ready) = g.submit(
            "b",
            &[outs[0], outs[1]],
            vec![meta()],
            CostHint::default(),
            0.0,
            noop(),
        );
        assert!(!ready);
        assert_eq!(g.tasks[b as usize].deps_remaining, 2);
        let ready = g.complete(a, None);
        assert_eq!(ready, vec![b]);
        assert_eq!(g.tasks[b as usize].deps_remaining, 0);
    }

    #[test]
    fn completion_stores_outputs() {
        let mut g = Graph::default();
        let (a, outs, _) = g.submit("a", &[], vec![meta()], CostHint::default(), 0.0, noop());
        g.complete(a, Some(vec![Block::Dense(DenseMatrix::full(1, 1, 7.0))]));
        let v = g.data[outs[0] as usize].value.as_ref().unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 7.0);
    }
}
