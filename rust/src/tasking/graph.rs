//! Dependency-graph bookkeeping shared by the local executor and the
//! discrete-event simulator.
//!
//! The master inserts every submitted task into this graph and tracks
//! readiness (paper §3.1.2): a task becomes dependency-free when all of its
//! read ids are produced. Because ids are single-assignment (SSA ≡ PyCOMPSs
//! data renaming), the only dependency kind is reader-after-writer.

use std::sync::Arc;

use crate::storage::{Block, BlockMeta};

use super::metrics::Metrics;
use super::task::{DataId, DataState, TaskBody, TaskId, TaskSpec, TaskSubmit};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting on `deps_remaining` producers.
    Pending,
    /// Dependency-free, queued for dispatch.
    Ready,
    Running,
    Done,
    Failed,
}

pub struct TaskNode {
    pub spec: TaskSpec,
    pub state: TaskState,
    pub deps_remaining: u32,
    /// Tasks to notify on completion. May contain duplicates when a
    /// dependent reads several of our outputs — each entry balances one
    /// increment of that dependent's `deps_remaining`.
    pub dependents: Vec<TaskId>,
}

#[derive(Default)]
pub struct Graph {
    pub tasks: Vec<TaskNode>,
    pub data: Vec<DataState>,
    /// Logical time for LRU spill ordering; bumped on every value touch.
    pub clock: u64,
    /// Ids whose spill files became garbage (the block died while a valid
    /// on-disk copy existed). The graph has no file-system access; the
    /// executor drains this queue and unlinks the files.
    pub dead_files: Vec<DataId>,
}

/// Outcome of completing one task: dependents that became ready, payload
/// bytes of each block reclaimed by refcount eviction at this completion
/// (0 for outputs dropped before they ever became resident — they count as
/// evicted blocks but must not reduce `resident_bytes`), and the bytes of
/// output values actually stored.
pub struct Completion {
    pub now_ready: Vec<TaskId>,
    pub evicted: Vec<usize>,
    pub stored_bytes: usize,
}

impl Graph {
    /// Register a block that exists from the start (no producing task).
    pub fn put_block(&mut self, meta: BlockMeta, value: Option<Arc<Block>>) -> DataId {
        let id = self.data.len() as DataId;
        self.data.push(DataState::new(meta, value, None));
        self.touch(id);
        id
    }

    /// Bump `id`'s LRU timestamp (value resolved, synchronized, or stored).
    pub fn touch(&mut self, id: DataId) {
        self.clock += 1;
        self.data[id as usize].last_use = self.clock;
    }

    /// Resident, unpinned, non-phantom blocks — what the memory-budget
    /// policy may spill — as `(last_use, id, payload bytes)` triples.
    /// The caller sorts by `last_use` and spills until under budget.
    pub fn spill_candidates(&self) -> Vec<(u64, DataId, usize)> {
        self.data
            .iter()
            .enumerate()
            .filter(|(_, d)| !d.pinned)
            .filter_map(|(id, d)| {
                let v = d.value.as_ref()?;
                if v.is_phantom() {
                    return None;
                }
                Some((d.last_use, id as DataId, v.meta().bytes()))
            })
            .collect()
    }

    /// Insert a task; allocates its output ids, wires dependencies, and
    /// returns (task id, output ids, ready-now?).
    pub fn submit(
        &mut self,
        name: &'static str,
        reads: &[DataId],
        out_metas: Vec<BlockMeta>,
        hint: super::task::CostHint,
        read_bytes: f64,
        body: TaskBody,
    ) -> (TaskId, Vec<DataId>, bool) {
        let tid = self.tasks.len() as TaskId;
        let mut write_ids = Vec::with_capacity(out_metas.len());
        let mut write_bytes = 0.0;
        for meta in out_metas {
            write_bytes += meta.bytes() as f64;
            let id = self.data.len() as DataId;
            self.data.push(DataState::new(meta, None, Some(tid)));
            write_ids.push(id);
        }

        let mut deps = 0u32;
        for &r in reads {
            // Every read occurrence keeps the input alive until completion
            // (balanced by the decrement in [`Graph::complete`]).
            self.data[r as usize].pending_reads += 1;
            let d = &self.data[r as usize];
            if d.value.is_some() {
                continue; // already materialized
            }
            match d.producer {
                Some(p) if self.tasks[p as usize].state != TaskState::Done => {
                    deps += 1;
                    self.tasks[p as usize].dependents.push(tid);
                }
                _ => {}
            }
        }

        let ready = deps == 0;
        self.tasks.push(TaskNode {
            spec: TaskSpec {
                name,
                reads: reads.to_vec().into_boxed_slice(),
                writes: write_ids.clone().into_boxed_slice(),
                hint,
                read_bytes,
                write_bytes,
                body,
            },
            state: if ready { TaskState::Ready } else { TaskState::Pending },
            deps_remaining: deps,
            dependents: Vec::new(),
        });
        (tid, write_ids, ready)
    }

    /// Insert one executor-facing submission record and account it in
    /// `metrics`. Shared by every executor so the real and simulated
    /// backends build — and count — identical graphs.
    pub fn submit_record(
        &mut self,
        t: TaskSubmit,
        metrics: &mut Metrics,
    ) -> (TaskId, Vec<DataId>, bool) {
        let n_reads = t.reads.len();
        let n_out = t.out_metas.len();
        let write_bytes: f64 = t.out_metas.iter().map(|m| m.bytes() as f64).sum();
        let (tid, outs, ready) =
            self.submit(t.name, &t.reads, t.out_metas, t.hint, t.read_bytes, t.body);
        metrics.record_submit(t.name, n_reads, n_out, t.read_bytes, write_bytes);
        metrics.record_fused(t.fused_ops);
        (tid, outs, ready)
    }

    /// Mark a task done, store its outputs (if any — the simulator passes
    /// `None`), decrement the reader counts of its inputs (reclaiming any
    /// that became fully consumed), and report the dependents that became
    /// ready.
    pub fn complete(&mut self, tid: TaskId, outputs: Option<Vec<Block>>) -> Completion {
        let mut evicted = Vec::new();
        let mut stored_bytes = 0usize;
        if let Some(outs) = outputs {
            let writes: Vec<DataId> = self.tasks[tid as usize].spec.writes.to_vec();
            debug_assert_eq!(outs.len(), writes.len(), "task output arity mismatch");
            for (id, block) in writes.into_iter().zip(outs) {
                let d = &mut self.data[id as usize];
                if d.ever_owned && d.handle_refs == 0 && d.pending_reads == 0 && !d.pinned {
                    // Every owner released the handle (and no reader was ever
                    // submitted) before the value materialized: drop it on
                    // the floor instead of storing garbage forever. Reported
                    // as 0 bytes — the value was never resident, so there is
                    // nothing to subtract from the residency accounting.
                    d.evicted = true;
                    evicted.push(0);
                } else {
                    stored_bytes += block.meta().bytes();
                    d.value = Some(Arc::new(block));
                    self.touch(id);
                }
            }
        }
        self.tasks[tid as usize].state = TaskState::Done;
        let dependents = std::mem::take(&mut self.tasks[tid as usize].dependents);
        let mut now_ready = Vec::new();
        for dep in dependents {
            let node = &mut self.tasks[dep as usize];
            debug_assert!(node.deps_remaining > 0);
            node.deps_remaining -= 1;
            if node.deps_remaining == 0 && node.state == TaskState::Pending {
                node.state = TaskState::Ready;
                now_ready.push(dep);
            }
        }
        // Balance the `pending_reads` increments from submission and
        // reclaim inputs this completion fully consumed.
        let reads: Vec<DataId> = self.tasks[tid as usize].spec.reads.to_vec();
        for r in reads {
            let d = &mut self.data[r as usize];
            d.pending_reads = d.pending_reads.saturating_sub(1);
            if let Some(bytes) = self.try_evict(r) {
                evicted.push(bytes);
            }
        }
        Completion {
            now_ready,
            evicted,
            stored_bytes,
        }
    }

    /// Add an application handle reference to `id`.
    pub fn retain(&mut self, id: DataId) {
        let d = &mut self.data[id as usize];
        d.handle_refs += 1;
        d.ever_owned = true;
    }

    /// Drop an application handle reference; returns the payload bytes when
    /// the release triggered reclamation.
    pub fn release(&mut self, id: DataId) -> Option<usize> {
        let d = &mut self.data[id as usize];
        d.handle_refs = d.handle_refs.saturating_sub(1);
        self.try_evict(id)
    }

    /// Evict `id`'s value if it is fully consumed: once owned by a handle,
    /// all handles released, no submitted reader outstanding, not pinned.
    /// Returns the reclaimed payload bytes. A block that dies while spilled
    /// reclaims 0 resident bytes but queues its file for unlinking; any
    /// stale clean on-disk copy is queued likewise.
    pub fn try_evict(&mut self, id: DataId) -> Option<usize> {
        let d = &mut self.data[id as usize];
        if d.pinned || !d.ever_owned || d.handle_refs > 0 || d.pending_reads > 0 {
            return None;
        }
        if let Some(v) = d.value.take() {
            d.evicted = true;
            if d.on_disk {
                d.on_disk = false;
                d.spilled = false;
                self.dead_files.push(id);
            }
            return Some(v.meta().bytes());
        }
        if d.spilled {
            // The value lives only on disk and the block just died: the
            // spill file is garbage now, not at store teardown.
            d.spilled = false;
            d.on_disk = false;
            d.evicted = true;
            self.dead_files.push(id);
            return Some(0);
        }
        None
    }

    /// Hand `id`'s value exclusively to its sole claiming reader, removing
    /// it from the data table. Eligibility is the [`Graph::try_evict`]
    /// condition with the claiming read itself still outstanding — i.e. the
    /// value would be reclaimed right after this read completes anyway, so
    /// granting it early lets the task reuse the buffer in place.
    pub fn take_exclusive(&mut self, id: DataId) -> Option<Arc<Block>> {
        let d = &mut self.data[id as usize];
        if d.pinned || !d.ever_owned || d.handle_refs > 0 || d.pending_reads != 1 {
            return None;
        }
        let v = d.value.take()?;
        d.evicted = true;
        if d.on_disk {
            // The grantee consumes the buffer; the clean disk copy is stale.
            d.on_disk = false;
            d.spilled = false;
            self.dead_files.push(id);
        }
        Some(v)
    }

    /// Longest path through the graph in task count — a lower bound used by
    /// property tests (the simulated makespan can never beat the critical
    /// path). O(V + E); valid because task ids are topologically ordered by
    /// construction (a task can only depend on earlier submissions).
    pub fn critical_path_len(&self) -> usize {
        let mut depth = vec![0usize; self.tasks.len()];
        let mut best = 0;
        for (i, node) in self.tasks.iter().enumerate() {
            let d = node
                .spec
                .reads
                .iter()
                .filter_map(|&r| self.data[r as usize].producer)
                .map(|p| depth[p as usize] + 1)
                .max()
                .unwrap_or(1)
                .max(1);
            depth[i] = d;
            best = best.max(d);
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;
    use crate::tasking::task::CostHint;
    use std::sync::Arc;

    fn noop() -> TaskBody {
        TaskBody::Shared(Arc::new(|_| Ok(vec![])))
    }

    fn meta() -> BlockMeta {
        BlockMeta::dense(1, 1)
    }

    #[test]
    fn diamond_dependencies_resolve_in_order() {
        let mut g = Graph::default();
        let src = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        let (a, a_out, ready_a) = g.submit("a", &[src], vec![meta()], CostHint::default(), 0.0, noop());
        assert!(ready_a);
        let (b, b_out, ready_b) =
            g.submit("b", &[a_out[0]], vec![meta()], CostHint::default(), 0.0, noop());
        let (c, c_out, ready_c) =
            g.submit("c", &[a_out[0]], vec![meta()], CostHint::default(), 0.0, noop());
        assert!(!ready_b && !ready_c);
        let (d, _, ready_d) = g.submit(
            "d",
            &[b_out[0], c_out[0]],
            vec![meta()],
            CostHint::default(),
            0.0,
            noop(),
        );
        assert!(!ready_d);

        let ready = g.complete(a, None).now_ready;
        assert_eq!(ready, vec![b, c]);
        assert!(g.complete(b, None).now_ready.is_empty());
        assert_eq!(g.complete(c, None).now_ready, vec![d]);
        assert_eq!(g.critical_path_len(), 3);
        let _ = d;
    }

    #[test]
    fn reading_materialized_data_needs_no_dep() {
        let mut g = Graph::default();
        let x = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        let (_, _, ready) = g.submit("t", &[x, x], vec![meta()], CostHint::default(), 0.0, noop());
        assert!(ready);
    }

    #[test]
    fn duplicate_reads_from_same_producer_balance() {
        let mut g = Graph::default();
        let (a, outs, _) = g.submit("a", &[], vec![meta(), meta()], CostHint::default(), 0.0, noop());
        let (b, _, ready) = g.submit(
            "b",
            &[outs[0], outs[1]],
            vec![meta()],
            CostHint::default(),
            0.0,
            noop(),
        );
        assert!(!ready);
        assert_eq!(g.tasks[b as usize].deps_remaining, 2);
        let ready = g.complete(a, None).now_ready;
        assert_eq!(ready, vec![b]);
        assert_eq!(g.tasks[b as usize].deps_remaining, 0);
    }

    #[test]
    fn completion_stores_outputs() {
        let mut g = Graph::default();
        let (a, outs, _) = g.submit("a", &[], vec![meta()], CostHint::default(), 0.0, noop());
        let c = g.complete(a, Some(vec![Block::Dense(DenseMatrix::full(1, 1, 7.0))]));
        assert_eq!(c.stored_bytes, 4);
        assert!(c.evicted.is_empty());
        let v = g.data[outs[0] as usize].value.as_ref().unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 7.0);
    }

    #[test]
    fn refcount_eviction_on_last_consumer() {
        let mut g = Graph::default();
        let src = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        g.retain(src); // an application handle owns the source
        let (t, _, ready) = g.submit("t", &[src], vec![meta()], CostHint::default(), 4.0, noop());
        assert!(ready);
        // Released handle + outstanding reader: kept until completion.
        assert_eq!(g.release(src), None);
        let c = g.complete(t, Some(vec![Block::Dense(DenseMatrix::zeros(1, 1))]));
        assert_eq!(c.evicted, vec![4]);
        assert!(g.data[src as usize].value.is_none());
        assert!(g.data[src as usize].evicted);
    }

    #[test]
    fn take_exclusive_mirrors_eviction_rules() {
        let mut g = Graph::default();
        let src = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        g.retain(src);
        let _ = g.submit("t", &[src], vec![meta()], CostHint::default(), 4.0, noop());
        // Handle still held: no grant.
        assert!(g.take_exclusive(src).is_none());
        g.release(src);
        // Sole reader, no handles: granted, and the table slot is evicted.
        let v = g.take_exclusive(src).unwrap();
        assert_eq!(v.meta(), meta());
        assert!(g.data[src as usize].value.is_none());
        assert!(g.data[src as usize].evicted);
        // Two outstanding readers: never granted.
        let two = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        g.retain(two);
        let _ = g.submit("r1", &[two], vec![meta()], CostHint::default(), 4.0, noop());
        let _ = g.submit("r2", &[two], vec![meta()], CostHint::default(), 4.0, noop());
        g.release(two);
        assert!(g.take_exclusive(two).is_none());
    }

    #[test]
    fn unowned_and_pinned_blocks_are_never_evicted() {
        let mut g = Graph::default();
        let bare = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        let (t, _, _) = g.submit("t", &[bare], vec![meta()], CostHint::default(), 4.0, noop());
        // Never owned by a handle: consuming it must not reclaim it.
        let c = g.complete(t, Some(vec![Block::Dense(DenseMatrix::zeros(1, 1))]));
        assert!(c.evicted.is_empty());
        assert!(g.data[bare as usize].value.is_some());
        // Pinned blocks survive a full retain/release cycle.
        let pinned = g.put_block(meta(), Some(Arc::new(Block::Dense(DenseMatrix::zeros(1, 1)))));
        g.retain(pinned);
        g.data[pinned as usize].pinned = true;
        assert_eq!(g.release(pinned), None);
        assert!(g.data[pinned as usize].value.is_some());
    }
}
